"""Recurrent sequence mixers: Mamba (S6), mLSTM and sLSTM (xLSTM).

All three share the framework's mixer contract:

    init_<kind>(key, cfg)                     -> params
    <kind>_forward(params, x, cfg, rules)     -> y               (train/prefill)
    init_<kind>_state(cfg, batch)             -> state           (decode cache)
    <kind>_decode(params, state, x, cfg)      -> (state, y)      (one token)
    <kind>_fill_state(params, x, cfg, rules)  -> (state, y)      (prefill+cache)

Training/prefill run a `lax.scan` over time with a compact carry, so the HLO
stays small and the 500k-token decode shape needs only O(1) state — this is
the sub-quadratic path that lets the SSM/hybrid architectures run long_500k.

TPU note: the recurrences are formulated as dense per-step einsums (MXU
friendly); the mLSTM matrix memory (H, hd, hd) maps onto the systolic array
directly. A chunkwise-parallel Pallas kernel for mLSTM is a perf-iteration
candidate recorded in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.common.sharding import LogicalRules, with_logical_constraint
from repro.models.config import ModelConfig
from repro.models import layers
from repro.models.member_math import member_dot


# ---------------------------------------------------------------------------
# Mamba (S6) — selective state-space model
# ---------------------------------------------------------------------------

def init_mamba(key, cfg: ModelConfig) -> dict:
    pd = layers.param_dtype_of(cfg)
    D, E, N, K = cfg.d_model, cfg.ssm_inner, cfg.ssm_state_dim, cfg.conv_kernel
    R = cfg.dt_rank_actual
    ks = jax.random.split(key, 7)
    # S4D-real initialization for A
    a_log = jnp.log(jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (E, N)))
    return {
        "in_proj": layers.dense_init(ks[0], (D, 2 * E), pd),
        "conv_w": layers.dense_init(ks[1], (K, E), pd, scale=1.0 / math.sqrt(K)),
        "conv_b": jnp.zeros((E,), pd),
        "x_proj": layers.dense_init(ks[2], (E, R + 2 * N), pd),
        "dt_proj_w": layers.dense_init(ks[3], (R, E), pd, scale=R ** -0.5),
        "dt_proj_b": jnp.log(jnp.expm1(  # softplus^-1 of dt in [1e-3, 1e-1]
            jnp.exp(jax.random.uniform(ks[4], (E,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1)))
        )).astype(pd),
        "a_log": a_log.astype(jnp.float32),
        "d_skip": jnp.ones((E,), jnp.float32),
        "out_proj": layers.dense_init(ks[5], (E, D), pd),
    }


MAMBA_AXES = {
    "in_proj": ("embed", "ssm_inner"),
    "conv_w": ("conv_kernel", "ssm_inner"),
    "conv_b": ("ssm_inner",),
    "x_proj": ("ssm_inner", None),
    "dt_proj_w": (None, "ssm_inner"),
    "dt_proj_b": ("ssm_inner",),
    "a_log": ("ssm_inner", "ssm_state"),
    "d_skip": ("ssm_inner",),
    "out_proj": ("ssm_inner", "embed"),
}


def _mamba_gates(params, xc, cfg):
    """xc: (B, E) post-conv activations -> (dt, Bmat, Cmat) for one step."""
    N = cfg.ssm_state_dim
    R = cfg.dt_rank_actual
    proj = jnp.einsum("be,er->br", xc, params["x_proj"].astype(xc.dtype))
    dt_r, Bm, Cm = proj[:, :R], proj[:, R:R + N], proj[:, R + N:]
    dt = jax.nn.softplus(
        jnp.einsum("br,re->be", dt_r, params["dt_proj_w"].astype(xc.dtype)).astype(jnp.float32)
        + params["dt_proj_b"].astype(jnp.float32)
    )  # (B, E)
    return dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def _mamba_step(params, h, xc, cfg):
    """h: (B, E, N) f32 state; xc: (B, E) conv-activated input."""
    A = -jnp.exp(params["a_log"])  # (E, N)
    dt, Bm, Cm = _mamba_gates(params, xc, cfg)
    dA = jnp.exp(dt[..., None] * A[None])                       # (B, E, N)
    dBx = dt[..., None] * Bm[:, None, :] * xc.astype(jnp.float32)[..., None]
    h = h * dA + dBx
    y = jnp.einsum("ben,bn->be", h, Cm) + params["d_skip"] * xc.astype(jnp.float32)
    return h, y


def mamba_forward(params, x, cfg: ModelConfig, rules: LogicalRules):
    state, y = _mamba_scan(params, x, cfg, rules)
    return y


def _mamba_scan(params, x, cfg: ModelConfig, rules: LogicalRules):
    B, S, D = x.shape
    E, N, K = cfg.ssm_inner, cfg.ssm_state_dim, cfg.conv_kernel
    xz = member_dot(x, params["in_proj"].astype(x.dtype))
    xz = with_logical_constraint(xz, rules, ("batch", "seq", "ssm_inner"))
    xi, z = jnp.split(xz, 2, axis=-1)
    # depthwise causal conv over time
    xpad = jnp.pad(xi, ((0, 0), (K - 1, 0), (0, 0)))
    conv = sum(
        xpad[:, i : i + S] * params["conv_w"][i].astype(x.dtype) for i in range(K)
    ) + params["conv_b"].astype(x.dtype)
    xc = jax.nn.silu(conv)  # (B, S, E)

    h0 = jnp.zeros((B, E, N), jnp.float32)

    def step(h, xt):
        h, y = _mamba_step(params, h, xt, cfg)
        return h, y

    h, ys = jax.lax.scan(step, h0, jnp.swapaxes(xc, 0, 1))  # ys: (S, B, E)
    y = jnp.swapaxes(ys, 0, 1).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = member_dot(y, params["out_proj"].astype(x.dtype))
    out = with_logical_constraint(out, rules, ("batch", "seq", "embed_act"))
    # final conv state = last K-1 raw (pre-conv) inner activations
    conv_state = xpad[:, -(K - 1):]
    return {"h": h, "conv": conv_state}, out


def init_mamba_state(cfg: ModelConfig, batch: int) -> dict:
    E, N, K = cfg.ssm_inner, cfg.ssm_state_dim, cfg.conv_kernel
    return {
        "h": jnp.zeros((batch, E, N), jnp.float32),
        "conv": jnp.zeros((batch, K - 1, E), layers.dtype_of(cfg)),
    }


MAMBA_STATE_AXES = {
    "h": ("batch", "ssm_inner", "ssm_state"),
    "conv": ("batch", None, "ssm_inner"),
}


def mamba_decode(params, state, x, cfg: ModelConfig):
    """x: (B, 1, D)."""
    B = x.shape[0]
    K = cfg.conv_kernel
    xz = jnp.einsum("bsd,de->bse", x[:, 0:1], params["in_proj"].astype(x.dtype))[:, 0]
    xi, z = jnp.split(xz, 2, axis=-1)  # (B, E)
    hist = jnp.concatenate([state["conv"], xi[:, None]], axis=1)  # (B, K, E)
    conv = jnp.einsum("bke,ke->be", hist, params["conv_w"].astype(x.dtype)) + params["conv_b"].astype(x.dtype)
    xc = jax.nn.silu(conv)
    h, y = _mamba_step(params, state["h"], xc, cfg)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("be,ed->bd", y, params["out_proj"].astype(x.dtype))
    return {"h": h, "conv": hist[:, 1:]}, out[:, None]


def mamba_fill_state(params, x, cfg: ModelConfig, rules: LogicalRules):
    return _mamba_scan(params, x, cfg, rules)


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory cell)
# ---------------------------------------------------------------------------

def _mlstm_dims(cfg: ModelConfig):
    inner = int(cfg.d_model * cfg.mlstm_proj_factor)
    H = cfg.num_heads
    hd = inner // H
    return inner, H, hd


def init_mlstm(key, cfg: ModelConfig) -> dict:
    pd = layers.param_dtype_of(cfg)
    D = cfg.d_model
    inner, H, hd = _mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "up_proj": layers.dense_init(ks[0], (D, 2 * inner), pd),
        "wq": layers.dense_init(ks[1], (inner, H, hd), pd),
        "wk": layers.dense_init(ks[2], (inner, H, hd), pd),
        "wv": layers.dense_init(ks[3], (inner, H, hd), pd),
        "w_if": layers.dense_init(ks[4], (inner, 2 * H), pd, scale=0.02),
        "b_if": jnp.concatenate(  # forget-gate bias init high (keep memory)
            [jnp.zeros((H,), jnp.float32), jnp.full((H,), 3.0, jnp.float32)]
        ).astype(pd),
        "gn_scale": jnp.ones((H, hd), pd),
        "down_proj": layers.dense_init(ks[5], (inner, D), pd),
    }


MLSTM_AXES = {
    "up_proj": ("embed", "ssm_inner"),
    "wq": ("ssm_inner", "heads", "head_dim"),
    "wk": ("ssm_inner", "heads", "head_dim"),
    "wv": ("ssm_inner", "heads", "head_dim"),
    "w_if": ("ssm_inner", "heads"),
    "b_if": ("heads",),
    "gn_scale": ("heads", "head_dim"),
    "down_proj": ("ssm_inner", "embed"),
}


def _mlstm_step(state, qkvif, eps=1e-6):
    """One mLSTM cell step with exponential-gate stabilization.

    state: C (B,H,hd,hd), n (B,H,hd), m (B,H)
    qkvif: q,k,v (B,H,hd); i_pre,f_pre (B,H)
    """
    C, n, m = state
    q, k, v, i_pre, f_pre = qkvif
    log_f = -jax.nn.softplus(-f_pre)     # log sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    C = f_g[..., None, None] * C + i_g[..., None, None] * (v[..., :, None] * k[..., None, :])
    n = f_g[..., None] * n + i_g[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)), jnp.exp(-m_new)) + eps
    h = jnp.einsum("bhvk,bhk->bhv", C, q) / denom[..., None]
    return (C, n, m_new), h


def _mlstm_qkvif(params, xs, cfg):
    """xs: (B, S, inner) -> per-step tensors, all f32."""
    inner, H, hd = _mlstm_dims(cfg)
    scale = hd ** -0.5
    q = member_dot(xs, params["wq"].astype(xs.dtype)).astype(jnp.float32)
    k = member_dot(xs, params["wk"].astype(xs.dtype)).astype(jnp.float32) * scale
    v = member_dot(xs, params["wv"].astype(xs.dtype)).astype(jnp.float32)
    gates = member_dot(xs, params["w_if"].astype(xs.dtype)).astype(jnp.float32)
    gates = gates + params["b_if"].astype(jnp.float32)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)
    return q, k, v, i_pre, f_pre


def _mlstm_groupnorm(params, h, eps=1e-5):
    """Per-head RMS norm of the cell output. h: (..., H, hd)."""
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    return h * jax.lax.rsqrt(var + eps) * params["gn_scale"].astype(h.dtype)


def _mlstm_scan(params, x, cfg: ModelConfig, rules: LogicalRules):
    B, S, D = x.shape
    inner, H, hd = _mlstm_dims(cfg)
    up = member_dot(x, params["up_proj"].astype(x.dtype))
    up = with_logical_constraint(up, rules, ("batch", "seq", "ssm_inner"))
    xs, z = jnp.split(up, 2, axis=-1)
    q, k, v, i_pre, f_pre = _mlstm_qkvif(params, xs, cfg)

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)

    def step(state, per_t):
        state, h = _mlstm_step(state, per_t)
        return state, h

    xs_t = tuple(jnp.swapaxes(t, 0, 1) for t in (q, k, v, i_pre, f_pre))
    state, hs = jax.lax.scan(step, (C0, n0, m0), xs_t)  # hs: (S, B, H, hd)
    h = jnp.swapaxes(hs, 0, 1)
    h = _mlstm_groupnorm(params, h).reshape(B, S, inner).astype(x.dtype)
    y = h * jax.nn.silu(z)
    out = member_dot(y, params["down_proj"].astype(x.dtype))
    out = with_logical_constraint(out, rules, ("batch", "seq", "embed_act"))
    return {"C": state[0], "n": state[1], "m": state[2]}, out


def mlstm_forward(params, x, cfg, rules):
    return _mlstm_scan(params, x, cfg, rules)[1]


def mlstm_fill_state(params, x, cfg, rules):
    return _mlstm_scan(params, x, cfg, rules)


def init_mlstm_state(cfg: ModelConfig, batch: int) -> dict:
    inner, H, hd = _mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


MLSTM_STATE_AXES = {
    "C": ("batch", "heads", "head_dim", None),
    "n": ("batch", "heads", "head_dim"),
    "m": ("batch", "heads"),
}


def mlstm_decode(params, state, x, cfg: ModelConfig):
    B = x.shape[0]
    inner, H, hd = _mlstm_dims(cfg)
    up = jnp.einsum("bsd,di->bsi", x, params["up_proj"].astype(x.dtype))
    xs, z = jnp.split(up, 2, axis=-1)
    q, k, v, i_pre, f_pre = _mlstm_qkvif(params, xs, cfg)
    st = (state["C"], state["n"], state["m"])
    st, h = _mlstm_step(st, tuple(t[:, 0] for t in (q, k, v, i_pre, f_pre)))
    h = _mlstm_groupnorm(params, h).reshape(B, 1, inner).astype(x.dtype)
    y = h * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, params["down_proj"].astype(x.dtype))
    return {"C": st[0], "n": st[1], "m": st[2]}, out


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory cell with exponential gating)
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig) -> dict:
    pd = layers.param_dtype_of(cfg)
    D, H = cfg.d_model, cfg.num_heads
    hd = D // H
    ks = jax.random.split(key, 6)
    F = cfg.slstm_ffn_dim
    return {
        # input projections for z,i,f,o gates
        "w_x": layers.dense_init(ks[0], (D, 4, H, hd), pd),
        # block-diagonal (per-head) recurrent projections
        "w_h": layers.dense_init(ks[1], (4, H, hd, hd), pd, scale=hd ** -0.5),
        "bias": jnp.zeros((4, H, hd), pd),
        "gn_scale": jnp.ones((H, hd), pd),
        # post-cell gated FFN (factor 4/3)
        "ffn_in": layers.dense_init(ks[2], (D, 2 * F), pd),
        "ffn_out": layers.dense_init(ks[3], (F, D), pd),
    }


SLSTM_AXES = {
    "w_x": ("embed", None, "heads", "head_dim"),
    # second head_dim stays unsharded: a PartitionSpec may not repeat a mesh axis
    "w_h": (None, "heads", "head_dim", None),
    "bias": (None, "heads", "head_dim"),
    "gn_scale": ("heads", "head_dim"),
    "ffn_in": ("embed", "mlp"),
    "ffn_out": ("mlp", "embed"),
}


def _slstm_step(params, state, x_t):
    """state: c,n,m,h each (B,H,hd); x_t: (B, 4, H, hd) pre-projected."""
    c, n, m, h_prev = state
    rec = jnp.einsum("bhd,ghde->bghe", h_prev, params["w_h"].astype(jnp.float32))
    pre = x_t.astype(jnp.float32) + rec + params["bias"].astype(jnp.float32)
    z_pre, i_pre, f_pre, o_pre = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    log_f = -jax.nn.softplus(-f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c_new = f_g * c + i_g * z
    n_new = f_g * n + i_g
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new), h_new


def _slstm_apply(params, x, cfg: ModelConfig, rules: LogicalRules, state=None):
    B, S, D = x.shape
    H = cfg.num_heads
    hd = D // H
    xp = member_dot(x, params["w_x"].astype(x.dtype))  # (B,S,4,H,hd)
    if state is None:
        zeros = jnp.zeros((B, H, hd), jnp.float32)
        state = (zeros, zeros, jnp.full((B, H, hd), -1e30, jnp.float32), zeros)

    def step(st, xt):
        return _slstm_step(params, st, xt)

    state, hs = jax.lax.scan(step, state, jnp.swapaxes(xp, 0, 1))
    h = jnp.swapaxes(hs, 0, 1)  # (B, S, H, hd)
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    h = h * jax.lax.rsqrt(var + 1e-5) * params["gn_scale"].astype(jnp.float32)
    y = h.reshape(B, S, D).astype(x.dtype)
    # gated FFN
    ff = member_dot(y, params["ffn_in"].astype(x.dtype))
    a, g = jnp.split(ff, 2, axis=-1)
    ff = a * jax.nn.sigmoid(g)  # GeGLU-style gate
    out = member_dot(ff, params["ffn_out"].astype(x.dtype))
    out = with_logical_constraint(out, rules, ("batch", "seq", "embed_act"))
    return state, out


def slstm_forward(params, x, cfg, rules):
    return _slstm_apply(params, x, cfg, rules)[1]


def slstm_fill_state(params, x, cfg, rules):
    state, y = _slstm_apply(params, x, cfg, rules)
    return {"c": state[0], "n": state[1], "m": state[2], "h": state[3]}, y


def init_slstm_state(cfg: ModelConfig, batch: int) -> dict:
    H = cfg.num_heads
    hd = cfg.d_model // H
    zeros = jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": zeros, "n": zeros, "m": jnp.full((batch, H, hd), -1e30, jnp.float32), "h": zeros}


SLSTM_STATE_AXES = {
    "c": ("batch", "heads", "head_dim"),
    "n": ("batch", "heads", "head_dim"),
    "m": ("batch", "heads", "head_dim"),
    "h": ("batch", "heads", "head_dim"),
}


def slstm_decode(params, state, x, cfg: ModelConfig):
    st = (state["c"], state["n"], state["m"], state["h"])
    B, S, D = x.shape
    xp = member_dot(x, params["w_x"].astype(x.dtype))
    st, h = _slstm_step(params, st, xp[:, 0])
    H = cfg.num_heads
    hd = D // H
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    h = h * jax.lax.rsqrt(var + 1e-5) * params["gn_scale"].astype(jnp.float32)
    y = h.reshape(B, 1, D).astype(x.dtype)
    ff = member_dot(y, params["ffn_in"].astype(x.dtype))
    a, g = jnp.split(ff, 2, axis=-1)
    ff = a * jax.nn.sigmoid(g)
    out = member_dot(ff, params["ffn_out"].astype(x.dtype))
    return {"c": st[0], "n": st[1], "m": st[2], "h": st[3]}, out
