"""Checkpointing: pytrees -> .npz tensors + JSON treedef manifest.

Layout: <dir>/step_<n>/arrays.npz + manifest.json. Restores to numpy (the
caller re-shards / re-casts as needed). No framework dependencies.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        out[name] = np.asarray(leaf)
    return out


def save_pytree(tree, directory: str, step: Optional[int] = None) -> str:
    d = directory if step is None else os.path.join(directory, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    arrays = _flatten_with_names(tree)
    np.savez(os.path.join(d, "arrays.npz"), **arrays)
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "treedef": str(treedef),
        "names": sorted(arrays),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
    }
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return d


def load_pytree(directory: str, like: Any, step: Optional[int] = None):
    """Restore into the structure of ``like`` (names must match)."""
    d = directory if step is None else os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(d, "arrays.npz"))
    names = _flatten_with_names(like)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    ordered_names = list(names)
    assert len(ordered_names) == len(leaves)
    restored = [data[n] for n in ordered_names]
    return jax.tree_util.tree_unflatten(treedef, restored)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", f))]
    return max(steps) if steps else None


def save_train_state(params, opt_state, step: int, directory: str) -> str:
    return save_pytree({"params": params, "opt": opt_state,
                        "step": np.int64(step)}, directory, step)


def load_train_state(directory: str, like_params, like_opt, step: Optional[int] = None):
    step = step if step is not None else latest_step(directory)
    tree = load_pytree(directory, {"params": like_params, "opt": like_opt,
                                   "step": np.int64(0)}, step)
    return tree["params"], tree["opt"], int(tree["step"])
