"""Shared neural layers: norms, embeddings, RoPE, chunked attention, FFNs.

Design notes
------------
* Pure-JAX, pytree parameters, no flax. Every layer is a pair of functions
  ``init_*(key, cfg, ...) -> params`` and ``apply(params, x, ...) -> y``.
* Attention never materializes the full (S x S) score matrix: prefill/train
  use an online-softmax scanned over KV chunks (jax-native flash attention),
  which is what makes 32k prefill and the memory roofline honest on TPU.
* Sliding-window attention masks the same chunked loop (train/prefill) and
  uses a ring-buffer KV cache at decode time, giving O(window) state for the
  500k-token decode shape.
* All activations are annotated with logical sharding axes so the same code
  lowers on 1 CPU device, a 16x16 pod and the 2x16x16 multi-pod mesh.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.sharding import LogicalRules, with_logical_constraint
from repro.models.config import ModelConfig
from repro.models.member_math import member_dot


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def param_dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., 0::2], x32[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (GQA, causal / bidirectional / windowed)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _pad_to(x, size, axis):
    pad = size - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def chunked_attention(
    q: jnp.ndarray,            # (B, Sq, H, hd)
    k: jnp.ndarray,            # (B, Sk, Hkv, hd)
    v: jnp.ndarray,            # (B, Sk, Hkv, hd)
    *,
    causal: bool,
    q_offset: int | jnp.ndarray = 0,   # absolute position of q[0] (for cache append)
    window: Optional[int] = None,
    q_chunk: int = 512,
    kv_chunk: int = 2048,
    kv_valid: Optional[jnp.ndarray] = None,  # (B,) number of valid kv positions
    remat_chunks: bool = False,
) -> jnp.ndarray:
    """Flash-style attention: scan over query chunks, inner scan over KV chunks
    with running (max, sum, acc) online softmax. Never builds (Sq, Sk) scores.

    ``remat_chunks`` checkpoints each query-chunk body so the backward pass
    recomputes probability blocks per chunk instead of saving every
    (q_chunk x kv_chunk) block of the layer (flash-backward behaviour).
    """
    B, Sq, H, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    G = H // Hkv
    scale = 1.0 / math.sqrt(hd)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq = -(-Sq // q_chunk)
    nk = -(-Sk // kv_chunk)
    Sq_p, Sk_p = nq * q_chunk, nk * kv_chunk

    qp = _pad_to(q, Sq_p, 1).reshape(B, nq, q_chunk, Hkv, G, hd)
    kp = _pad_to(k, Sk_p, 1).reshape(B, nk, kv_chunk, Hkv, hd)
    vp = _pad_to(v, Sk_p, 1).reshape(B, nk, kv_chunk, Hkv, hd)

    q_pos_base = jnp.arange(q_chunk)
    k_pos_base = jnp.arange(kv_chunk)
    kv_valid_arr = kv_valid if kv_valid is not None else None

    def q_body(_, qi):
        qc = qp[:, qi]  # (B, qc, Hkv, G, hd)
        q_pos = q_offset + qi * q_chunk + q_pos_base  # (qc,)

        def kv_body(carry, ki):
            m, l, acc = carry
            kc = kp[:, ki]  # (B, kc, Hkv, hd)
            vc = vp[:, ki]
            k_pos = ki * kv_chunk + k_pos_base  # (kc,)
            # scores: (B, Hkv, G, qc, kc). Inputs stay in model dtype (bf16
            # feeds the MXU natively); accumulation is f32.
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qc, kc,
                preferred_element_type=jnp.float32,
            ) * scale
            mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if window is not None:
                mask &= k_pos[None, :] > (q_pos[:, None] - window)
            mask &= (k_pos < Sk)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            if kv_valid_arr is not None:
                vmask = k_pos[None, :] < kv_valid_arr[:, None]  # (B, kc)
                s = jnp.where(vmask[:, None, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            # probabilities are cast back to the model dtype for the PV
            # matmul (halves the HBM-resident score-block traffic; the
            # accumulator stays f32) — standard flash-attention practice.
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v.dtype), vc,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)  # (B, Hkv, G, qc, hd)
        return None, jnp.transpose(out, (0, 3, 1, 2, 4))  # (B, qc, Hkv, G, hd)

    if remat_chunks:
        q_body = jax.checkpoint(q_body)
    _, outs = jax.lax.scan(q_body, None, jnp.arange(nq))  # (nq, B, qc, Hkv, G, hd)
    out = jnp.transpose(outs, (1, 0, 2, 3, 4, 5)).reshape(B, Sq_p, H, hd)
    return out[:, :Sq].astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,          # (B, 1, H, hd)
    k_cache: jnp.ndarray,    # (B, C, Hkv, hd)
    v_cache: jnp.ndarray,    # (B, C, Hkv, hd)
    valid: jnp.ndarray,      # (B,) or scalar: number of valid cache slots
) -> jnp.ndarray:
    """Single-token attention against a (possibly ring-buffer) KV cache.

    Ring-buffer semantics: every slot with index < valid is a real token; the
    softmax is permutation-invariant so slot order does not matter.
    """
    B, C, Hkv, hd = k_cache.shape
    H = q.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(hd)
    qr = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qr.astype(jnp.float32), k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(C)
    valid = jnp.asarray(valid)
    vmask = pos[None, :] < valid.reshape(-1, 1)  # (B or 1, C)
    s = jnp.where(vmask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig) -> dict:
    pd = param_dtype_of(cfg)
    D, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (D, H, hd), pd),
        "wk": dense_init(k2, (D, Hkv, hd), pd),
        "wv": dense_init(k3, (D, Hkv, hd), pd),
        "wo": dense_init(k4, (H, hd, D), pd, scale=1.0 / math.sqrt(H * hd)),
    }


ATTN_AXES = {
    "wq": ("embed", "heads", "head_dim"),
    "wk": ("embed", "kv_heads", "head_dim"),
    "wv": ("embed", "kv_heads", "head_dim"),
    "wo": ("heads", "head_dim", "embed"),
}


def attention_forward(
    params, x, cfg: ModelConfig, rules: LogicalRules, positions=None
):
    """Full-sequence attention (train / prefill). x: (B, S, D)."""
    B, S, D = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = member_dot(x, params["wq"].astype(x.dtype))
    k = member_dot(x, params["wk"].astype(x.dtype))
    v = member_dot(x, params["wv"].astype(x.dtype))
    q = with_logical_constraint(q, rules, ("batch", "seq", "heads", "head_dim"))
    k = with_logical_constraint(k, rules, ("batch", "seq", "kv_heads", "head_dim"))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = chunked_attention(
        q, k, v,
        causal=cfg.causal,
        window=cfg.sliding_window,
        q_chunk=cfg.q_chunk,
        kv_chunk=cfg.kv_chunk,
        remat_chunks=(cfg.remat == "full"),
    )
    out = with_logical_constraint(out, rules, ("batch", "seq", "heads", "head_dim"))
    y = member_dot(out, params["wo"].astype(x.dtype), ncon=2)
    return with_logical_constraint(y, rules, ("batch", "seq", "embed_act"))


def attention_cache_size(cfg: ModelConfig, max_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, max_len)
    return max_len


def init_attention_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    C = attention_cache_size(cfg, max_len)
    dt = dtype_of(cfg)
    return {
        "k": jnp.zeros((batch, C, cfg.num_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((batch, C, cfg.num_kv_heads, cfg.head_dim), dt),
    }


# decode KV caches get their own sequence axis: when kv-head TP is
# impossible (kv_heads doesn't divide the model axis) the cache shards over
# its SEQUENCE dim instead — decode attention then reduces over the sharded
# seq with only (B, H)-sized softmax-stat psums (see launch.mesh.rules_for).
ATTN_CACHE_AXES = {
    "k": ("batch", "cache_seq", "kv_heads", "head_dim"),
    "v": ("batch", "cache_seq", "kv_heads", "head_dim"),
}


def attention_decode(params, cache, x, pos, cfg: ModelConfig, rules: LogicalRules):
    """One-token decode. x: (B, 1, D); pos: scalar int32 (same for the batch).

    The cache is a ring buffer of size C (= window, or max_len); slot index is
    pos % C. `valid` = min(pos + 1, C).
    """
    B = x.shape[0]
    C = cache["k"].shape[1]
    q = member_dot(x, params["wq"].astype(x.dtype))
    k = member_dot(x, params["wk"].astype(x.dtype))
    v = member_dot(x, params["wv"].astype(x.dtype))
    posb = jnp.full((B, 1), pos)
    q = apply_rope(q, posb, cfg.rope_theta)
    k = apply_rope(k, posb, cfg.rope_theta)
    slot = jnp.mod(pos, C)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    valid = jnp.minimum(pos + 1, C)
    out = decode_attention(q, k_cache, v_cache, valid)
    y = member_dot(out, params["wo"].astype(x.dtype), ncon=2)
    return {"k": k_cache, "v": v_cache}, y


def attention_fill_cache(params, x, cfg: ModelConfig, rules: LogicalRules,
                         max_len: Optional[int] = None):
    """Prefill: run full attention AND return the ring-buffer KV cache.

    ``max_len`` sizes the cache for the decode horizon (>= S + new tokens);
    defaults to S. With a sliding window the cache is the trailing window.
    """
    B, S, D = x.shape
    positions = jnp.arange(S)[None, :]
    k = member_dot(x, params["wk"].astype(x.dtype))
    v = member_dot(x, params["wv"].astype(x.dtype))
    k = apply_rope(k, positions, cfg.rope_theta)
    y = attention_forward(params, x, cfg, rules, positions)
    C = attention_cache_size(cfg, max(max_len or S, S))
    if C >= S:
        # token pos i sits at slot i; tail slots stay zero until decode
        kc = _pad_to(k, C, 1)
        vc = _pad_to(v, C, 1)
    else:
        # last C tokens, laid out at ring slots (S - C + i) % C
        k_tail = jax.lax.dynamic_slice_in_dim(k, S - C, C, axis=1)
        v_tail = jax.lax.dynamic_slice_in_dim(v, S - C, C, axis=1)
        roll = jnp.mod(S - C, C)
        kc = jnp.roll(k_tail, roll, axis=1)
        vc = jnp.roll(v_tail, roll, axis=1)
    return {"k": kc, "v": vc}, y


# ---------------------------------------------------------------------------
# Dense feed-forward (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def init_ffn(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    pd = param_dtype_of(cfg)
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(k1, (D, F), pd),
        "w_out": dense_init(k2, (F, D), pd),
    }
    if cfg.ffn_act == "swiglu":
        p["w_gate"] = dense_init(k3, (D, F), pd)
    return p


FFN_AXES = {
    "w_in": ("embed", "mlp"),
    "w_out": ("mlp", "embed"),
    "w_gate": ("embed", "mlp"),
}


def ffn_forward(params, x, cfg: ModelConfig, rules: LogicalRules):
    h = member_dot(x, params["w_in"].astype(x.dtype))
    if cfg.ffn_act == "swiglu":
        g = member_dot(x, params["w_gate"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    elif cfg.ffn_act == "gelu":
        h = jax.nn.gelu(h)
    elif cfg.ffn_act == "relu2":  # squared ReLU (nemotron / minitron)
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.relu(h)
    h = with_logical_constraint(h, rules, ("batch", "seq", "mlp"))
    y = member_dot(h, params["w_out"].astype(x.dtype))
    return with_logical_constraint(y, rules, ("batch", "seq", "embed_act"))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig) -> dict:
    """Tables padded to cfg.vocab_padded; pad rows stay zero (never indexed,
    and pad logits are masked in the loss via mask_vocab_pad)."""
    pd = param_dtype_of(cfg)
    Vp = cfg.vocab_padded
    k1, k2 = jax.random.split(key)
    tok = dense_init(k1, (cfg.vocab_size, cfg.d_model), pd, scale=1.0)
    tok = _pad_to(tok, Vp, 0)
    p = {"tok": tok}
    if not cfg.tie_embeddings:
        un = dense_init(k2, (cfg.d_model, cfg.vocab_size), pd)
        p["unembed"] = _pad_to(un, Vp, 1)
    return p


# The lookup table keeps its vocab dim REPLICATED ("vocab_lookup" -> None):
# a vocab-sharded gather forces GSPMD into involuntary full rematerialization
# of the table per step. The unembedding stays vocab-sharded (the matmul
# partitions cleanly and the big logits tensor shards with it).
EMBED_AXES = {"tok": ("vocab_lookup", "embed"), "unembed": ("embed", "vocab")}


def mask_vocab_pad(logits, cfg: ModelConfig):
    """-inf the padded vocab columns (elementwise, sharding-compatible)."""
    Vp = logits.shape[-1]
    if Vp == cfg.vocab_size:
        return logits
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(col < cfg.vocab_size, logits, NEG_INF)


def embed_tokens(params, tokens, cfg: ModelConfig, rules: LogicalRules):
    x = params["tok"].astype(dtype_of(cfg))[tokens]
    return with_logical_constraint(x, rules, ("batch", "seq", "embed_act"))


def unembed(params, x, cfg: ModelConfig, rules: LogicalRules):
    if cfg.tie_embeddings:
        w = params["tok"].astype(x.dtype).T
    else:
        w = params["unembed"].astype(x.dtype)
    logits = member_dot(x, w)
    logits = mask_vocab_pad(logits, cfg)
    return with_logical_constraint(logits, rules, ("batch", "seq", "vocab"))
