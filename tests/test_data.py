"""Data pipeline: partitioning, calibration batches, loaders.

Hypothesis-based variants live in ``tests/test_property.py`` (optional dep).
"""
import numpy as np
import pytest

from repro.data import (ClientDataset, batch_iterator, dirichlet_partition,
                        iid_partition, make_calibration_batch,
                        make_classification, make_lm_corpus, train_test_split)


def test_partition_is_exact_cover():
    ds = make_classification(2000, 10, 16, seed=1)
    parts = dirichlet_partition(ds, 13, alpha=0.5, seed=2)
    allidx = np.concatenate(parts)
    assert len(allidx) == len(ds)
    assert len(np.unique(allidx)) == len(ds)


def test_partition_min_size():
    for seed in (0, 3, 77, 512, 999):
        ds = make_classification(1000, 5, 8, seed=seed % 17)
        parts = dirichlet_partition(ds, 10, alpha=0.1, seed=seed, min_size=2)
        assert min(len(p) for p in parts) >= 2


def test_heterogeneity_increases_as_alpha_decreases():
    """Mean per-client label-distribution distance from uniform grows as
    alpha shrinks — the Dirichlet protocol's defining property."""
    ds = make_classification(20000, 10, 8, seed=3)

    def skew(alpha):
        parts = dirichlet_partition(ds, 20, alpha=alpha, seed=4)
        ds_ = []
        for p in parts:
            hist = np.bincount(ds.y[p], minlength=10) / max(len(p), 1)
            ds_.append(np.abs(hist - 0.1).sum())
        return np.mean(ds_)

    assert skew(0.1) > skew(1.0) > skew(100.0)


def test_iid_partition_balanced():
    ds = make_classification(1000, 10, 8, seed=5)
    parts = iid_partition(ds, 7, seed=6)
    sizes = [len(p) for p in parts]
    assert max(sizes) - min(sizes) <= 1


def test_train_test_split_disjoint_fractions():
    ds = make_classification(1000, 10, 8, seed=7)
    tr, te = train_test_split(ds, 0.1, seed=8)
    assert len(te) == 100 and len(tr) == 900


def test_calibration_sources():
    ds = make_classification(500, 10, 16, seed=9)
    real = make_calibration_batch(ds, 32, "real")
    gauss = make_calibration_batch(ds, 32, "gaussian")
    assert real["x"].shape == gauss["x"].shape == (32, 16)
    assert gauss["y"].max() < 10
    # gaussian calibration must NOT be a subset of the data
    assert not any((gauss["x"][0] == ds.x).all(axis=1).any() for _ in [0])


def test_epoch_iterator_counts():
    ds = make_classification(130, 5, 8, seed=10)
    cd = ClientDataset(ds)
    batches = list(cd.epochs(num_epochs=3, batch_size=64, seed=0))
    assert len(batches) == 6  # floor(130/64)=2 per epoch x 3
    assert all(b["x"].shape == (64, 8) for b in batches)


def test_small_client_batch_clamps():
    ds = make_classification(10, 5, 8, seed=11)
    cd = ClientDataset(ds)
    batches = list(cd.epochs(num_epochs=2, batch_size=64, seed=0))
    assert len(batches) == 2 and batches[0]["x"].shape[0] == 10


def test_batch_iterator_drops_tail_batch():
    """The documented batch_iterator contract: every batch is exactly
    ``batch_size`` rows and the ragged tail of each epoch's permutation is
    silently dropped — ``n // batch_size`` batches per epoch, pinned here
    so a future tail-emitting fix is a deliberate contract change."""
    ds = make_classification(130, 5, 8, seed=12)
    it = batch_iterator(ds, batch_size=64, seed=0)
    # 3 epochs' worth: floor(130/64) = 2 full batches per epoch, never a
    # 2-row tail batch
    batches = [next(it) for _ in range(6)]
    assert all(b["x"].shape == (64, 8) for b in batches)
    # epoch boundary check: batches 0-1 and 2-3 come from different
    # permutations of the same rows (row multiset differs by the dropped
    # 2-row tails), and no row repeats within one epoch
    e0 = np.concatenate([batches[0]["x"], batches[1]["x"]])
    assert len(np.unique(e0, axis=0)) == 128


def test_lm_corpus_learnable_structure():
    toks = make_lm_corpus(5000, vocab=64, seed=0, branching=4)
    assert toks.min() >= 0 and toks.max() < 64
    # each token has at most `branching` successors
    succ = {}
    for a, b in zip(toks[:-1], toks[1:]):
        succ.setdefault(int(a), set()).add(int(b))
    assert max(len(v) for v in succ.values()) <= 4
