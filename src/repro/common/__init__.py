from repro.common.tree import (
    tree_add,
    tree_sub,
    tree_scale,
    tree_axpy,
    tree_zeros_like,
    tree_dot,
    tree_sq_norm,
    tree_norm,
    tree_size,
    tree_weighted_sum,
    tree_cast,
    tree_all_finite,
    flatten_to_vector,
    unflatten_from_vector,
)
from repro.common.sharding import (
    LogicalRules,
    logical_to_pspec,
    shard_pytree_spec,
    with_logical_constraint,
)
