"""Event-driven virtual-time AFL simulator (FLGO-style: 86,400 units/day).

Asynchronous runners keep ``concurrency`` clients training at all times: a
heap of completion events; on completion the server ingests the update, a
new client is sampled and dispatched with the *current* global model, and
the learning curve is sampled on a fixed virtual-time grid. The synchronous
FedAvg runner advances rounds at the pace of each round's slowest client —
exactly the straggler behaviour the paper contrasts against.

Two client engines drive the same event semantics:

``cohort`` (default)  completions drain in device batches. Every event's
    training depends only on its dispatch snapshot, so all events due before
    the earliest possible completion of any re-dispatch (``t_first +
    latency_lo``) form a *wave* that trains as ONE compiled call
    (``federated.cohort.CohortEngine`` — vmap over clients, scan over local
    steps, flat parameter layout end to end: dispatch snapshots are the
    server's flat (d,) vector, never a pytree). Receives then apply strictly
    in completion order, so the receive order, per-dispatch lr/seed
    assignment, and RNG streams are identical to the sequential engine.

``sequential``  the legacy reference loop: one ``client.local_update``
    (python loop of per-batch jit calls) per completion. Kept as the
    numerical oracle the batched engine is pinned against.

The paper's defaults (§6.1): 50 clients, 20% concurrency/sampling, 5 local
epochs, batch 64, SGD lr 0.01 with x0.999 decay per (dispatch) round,
latency ~ U(10, 500). Client availability (FLGo-style intermittent
dropouts) is modelled per dispatch: a failed dispatch holds its concurrency
slot for the full response time, then re-dispatches without a receive.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import tree as tu
from repro.core import psa as psa_lib
from repro.data.loader import ClientDataset, ClientSlabStore, StackedClients
from repro.federated import client as client_lib
from repro.federated import servers as servers_lib
from repro.federated.cohort import CohortEngine, StreamingCohortEngine
from repro.federated.latency import STREAM_SYNC_CHOICE, _subseed
from repro.federated.scheduler import Dispatcher, make_scheduler, make_streams
from repro.federated.timeline import Timeline, _Event
from repro.models import model as model_lib
from repro.models import registry
from repro.models.config import ModelConfig

ENGINES = ("cohort", "sequential")

_FALLBACK_WARNED = set()


def _timeline_seed(sim: "SimConfig") -> int:
    """The seed driving the EVENT TIMELINE (latency, client sampling,
    availability) — ``sim.seed`` unless ``sim.timeline_seed`` splits it."""
    return sim.seed if sim.timeline_seed is None else sim.timeline_seed


def _resolve_engine(sim: "SimConfig", cfg: ModelConfig) -> str:
    """Validate ``sim.engine`` and pick the engine that can train ``cfg``.

    The cohort engine compiles any family in the model-family registry
    (``models.registry``); unregistered families fall back to the sequential
    per-client loop (the generic ``client.local_update``) rather than
    crashing on the default ``engine="cohort"`` — with a one-time warning,
    because silently comparing a cohort run against a sequential fallback
    would corrupt benchmarks. The engine actually used is recorded on
    ``SimResult.engine``.
    """
    if sim.engine not in ENGINES:
        raise ValueError(f"unknown engine {sim.engine!r}; known: {ENGINES}")
    if sim.engine == "cohort" and not registry.is_registered(cfg.family):
        if cfg.family not in _FALLBACK_WARNED:
            _FALLBACK_WARNED.add(cfg.family)
            warnings.warn(
                f"model family {cfg.family!r} is not in the model-family "
                f"registry (registered: {registry.registered_families()}); "
                f"engine='cohort' falls back to the sequential loop for it. "
                f"Register the family (models/registry.py) to compile it.",
                RuntimeWarning, stacklevel=3)
        return "sequential"
    return sim.engine


@dataclass
class SimConfig:
    num_clients: int = 50
    concurrency: float = 0.2          # fraction of clients training at once
    local_epochs: int = 5
    batch_size: int = 64
    lr: float = 0.01
    lr_decay: float = 0.999
    horizon: float = 86_400.0         # virtual time units (1 day default)
    eval_every: float = 2_000.0
    latency_kind: str = "uniform"
    latency_lo: float = 10.0
    latency_hi: float = 500.0
    availability_kind: str = "always"  # see latency.per_client_availability
    dropout_rate: float = 0.0          # per-dispatch failure rate when enabled
    # Dispatch policy — who to dispatch and when a freed slot relaunches
    # (federated.scheduler): "uniform" (historical immediate-refill rule,
    # golden-pinned), "period" (FLGo-style period-triggered sampling),
    # "staleness" (CSMAAFL-style utility/staleness-weighted selection).
    # ``scheduler_params`` passes scheduler keyword overrides (e.g.
    # {"period": 40.0} or {"staleness_weight": 2.0}).
    scheduler: str = "uniform"
    scheduler_params: Optional[dict] = None
    seed: int = 0
    # The seed is split along the sweep-lane contract: ``timeline_seed``
    # drives everything that shapes the EVENT TIMELINE (latency draws,
    # client sampling, availability) while ``seed`` keeps driving the
    # model/data side (client batch shuffles). None = use ``seed`` for both
    # (the historical behavior). run_sweep shares one timeline across all
    # lanes and varies only the per-lane model/data seeds.
    timeline_seed: Optional[int] = None
    # Periodic full-fidelity snapshots (repro.checkpoint.store layout):
    # every ``checkpoint_every`` virtual-time units the simulator persists
    # the ServerState, the host RNG streams, the in-flight event timeline and
    # the metric/digest streams under ``checkpoint_dir``. ``resume=True``
    # restores the latest snapshot and reproduces the remaining trajectory
    # exactly. Single runs only (sweeps are not checkpointed).
    checkpoint_dir: Optional[str] = None
    checkpoint_every: float = 0.0
    resume: bool = False
    eval_batches: int = 8
    eval_batch_size: int = 512
    engine: str = "cohort"             # "cohort" (batched) | "sequential"
    max_cohort: int = 256              # cap on one wave's device batch
    # Member-math routing inside the cohort engines (models.member_math):
    # "vmap" keeps the per-member dot_general HLO the golden digests pin;
    # "grouped" collapses each wave's dense layers into single Pallas
    # grouped-GEMM launches over the stacked member axis (compiled on TPU,
    # interpret fallback elsewhere) — 1e-5-parity-pinned against "vmap".
    member_kernel: str = "vmap"        # "vmap" | "grouped"
    # Streaming client slabs (population scale): ``shard_size > 0`` switches
    # the cohort engine from the monolithic (C, n_max, ...) device slab to
    # fixed-size client shards uploaded lazily per wave behind a bounded LRU
    # (``data.loader.ClientSlabStore``); host+device data memory is then
    # O(shard_cache * shard_size * n_max), independent of C. Passing a lazy
    # population (e.g. ``data.synthetic.SyntheticPopulation``) instead of a
    # client-dataset list forces the streaming path (auto shard size when 0).
    shard_size: int = 0                # clients per shard; 0 = monolithic
    shard_cache: int = 32              # max resident shards (LRU)
    shard_promote: int = 8             # cache a shard once a wave wants
                                       # >= this many of its clients
    # Async shard prefetch (streaming engine only): right after a wave's
    # replacement dispatches are inserted, peek the NEXT wave's member set
    # off the timeline (Timeline.peek_wave_cids) and overlap its host
    # materialization + H2D upload with the current device work on the
    # store's single background worker. Pure hint: rows are a pure function
    # of cid, so results are bit-identical with prefetch on or off (see
    # ARCHITECTURE.md "dispatch pipeline contract").
    prefetch: bool = False
    # Layout: with a mesh, the policy server shards ServerState over the
    # mesh's flat-parameter axis (servers.ShardedPolicyServer) and the
    # cohort engine trains waves data-parallel over the client axis; rules
    # (default common.sharding.FEDERATED_RULES) map the logical
    # param_shard/cohort axes onto mesh axes. None = single-device layout.
    mesh: Optional[object] = None      # jax.sharding.Mesh
    rules: Optional[object] = None     # common.sharding.LogicalRules
    # Record a per-receive (||w||, probe·w) digest stream of the global
    # model — the golden-trajectory fingerprint (tests/test_golden.py).
    record_trajectory: bool = False


@dataclass
class SimResult:
    times: List[float] = field(default_factory=list)
    accuracies: List[float] = field(default_factory=list)
    final_accuracy: float = 0.0
    versions: int = 0
    dispatches: int = 0
    launched: int = 0                 # total dispatch calls (incl. in flight)
    dropped: int = 0                  # dispatches lost to client unavailability
    cohorts: int = 0                  # device batches the cohort engine ran
    engine: str = ""                  # engine actually used ("cohort" may
                                      # have resolved to "sequential")
    server_log: List[dict] = field(default_factory=list)
    receive_log: List[dict] = field(default_factory=list)
    digests: List[List[float]] = field(default_factory=list)

    @property
    def aulc(self) -> float:
        """Area under the learning curve normalized by the run's actual
        time span, so the unit (mean accuracy over the run) is comparable
        across horizons — matching the paper's Table 3 convention.

        NaN (not 0.0) when the curve has fewer than two points or spans no
        time (e.g. ``eval_every`` > horizon): there is no area to report,
        and a silent zero would poison AULC comparison tables."""
        if len(self.times) < 2:
            return float("nan")
        t = np.asarray(self.times)
        a = np.asarray(self.accuracies)
        span = float(t[-1] - t[0])
        if span <= 0.0:
            return float("nan")
        return float(np.trapezoid(a, t) / span)


# Cross-run jit reuse: evaluation and sketch closures are deterministic in
# (model, dataset object, config), so cache them instead of re-jitting per
# run. The anchor object is part of the key by id() and is also stored in
# the value: the strong reference keeps the id valid for the cache's
# lifetime, and the identity check guards against id reuse.
_EVAL_CACHE: Dict[tuple, tuple] = {}
_EVAL_LANES_CACHE: Dict[tuple, tuple] = {}
_SKETCH_FN_CACHE: Dict[tuple, tuple] = {}
_SKETCH_FLAT_CACHE: Dict[tuple, tuple] = {}
_SKETCH_LANES_CACHE: Dict[tuple, tuple] = {}


def _memo_identity(cache: Dict[tuple, tuple], key: tuple, anchor, build):
    hit = cache.get(key + (id(anchor),))
    if hit is not None and hit[0] is anchor:
        return hit[1]
    fn = build()
    cache[key + (id(anchor),)] = (anchor, fn)
    return fn


def _make_eval(cfg: ModelConfig, test_ds, sim: SimConfig):
    # the registry entry (None for unregistered families) is part of the
    # key so register_family(..., override=True) invalidates the closure
    fam = (registry.get_family(cfg)
           if registry.is_registered(cfg.family) else None)
    return _memo_identity(
        _EVAL_CACHE, (cfg, sim.eval_batches, sim.eval_batch_size, fam),
        test_ds, lambda: _build_eval(cfg, test_ds, sim))


def _build_eval(cfg: ModelConfig, test_ds, sim: SimConfig):
    from repro.common.sharding import SINGLE_DEVICE_RULES as R

    rng = np.random.RandomState(1234)
    n = len(test_ds)
    bs = min(sim.eval_batch_size, n)
    idxs = [rng.choice(n, size=bs, replace=False) for _ in range(sim.eval_batches)]
    if registry.is_registered(cfg.family):
        fam = registry.get_family(cfg)
        batches = [fam.batch_fn(test_ds.x[ix], test_ds.y[ix]) for ix in idxs]

        @jax.jit
        def acc1(params, batch):
            return fam.eval_accuracy(params, batch, cfg, R)
    else:
        # unregistered family on the sequential fallback: the legacy argmax
        # eval (model_lib.predict raises a clear error for families it
        # cannot score — register the family to plug in a metric)
        batches = [{"x": jnp.asarray(test_ds.x[ix]),
                    "y": jnp.asarray(test_ds.y[ix])} for ix in idxs]

        @jax.jit
        def acc1(params, batch):
            return jnp.mean((model_lib.predict(params, batch["x"], cfg)
                             == batch["y"]).astype(jnp.float32))

    def evaluate(params) -> float:
        return float(np.mean([float(acc1(params, b)) for b in batches]))

    return evaluate


def _make_eval_lanes(cfg: ModelConfig, test_ds, sim: SimConfig,
                     spec: tu.FlatSpec):
    fam = registry.get_family(cfg)
    return _memo_identity(
        _EVAL_LANES_CACHE,
        (cfg, sim.eval_batches, sim.eval_batch_size, fam, spec),
        test_ds, lambda: _build_eval_lanes(cfg, test_ds, sim, spec))


def _build_eval_lanes(cfg: ModelConfig, test_ds, sim: SimConfig,
                      spec: tu.FlatSpec):
    """Lane-batched evaluation: (S, d) flat lane models -> (S,) accuracies,
    one vmapped call per eval batch. Same RandomState(1234) batch draw as
    ``_build_eval``, so a lane's accuracy equals the standalone run's."""
    from repro.common.sharding import SINGLE_DEVICE_RULES as R

    fam = registry.get_family(cfg)
    rng = np.random.RandomState(1234)
    n = len(test_ds)
    bs = min(sim.eval_batch_size, n)
    idxs = [rng.choice(n, size=bs, replace=False)
            for _ in range(sim.eval_batches)]
    batches = [fam.batch_fn(test_ds.x[ix], test_ds.y[ix]) for ix in idxs]

    acc1 = jax.jit(jax.vmap(
        lambda vec, batch: fam.eval_accuracy(spec.unflatten(vec), batch,
                                             cfg, R),
        in_axes=(0, None)))

    def evaluate(flat_stack) -> np.ndarray:
        return np.mean([np.asarray(acc1(flat_stack, b)) for b in batches],
                       axis=0)

    return evaluate


def make_sketch_fn(cfg: ModelConfig, calib_batch: dict, psa_cfg: psa_lib.PSAConfig):
    return _memo_identity(
        _SKETCH_FN_CACHE, (cfg, psa_cfg), calib_batch,
        lambda: _build_sketch_fn(cfg, calib_batch, psa_cfg))


def _build_sketch_fn(cfg: ModelConfig, calib_batch: dict, psa_cfg: psa_lib.PSAConfig):
    calib = {k: jnp.asarray(v) for k, v in calib_batch.items()}
    from repro.common.sharding import SINGLE_DEVICE_RULES as R

    def loss(params, batch):
        return model_lib.loss_fn(params, batch, cfg, R)

    @jax.jit
    def fn(params):
        return psa_lib.client_sketch(loss, params, calib, psa_cfg)

    return fn


def make_sketch_fn_flat(cfg: ModelConfig, calib_batch: dict,
                        psa_cfg: psa_lib.PSAConfig, spec: tu.FlatSpec):
    return _memo_identity(
        _SKETCH_FLAT_CACHE, (cfg, psa_cfg, spec), calib_batch,
        lambda: _build_sketch_fn_flat(cfg, calib_batch, psa_cfg, spec))


def _build_sketch_fn_flat(cfg: ModelConfig, calib_batch: dict,
                          psa_cfg: psa_lib.PSAConfig, spec: tu.FlatSpec):
    """Batched sketch over flat client models: (B, d) -> (B, k), one jitted
    vmap call per wave (row counts bucketed like the engine)."""
    calib = {k: jnp.asarray(v) for k, v in calib_batch.items()}
    from repro.common.sharding import SINGLE_DEVICE_RULES as R

    def loss(params, batch):
        return model_lib.loss_fn(params, batch, cfg, R)

    batched = jax.jit(jax.vmap(
        lambda vec: psa_lib.client_sketch(loss, spec.unflatten(vec), calib,
                                          psa_cfg)))
    from repro.federated.cohort import bucket_size
    data_kind = registry.get_family(cfg).data_kind

    def fn(w_stack: jnp.ndarray) -> jnp.ndarray:
        B = int(w_stack.shape[0])
        # same family-dependent bucket grid as the engine
        Bp = bucket_size(B, data_kind)
        if Bp > B:
            w_stack = jnp.concatenate(
                [w_stack, jnp.zeros((Bp - B, w_stack.shape[1]), w_stack.dtype)])
        return batched(w_stack)[:B]

    return fn


def make_sketch_fn_lanes(cfg: ModelConfig, calib_batch: dict,
                         psa_cfg: psa_lib.PSAConfig, spec: tu.FlatSpec):
    return _memo_identity(
        _SKETCH_LANES_CACHE, (cfg, psa_cfg, spec), calib_batch,
        lambda: _build_sketch_fn_lanes(cfg, calib_batch, psa_cfg, spec))


def _build_sketch_fn_lanes(cfg: ModelConfig, calib_batch: dict,
                           psa_cfg: psa_lib.PSAConfig, spec: tu.FlatSpec):
    """Lane-batched client sketches: (S, B, d) -> (S, B, k) with one nested
    vmap call per wave, member axis bucketed like the engine."""
    calib = {k: jnp.asarray(v) for k, v in calib_batch.items()}
    from repro.common.sharding import SINGLE_DEVICE_RULES as R

    def loss(params, batch):
        return model_lib.loss_fn(params, batch, cfg, R)

    batched = jax.jit(jax.vmap(jax.vmap(
        lambda vec: psa_lib.client_sketch(loss, spec.unflatten(vec), calib,
                                          psa_cfg))))
    from repro.federated.cohort import bucket_size
    data_kind = registry.get_family(cfg).data_kind

    def fn(w_stack: jnp.ndarray) -> jnp.ndarray:
        S, B = int(w_stack.shape[0]), int(w_stack.shape[1])
        Bp = bucket_size(B, data_kind)
        if Bp > B:
            w_stack = jnp.concatenate(
                [w_stack, jnp.zeros((S, Bp - B, w_stack.shape[2]),
                                    w_stack.dtype)], axis=1)
        return batched(w_stack)[:, :B]

    return fn


# Trajectory digest: one (||w||_2, probe·w) pair per applied receive — a
# 2-float fingerprint of the full (d,) global vector that any execution path
# (sequential, cohort, sharded) can be compared on within float tolerance.
_DIGEST_SEED = 0xD16E57
_DIGEST_FN_CACHE: Dict[int, Callable] = {}


def make_digest_fn(d: int) -> Callable:
    """(B, d) -> (B, 2) numpy digest with the fixed probe vector for d.
    Host-side on purpose: the rows are transferred for recording anyway,
    and a jitted variant would recompile for every distinct wave size."""
    fn = _DIGEST_FN_CACHE.get(d)
    if fn is None:
        probe = np.random.RandomState(_DIGEST_SEED).randn(d).astype(np.float32)

        def fn(rows):
            rows = np.asarray(rows, np.float32)
            return np.stack([np.sqrt(np.sum(rows * rows, axis=-1)),
                             rows @ probe], axis=-1)

        _DIGEST_FN_CACHE[d] = fn
    return fn


# ---------------------------------------------------------------------------
# Simulator checkpointing (SimConfig.checkpoint_dir / checkpoint_every)
# ---------------------------------------------------------------------------
# A snapshot is taken at wave boundaries (timeline complete, all receives
# applied): the ServerState leaves, the three host RNG streams (dispatch,
# latency jitter, availability draws), the in-flight events with their
# dispatch snapshots materialized to one (n, d) stack, and the
# metric/digest/receive-log streams — enough to restore mid-run and
# reproduce the REMAINING digest stream exactly. ``server.log`` (the
# policy's rendered per-update log) is the one stream NOT persisted: a
# resumed run's copy covers only the post-resume segment.

def _rng_pack(rng: np.random.RandomState) -> dict:
    kind, keys, pos, has_gauss, cached = rng.get_state()
    assert kind == "MT19937"
    return {"keys": np.asarray(keys, np.uint32),
            "pos": np.int64(pos), "has_gauss": np.int64(has_gauss),
            "cached": np.float64(cached)}


def _rng_unpack(rng: np.random.RandomState, packed: dict) -> None:
    rng.set_state(("MT19937", np.asarray(packed["keys"], np.uint32),
                   int(packed["pos"]), int(packed["has_gauss"]),
                   float(packed["cached"])))


def _event_snapshot_vec(ev: "_Event", spec: tu.FlatSpec) -> np.ndarray:
    """Materialize one in-flight event's dispatch snapshot as a flat (d,)
    row (resolving cohort-engine ``(source, row)`` references and
    flattening sequential-engine pytrees)."""
    s = ev.snapshot
    if isinstance(s, tuple):
        return np.asarray(s[0][s[1]])
    if isinstance(s, jnp.ndarray) and s.ndim == 1:
        return np.asarray(s)
    return np.asarray(spec.flatten(s))


def _ckpt_state_sched(scheduler) -> bool:
    """Whether snapshots for this run carry a scheduler-state subtree.
    Stateless schedulers contribute nothing (their tree layout — and thus
    old snapshots — stays unchanged); stateful ones must have opted in via
    ``checkpoint_state`` (run_async rejects the rest up front)."""
    return not scheduler.stateless and scheduler.checkpoint_state


def _ckpt_save(sim: "SimConfig", server, rng, latency, avail_rng, timeline,
               scheduler, result: "SimResult", t: float, next_eval: float,
               seq: int) -> str:
    from repro.checkpoint import store
    spec = server.policy.spec
    events = timeline.events()
    tree = {
        "server": {f"{i:04d}": np.asarray(x) for i, x in
                   enumerate(jax.tree_util.tree_leaves(server.state))},
        "events": {
            "t_done": np.asarray([e.t_done for e in events], np.float64),
            "seq": np.asarray([e.seq for e in events], np.int64),
            "cid": np.asarray([e.cid for e in events], np.int64),
            "version": np.asarray([e.version for e in events], np.int64),
            "ok": np.asarray([e.ok for e in events], bool),
            "snapshots": np.stack([_event_snapshot_vec(e, spec)
                                   for e in events]),
        },
        "rng": _rng_pack(rng),
        "lat_rng": _rng_pack(latency.rng),
        "avail_rng": _rng_pack(avail_rng),
        "counters": np.asarray(
            [t, next_eval, seq, result.dispatches, result.launched,
             result.dropped, result.cohorts, server.version], np.float64),
        "times": np.asarray(result.times, np.float64),
        "accuracies": np.asarray(result.accuracies, np.float64),
        "digests": np.asarray(result.digests, np.float64).reshape(-1, 2),
        "receive_log": {
            "t": np.asarray([r["t"] for r in result.receive_log], np.float64),
            "tau": np.asarray([r["tau"] for r in result.receive_log],
                              np.int64),
            "client": np.asarray([r["client"] for r in result.receive_log],
                                 np.int64),
        },
    }
    if _ckpt_state_sched(scheduler):
        tree["scheduler"] = scheduler.state_arrays()
    return store.save_pytree(tree, sim.checkpoint_dir, step=result.dispatches)


def _ckpt_like(server, scheduler) -> dict:
    """A structure template for ``store.load_pytree`` (shapes are ignored by
    the restore — only the tree structure and leaf names must match)."""
    z = np.zeros((0,))
    sched_tree = ({"scheduler": {k: z for k in scheduler.state_arrays()}}
                  if _ckpt_state_sched(scheduler) else {})
    return {
        **sched_tree,
        "server": {f"{i:04d}": z for i in
                   range(len(jax.tree_util.tree_leaves(server.state)))},
        "events": {k: z for k in ("t_done", "seq", "cid", "version", "ok",
                                  "snapshots")},
        "rng": {k: z for k in ("keys", "pos", "has_gauss", "cached")},
        "lat_rng": {k: z for k in ("keys", "pos", "has_gauss", "cached")},
        "avail_rng": {k: z for k in ("keys", "pos", "has_gauss", "cached")},
        "counters": z, "times": z, "accuracies": z, "digests": z,
        "receive_log": {k: z for k in ("t", "tau", "client")},
    }


def _ckpt_restore(sim: "SimConfig", server, rng, latency, avail_rng,
                  timeline, scheduler, result: "SimResult", batched: bool):
    """Restore the latest snapshot under ``sim.checkpoint_dir`` into the
    live run, returning ``(t, next_eval, seq)`` — or None when there is no
    snapshot to resume from (the run then starts fresh)."""
    from repro.checkpoint import store
    step = store.latest_step(sim.checkpoint_dir)
    if step is None:
        return None
    tree = store.load_pytree(sim.checkpoint_dir,
                             _ckpt_like(server, scheduler), step)
    if _ckpt_state_sched(scheduler):
        scheduler.load_state_arrays(tree["scheduler"])
    treedef = jax.tree_util.tree_structure(server.state)
    leaves = [jnp.asarray(tree["server"][f"{i:04d}"])
              for i in range(treedef.num_leaves)]
    server.state = jax.tree_util.tree_unflatten(treedef, leaves)
    _rng_unpack(rng, tree["rng"])
    _rng_unpack(latency.rng, tree["lat_rng"])
    _rng_unpack(avail_rng, tree["avail_rng"])
    (t, next_eval, seq, dispatches, launched, dropped, cohorts,
     version) = (float(v) for v in tree["counters"])
    server._version = int(version)
    ev = tree["events"]
    snaps = jnp.asarray(ev["snapshots"], jnp.float32)
    unflatten = (None if batched
                 else tu.jit_unflatten(server.policy.spec))
    timeline.clear()
    n = len(ev["seq"])
    snap_refs = [(snaps, i) if batched else unflatten(snaps[i])
                 for i in range(n)]
    timeline.extend_arrays(ev["t_done"], ev["seq"], ev["cid"],
                           ev["version"], ev["ok"], snap_refs)
    result.dispatches = int(dispatches)
    result.launched = int(launched)
    result.dropped = int(dropped)
    result.cohorts = int(cohorts)
    result.times = [float(x) for x in tree["times"]]
    result.accuracies = [float(x) for x in tree["accuracies"]]
    result.digests = [list(row) for row in tree["digests"]]
    rl = tree["receive_log"]
    result.receive_log = [
        {"t": float(rl["t"][i]), "tau": int(rl["tau"][i]),
         "client": int(rl["client"][i])} for i in range(len(rl["t"]))]
    return float(t), float(next_eval), int(seq)


def _data_sizes(client_datasets) -> np.ndarray:
    """(C,) per-client sample counts — reading ``.sizes`` when the client
    source is a lazy population (no per-client dataset objects to len())."""
    sizes = getattr(client_datasets, "sizes", None)
    if sizes is not None:
        return np.asarray(sizes, np.float64)
    return np.array([len(d) for d in client_datasets], np.float64)


def _wants_streaming(sim: "SimConfig", client_datasets) -> bool:
    """The streaming slab path: explicitly via ``sim.shard_size > 0``, or
    implicitly when the client source is a lazy population object rather
    than a list of materialized ``ClientDataset``s."""
    return sim.shard_size > 0 or not isinstance(client_datasets, (list, tuple))


def _make_cohort_engine(cfg, client_datasets, spec, template_params,
                        sim: "SimConfig", *, prox: float = 0.0,
                        align: float = 0.0):
    """Build the wave-training engine: the monolithic-slab ``CohortEngine``
    by default, the shard-streaming variant when configured (see
    ``SimConfig.shard_size``)."""
    if _wants_streaming(sim, client_datasets):
        if sim.mesh is not None:
            raise ValueError("streaming client slabs are single-device; "
                             "drop SimConfig.mesh or shard_size")
        store = ClientSlabStore.build(
            client_datasets, shard_size=sim.shard_size,
            cache_shards=sim.shard_cache, promote=sim.shard_promote)
        return StreamingCohortEngine(
            cfg, store, spec, template_params,
            local_epochs=sim.local_epochs, batch_size=sim.batch_size,
            prox=prox, align=align, member_kernel=sim.member_kernel)
    stacked = StackedClients.from_datasets(client_datasets)
    return CohortEngine(cfg, stacked, spec, template_params,
                        local_epochs=sim.local_epochs,
                        batch_size=sim.batch_size, prox=prox, align=align,
                        mesh=sim.mesh, rules=sim.rules,
                        member_kernel=sim.member_kernel)


def _gather_snapshots(snaps) -> jnp.ndarray:
    """Stack dispatch snapshots into (B, d) with one gather per distinct
    source instead of one device slice per event. Entries are plain (d,)
    vectors (grouped by identity — e.g. the initial dispatches all share the
    version-0 vector) or ``(source (n, d), row)`` references into a previous
    flush's post-receive sequence."""
    groups: dict = {}
    order = []
    for pos, s in enumerate(snaps):
        src, row = s if isinstance(s, tuple) else (s, None)
        g = groups.get(id(src))
        if g is None:
            g = (src, [], [])
            groups[id(src)] = g
            order.append(g)
        g[1].append(row)
        g[2].append(pos)
    parts, positions = [], []
    for src, rows, poss in order:
        if rows[0] is None:
            parts.append(jnp.broadcast_to(src, (len(poss),) + src.shape))
        elif len(rows) == 1:
            parts.append(src[rows[0]][None])
        else:
            parts.append(src[jnp.asarray(np.asarray(rows, np.int32))])
        positions.extend(poss)
    out = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    if positions != list(range(len(snaps))):
        inv = np.empty(len(snaps), np.int32)
        inv[np.asarray(positions)] = np.arange(len(snaps), dtype=np.int32)
        out = out[jnp.asarray(inv)]
    return out


def _gather_snapshots_lanes(snaps) -> jnp.ndarray:
    """Lane-stacked ``_gather_snapshots``: entries are plain ``(S, d)``
    stacks (grouped by identity) or ``(source (S, n, d), row)`` references
    into a previous flush's post-receive sequence. Returns ``(S, B, d)``."""
    groups: dict = {}
    order = []
    for pos, s in enumerate(snaps):
        src, row = s if isinstance(s, tuple) else (s, None)
        g = groups.get(id(src))
        if g is None:
            g = (src, [], [])
            groups[id(src)] = g
            order.append(g)
        g[1].append(row)
        g[2].append(pos)
    parts, positions = [], []
    for src, rows, poss in order:
        if rows[0] is None:
            parts.append(jnp.broadcast_to(
                src[:, None, :], (src.shape[0], len(poss), src.shape[1])))
        elif len(rows) == 1:
            parts.append(src[:, rows[0]][:, None])
        else:
            parts.append(src[:, jnp.asarray(np.asarray(rows, np.int32))])
        positions.extend(poss)
    out = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    if positions != list(range(len(snaps))):
        inv = np.empty(len(snaps), np.int32)
        inv[np.asarray(positions)] = np.arange(len(snaps), dtype=np.int32)
        out = out[:, jnp.asarray(inv)]
    return out


def run_async(server_name: str, cfg: ModelConfig, init_params,
              client_datasets: List[ClientDataset], test_ds,
              sim: SimConfig, *, psa_cfg: Optional[psa_lib.PSAConfig] = None,
              calib_batch: Optional[dict] = None,
              server_kwargs: Optional[dict] = None,
              receive_hook: Optional[Callable] = None) -> SimResult:
    """Run one asynchronous algorithm to the virtual-time horizon."""
    engine = _resolve_engine(sim, cfg)
    batched = engine == "cohort"
    # One SimStreams bundle replaces the per-run RNG plumbing: the dispatch
    # stream (client sampling, owned by the scheduler), the latency jitter
    # stream, and the availability Bernoulli stream are decorrelated
    # sub-streams (see latency._subseed / scheduler.make_streams).
    streams = make_streams(sim)
    scheduler = make_scheduler(sim)
    if sim.checkpoint_dir and not (scheduler.stateless
                                   or scheduler.checkpoint_state):
        raise ValueError(
            f"scheduler {scheduler.name!r} keeps host-side state beyond its "
            f"RNG and does not implement the state_arrays checkpoint "
            f"round-trip; drop checkpoint_dir or use a checkpointable "
            f"scheduler")
    sketch_fn = None
    if server_name == "fedpsa":
        psa_cfg = psa_cfg or psa_lib.PSAConfig()
        assert calib_batch is not None
        sketch_fn = make_sketch_fn(cfg, calib_batch, psa_cfg)
    server = servers_lib.make_server(
        server_name, init_params, num_clients=sim.num_clients,
        psa_cfg=psa_cfg, sketch_fn=sketch_fn, mesh=sim.mesh, rules=sim.rules,
        **(server_kwargs or {}))
    align = getattr(server, "client_align", 0.0)
    digest_fn = (make_digest_fn(server.policy.spec.size)
                 if sim.record_trajectory else None)

    evaluate = _make_eval(cfg, test_ds, sim)
    result = SimResult(engine=engine)
    concurrency = max(1, int(round(sim.concurrency * sim.num_clients)))
    timeline = Timeline()
    data_sizes = _data_sizes(client_datasets)
    dispatcher = Dispatcher(sim, streams, scheduler, timeline, server,
                            result, batched=batched, data_sizes=data_sizes)

    t0 = next_eval0 = 0.0
    resumed = None
    if sim.checkpoint_dir and sim.resume:
        resumed = _ckpt_restore(sim, server, streams.rng, streams.latency,
                                streams.avail_rng, timeline, scheduler,
                                result, batched)
    if resumed is None:
        dispatcher.dispatch_many(np.zeros(concurrency))
    else:
        t0, next_eval0, dispatcher.seq = resumed

    ckpt = None
    if sim.checkpoint_dir and sim.checkpoint_every > 0:
        nxt = [(np.floor(t0 / sim.checkpoint_every) + 1)
               * sim.checkpoint_every]

        def ckpt(timeline_, t_, next_eval_):
            if t_ < nxt[0]:
                return
            _ckpt_save(sim, server, streams.rng, streams.latency,
                       streams.avail_rng, timeline_, scheduler, result, t_,
                       next_eval_, dispatcher.seq)
            while nxt[0] <= t_:
                nxt[0] += sim.checkpoint_every

    if batched:
        t = _drain_cohort(server, cfg, init_params, client_datasets, sim,
                          dispatcher.dispatch_many, timeline, evaluate,
                          result, data_sizes, align, psa_cfg, calib_batch,
                          receive_hook, digest_fn, t0=t0,
                          next_eval0=next_eval0, ckpt=ckpt)
    else:
        t = _drain_sequential(server, cfg, client_datasets, sim,
                              dispatcher.dispatch, timeline, evaluate,
                              result, data_sizes, align,
                              sketch_fn, receive_hook, digest_fn,
                              t0=t0, next_eval0=next_eval0, ckpt=ckpt)

    result.final_accuracy = evaluate(server.params)
    result.times.append(min(t, sim.horizon))
    result.accuracies.append(result.final_accuracy)
    result.versions = server.version
    result.server_log = server.log
    return result


def _drain_sequential(server, cfg, client_datasets, sim: SimConfig, dispatch,
                      timeline, evaluate, result: SimResult, data_sizes,
                      align, sketch_fn, receive_hook, digest_fn=None, *,
                      t0: float = 0.0, next_eval0: float = 0.0,
                      ckpt=None) -> float:
    """Legacy reference loop: one local_update per completion (oracle)."""
    next_eval = next_eval0
    t = t0
    while timeline and t < sim.horizon:
        if ckpt is not None:
            ckpt(timeline, t, next_eval)
        ev = timeline.pop()
        t = ev.t_done
        if t > sim.horizon:
            break
        while next_eval <= t:
            acc = evaluate(server.params)
            result.times.append(next_eval)
            result.accuracies.append(acc)
            next_eval += sim.eval_every
        if not ev.ok:
            result.dropped += 1
            dispatch(t)
            continue
        lr = sim.lr * (sim.lr_decay ** result.dispatches)
        delta, w_client = client_lib.local_update(
            ev.snapshot, cfg, client_datasets[ev.cid],
            epochs=sim.local_epochs, batch_size=sim.batch_size, lr=lr,
            seed=sim.seed * 100003 + result.dispatches, align=align)
        meta = {
            "tau": server.version - ev.version,
            "client_id": ev.cid,
            "data_size": float(data_sizes[ev.cid]),
        }
        if server.needs_sketch:
            meta["sketch"] = sketch_fn(w_client)
        if receive_hook is not None:
            receive_hook(server, w_client, delta, meta, t)
        server.receive(delta, w_client, meta)
        if digest_fn is not None:
            result.digests.append(
                digest_fn(server.flat_params[None, :])[0].tolist())
        result.dispatches += 1
        result.receive_log.append({"t": t, "tau": meta["tau"], "client": ev.cid})
        dispatch(t)
    return t


def _drain_cohort(server, cfg, init_params, client_datasets, sim: SimConfig,
                  dispatch_many, timeline, evaluate, result: SimResult,
                  data_sizes, align, psa_cfg, calib_batch, receive_hook,
                  digest_fn=None, *, t0: float = 0.0,
                  next_eval0: float = 0.0, ckpt=None) -> float:
    """Batched drain: train completion waves as single device calls.

    A wave is the maximal timeline prefix with ``t_done < t_first +
    latency_lo`` (capped at ``sim.max_cohort``). Any dispatch issued while
    the wave is being received completes no earlier than ``t_first +
    latency_lo`` — and at an equal timestamp sorts after the wave by ``seq``
    — so training the wave up front observes exactly the snapshots, learning
    rates, and seeds the sequential engine would have used.
    """
    spec = server.policy.spec
    engine = _make_cohort_engine(cfg, client_datasets, spec, init_params,
                                 sim, align=align)
    # prefetch only has a target on the streaming engine (the monolithic
    # slab is fully device-resident already)
    prefetch_store = (getattr(engine, "store", None) if sim.prefetch
                      else None)
    sketch_flat = None
    if server.needs_sketch:
        sketch_flat = make_sketch_fn_flat(cfg, calib_batch, psa_cfg, spec)
    unflatten = tu.jit_unflatten(spec) if receive_hook is not None else None

    next_eval = next_eval0
    t = t0
    while timeline and t < sim.horizon:
        if ckpt is not None:
            ckpt(timeline, t, next_eval)
        first = timeline.pop()
        if first.t_done > sim.horizon:
            t = first.t_done       # mirror the sequential pop-then-break
            break
        bound = first.t_done + sim.latency_lo
        wave: List[_Event] = [first]
        t_over = None
        while (timeline and timeline.head_t() < bound
               and len(wave) < sim.max_cohort):
            ev = timeline.pop()
            if ev.t_done > sim.horizon:
                t_over = ev.t_done  # discarded, like the sequential break
                break
            wave.append(ev)

        ok_events = [ev for ev in wave if ev.ok]
        deltas = w_stack = sketches = None
        if ok_events:
            d0 = result.dispatches
            snapshots = _gather_snapshots([ev.snapshot for ev in ok_events])
            cids = [ev.cid for ev in ok_events]
            lrs = [sim.lr * (sim.lr_decay ** (d0 + r))
                   for r in range(len(ok_events))]
            seeds = [sim.seed * 100003 + (d0 + r)
                     for r in range(len(ok_events))]
            deltas, w_stack = engine.cohort_update(snapshots, cids, lrs, seeds)
            if sketch_flat is not None:
                sketches = sketch_flat(w_stack)
            result.cohorts += 1

        # Receives are deferred into ``pending`` and flushed as ONE batched
        # ingest (``receive_many``) — flushing early only when an eval
        # boundary needs the intermediate global model, or per-event when a
        # receive_hook must observe pre-receive server state. Replacement
        # dispatches happen inside the flush, each snapshotting the global
        # vector as of *its* event (``snaps`` rows), so RNG order and
        # snapshot contents match the sequential engine exactly.
        pending: List[_Event] = []
        next_row = 0

        def flush():
            nonlocal next_row
            if not pending:
                return
            ok = [ev for ev in pending if ev.ok]
            r0, r1 = next_row, next_row + len(ok)
            cur = server.flat_params   # pre-flush vector, for leading dropouts
            snaps = None
            upd = np.zeros((0,), bool)
            if ok:
                if receive_hook is not None:
                    assert len(pending) == 1
                    ev = ok[0]
                    meta = {"tau": server.version - ev.version,
                            "client_id": ev.cid,
                            "data_size": float(data_sizes[ev.cid])}
                    if sketches is not None:
                        meta["sketch"] = sketches[r0]
                    receive_hook(server, unflatten(w_stack[r0]),
                                 unflatten(deltas[r0]), meta, ev.t_done)
                upd, taus, snaps = server.receive_many(
                    deltas[r0:r1], w_stack[r0:r1],
                    [ev.cid for ev in ok],
                    [float(data_sizes[ev.cid]) for ev in ok],
                    [ev.version for ev in ok],
                    None if sketches is None else sketches[r0:r1])
                if digest_fn is not None:
                    result.digests.extend(digest_fn(snaps).tolist())
                for ev, tau in zip(ok, taus):
                    result.receive_log.append(
                        {"t": ev.t_done, "tau": tau, "client": ev.cid})
                result.dispatches += len(ok)
                next_row = r1
            vcur = server.version - int(np.sum(upd))  # version pre-flush
            oi = 0
            # replacement dispatches batched as ONE run insertion; each
            # snapshots the global vector as of *its* event (snaps rows)
            ts_, snaps_, vers_ = [], [], []
            for ev in pending:
                if ev.ok:
                    cur = (snaps, oi)   # row reference, gathered lazily
                    vcur += int(upd[oi])
                    oi += 1
                else:
                    result.dropped += 1
                ts_.append(ev.t_done)
                snaps_.append(cur)
                vers_.append(vcur)
            dispatch_many(ts_, snaps_, vers_)
            pending.clear()

        for ev in wave:
            t = ev.t_done
            if next_eval <= t:
                flush()
                while next_eval <= t:
                    acc = evaluate(server.params)
                    result.times.append(next_eval)
                    result.accuracies.append(acc)
                    next_eval += sim.eval_every
            pending.append(ev)
            if receive_hook is not None:
                flush()
        flush()
        # the wave's replacements are inserted: the NEXT wave's member set
        # is determined, so overlap its materialization + upload with the
        # still-retiring device work (device dispatch is async)
        if prefetch_store is not None and t_over is None and t < sim.horizon:
            nxt = timeline.peek_wave_cids(sim.latency_lo, sim.max_cohort,
                                          sim.horizon)
            if nxt.size:
                prefetch_store.prefetch(nxt)
        if t_over is not None:
            t = t_over
            break
    return t


# ---------------------------------------------------------------------------
# Fleet sweep engine: S experiment lanes as ONE batched simulation
# ---------------------------------------------------------------------------

@dataclass
class SweepConfig:
    """S experiment variants ("lanes") of one batched simulation.

    All lanes share one event timeline (``SimConfig.timeline_seed``, falling
    back to ``SimConfig.seed``): latency draws, client sampling, dropout,
    wave boundaries and version bookkeeping are identical across lanes, so
    the whole grid trains and ingests through lane-vmapped compiled calls.
    What may vary per lane:

    * ``model_seeds`` — per-lane model-init seeds (``init_params`` is used
      for every lane when None),
    * ``data_seeds`` — per-lane client batch-shuffle seeds (``SimConfig
      .seed`` for every lane when None),
    * ``policy_params`` — per-lane dicts of timeline-preserving policy
      hyperparameters (``federated.policies.PolicyParams`` field names:
      alpha, a, server_lr, beta, gamma, delta, eps, use_thermometer,
      dist_mode — the asyncfeded l2/cosine metric, "l2"/"cosine" accepted).

    Shape-determining parameters (buffer_size, queue_len, sketch_k,
    num_clients) and the client sketch program (use_sensitivity) are
    structural: lanes must share them (pass via psa_cfg/server_kwargs).
    """
    num_lanes: Optional[int] = None
    model_seeds: Optional[List[int]] = None
    data_seeds: Optional[List[int]] = None
    policy_params: Optional[List[Optional[dict]]] = None

    def resolve(self, base_seed: int):
        given = [x for x in (self.model_seeds, self.data_seeds,
                             self.policy_params) if x is not None]
        lens = {len(x) for x in given}
        if self.num_lanes is not None:
            lens.add(int(self.num_lanes))
        if len(lens) > 1:
            raise ValueError(
                f"inconsistent lane counts in SweepConfig: {sorted(lens)}")
        S = lens.pop() if lens else 1
        if S < 1:
            raise ValueError("a sweep needs at least one lane")
        data_seeds = (list(self.data_seeds) if self.data_seeds is not None
                      else [base_seed] * S)
        hypers = (list(self.policy_params)
                  if self.policy_params is not None else [None] * S)
        model_seeds = (list(self.model_seeds)
                       if self.model_seeds is not None else None)
        return S, model_seeds, data_seeds, hypers


@dataclass
class SweepResult:
    """A batched ``SimResult``: shared timeline counters + per-lane streams.

    ``lane_accuracies[s]`` is lane s's learning curve over the shared
    ``times`` grid; ``digests[s]`` its per-receive trajectory digest stream
    (when ``record_trajectory``). ``lane(s)`` views one lane as a plain
    ``SimResult`` for code that consumes single runs."""
    num_lanes: int = 1
    times: List[float] = field(default_factory=list)
    lane_accuracies: List[List[float]] = field(default_factory=list)
    final_accuracy: List[float] = field(default_factory=list)
    versions: int = 0
    dispatches: int = 0
    launched: int = 0
    dropped: int = 0
    cohorts: int = 0
    engine: str = "cohort"
    receive_log: List[dict] = field(default_factory=list)
    digests: List[List[List[float]]] = field(default_factory=list)

    def lane(self, s: int) -> SimResult:
        return SimResult(
            times=list(self.times), accuracies=list(self.lane_accuracies[s]),
            final_accuracy=self.final_accuracy[s], versions=self.versions,
            dispatches=self.dispatches, launched=self.launched,
            dropped=self.dropped, cohorts=self.cohorts, engine=self.engine,
            receive_log=list(self.receive_log),
            digests=[list(d) for d in self.digests[s]])

    @property
    def aulc(self) -> List[float]:
        return [self.lane(s).aulc for s in range(self.num_lanes)]

    def accuracy_mean_std(self):
        a = np.asarray(self.final_accuracy, np.float64)
        return float(a.mean()), float(a.std())


def run_sweep(server_name: str, cfg: ModelConfig, init_params,
              client_datasets: List[ClientDataset], test_ds,
              sim: SimConfig, sweep: SweepConfig, *,
              psa_cfg: Optional[psa_lib.PSAConfig] = None,
              calib_batch: Optional[dict] = None,
              server_kwargs: Optional[dict] = None) -> SweepResult:
    """Run S variants of one async algorithm as ONE batched simulation.

    One host event heap drives every lane (see ``SweepConfig``); per wave
    the cohort engine trains an ``(S, B, d)`` snapshot stack in one compiled
    call (``CohortEngine.sweep_update``) and the lane-stacked server ingests
    it with one vmapped scan (``servers.LanePolicyServer``), so the whole
    seed x hyperparameter grid pays the per-dispatch overhead once instead
    of S times. Lane s reproduces the standalone run with
    ``SimConfig(seed=data_seeds[s], timeline_seed=<shared>)``, that lane's
    init params, and its hyper overrides, within float tolerance
    (``tests/test_sweep.py`` pins this).
    """
    if server_name == "fedavg":
        raise ValueError("run_sweep batches the async policies; run the "
                         "synchronous fedavg per seed instead")
    if sim.mesh is not None:
        raise ValueError("run_sweep is single-device; drop SimConfig.mesh")
    if sim.checkpoint_dir:
        raise ValueError("checkpointing supports single runs, not sweeps")
    engine = _resolve_engine(sim, cfg)
    if engine != "cohort":
        raise ValueError(
            "run_sweep requires the batched cohort engine (engine='cohort' "
            "and a registered model family)")
    S, model_seeds, data_seeds, lane_hypers = sweep.resolve(sim.seed)
    if model_seeds is None:
        params_lanes = [init_params] * S
    else:
        params_lanes = [model_lib.init_params(jax.random.PRNGKey(int(s)), cfg)
                        for s in model_seeds]

    streams = make_streams(sim)
    scheduler = make_scheduler(sim)
    sketch_fn = None
    if server_name == "fedpsa":
        psa_cfg = psa_cfg or psa_lib.PSAConfig()
        assert calib_batch is not None
        sketch_fn = make_sketch_fn(cfg, calib_batch, psa_cfg)
    server = servers_lib.make_lane_server(
        server_name, params_lanes, lane_hypers, num_clients=sim.num_clients,
        psa_cfg=psa_cfg, sketch_fn=sketch_fn, **(server_kwargs or {}))
    align = server.client_align
    spec = server.policy.spec
    digest_fn = (make_digest_fn(spec.size) if sim.record_trajectory else None)

    evaluate = _make_eval_lanes(cfg, test_ds, sim, spec)
    result = SweepResult(num_lanes=S, engine="cohort",
                         lane_accuracies=[[] for _ in range(S)],
                         digests=[[] for _ in range(S)])
    concurrency = max(1, int(round(sim.concurrency * sim.num_clients)))
    timeline = Timeline()
    data_sizes = _data_sizes(client_datasets)

    # Same Dispatcher as run_async: batched=True snapshots the (S, d) lane
    # stack, and the RNG stream layout is identical, so a 1-lane sweep
    # replays the exact single-run event timeline.
    dispatcher = Dispatcher(sim, streams, scheduler, timeline, server,
                            result, batched=True, data_sizes=data_sizes)
    dispatcher.dispatch_many(np.zeros(concurrency))

    t = _drain_sweep(server, cfg, params_lanes, client_datasets, sim,
                     dispatcher.dispatch_many, timeline, evaluate, result,
                     data_sizes, align, psa_cfg, calib_batch, digest_fn,
                     data_seeds)

    final = evaluate(server.flat_params)
    result.final_accuracy = [float(a) for a in final]
    result.times.append(min(t, sim.horizon))
    for s in range(S):
        result.lane_accuracies[s].append(result.final_accuracy[s])
    result.versions = server.version
    return result


def _drain_sweep(server, cfg, params_lanes, client_datasets, sim: SimConfig,
                 dispatch_many, timeline, evaluate, result: SweepResult,
                 data_sizes, align, psa_cfg, calib_batch, digest_fn,
                 data_seeds) -> float:
    """The cohort drain, lane-stacked: identical wave selection and flush
    ordering to ``_drain_cohort`` (the timeline is lane-invariant), with
    every tensor growing a leading lane axis."""
    S = server.num_lanes
    spec = server.policy.spec
    engine = _make_cohort_engine(cfg, client_datasets, spec, params_lanes[0],
                                 sim, align=align)
    prefetch_store = (getattr(engine, "store", None) if sim.prefetch
                      else None)
    sketch_lanes = None
    if server.needs_sketch:
        sketch_lanes = make_sketch_fn_lanes(cfg, calib_batch, psa_cfg, spec)

    next_eval = 0.0
    t = 0.0
    while timeline and t < sim.horizon:
        first = timeline.pop()
        if first.t_done > sim.horizon:
            t = first.t_done
            break
        bound = first.t_done + sim.latency_lo
        wave: List[_Event] = [first]
        t_over = None
        while (timeline and timeline.head_t() < bound
               and len(wave) < sim.max_cohort):
            ev = timeline.pop()
            if ev.t_done > sim.horizon:
                t_over = ev.t_done
                break
            wave.append(ev)

        ok_events = [ev for ev in wave if ev.ok]
        deltas = w_stack = sketches = None
        if ok_events:
            d0 = result.dispatches
            snapshots = _gather_snapshots_lanes(
                [ev.snapshot for ev in ok_events])
            cids = [ev.cid for ev in ok_events]
            lrs = [sim.lr * (sim.lr_decay ** (d0 + r))
                   for r in range(len(ok_events))]
            seeds = np.asarray(
                [[int(ds) * 100003 + (d0 + r)
                  for r in range(len(ok_events))] for ds in data_seeds])
            deltas, w_stack = engine.sweep_update(snapshots, cids, lrs, seeds)
            if sketch_lanes is not None:
                sketches = sketch_lanes(w_stack)
            result.cohorts += 1

        pending: List[_Event] = []
        next_row = 0

        def flush():
            nonlocal next_row
            if not pending:
                return
            ok = [ev for ev in pending if ev.ok]
            r0, r1 = next_row, next_row + len(ok)
            cur = server.flat_params       # (S, d) pre-flush stack
            snaps = None
            upd = np.zeros((0,), bool)
            if ok:
                upd, taus, snaps = server.receive_many(
                    deltas[:, r0:r1], w_stack[:, r0:r1],
                    [ev.cid for ev in ok],
                    [float(data_sizes[ev.cid]) for ev in ok],
                    [ev.version for ev in ok],
                    None if sketches is None else sketches[:, r0:r1])
                if digest_fn is not None:
                    rows = np.asarray(snaps)           # (S, B, d) once
                    for s in range(S):
                        result.digests[s].extend(digest_fn(rows[s]).tolist())
                for ev, tau in zip(ok, taus):
                    result.receive_log.append(
                        {"t": ev.t_done, "tau": tau, "client": ev.cid})
                result.dispatches += len(ok)
                next_row = r1
            vcur = server.version - int(np.sum(upd))
            oi = 0
            ts_, snaps_, vers_ = [], [], []
            for ev in pending:
                if ev.ok:
                    cur = (snaps, oi)
                    vcur += int(upd[oi])
                    oi += 1
                else:
                    result.dropped += 1
                ts_.append(ev.t_done)
                snaps_.append(cur)
                vers_.append(vcur)
            dispatch_many(ts_, snaps_, vers_)
            pending.clear()

        for ev in wave:
            t = ev.t_done
            if next_eval <= t:
                flush()
                while next_eval <= t:
                    accs = evaluate(server.flat_params)
                    result.times.append(next_eval)
                    for s in range(S):
                        result.lane_accuracies[s].append(float(accs[s]))
                    next_eval += sim.eval_every
            pending.append(ev)
        flush()
        if prefetch_store is not None and t_over is None and t < sim.horizon:
            nxt = timeline.peek_wave_cids(sim.latency_lo, sim.max_cohort,
                                          sim.horizon)
            if nxt.size:
                prefetch_store.prefetch(nxt)
        if t_over is not None:
            t = t_over
            break
    return t


def run_fedavg(cfg: ModelConfig, init_params, client_datasets: List[ClientDataset],
               test_ds, sim: SimConfig, *, prox: float = 0.0) -> SimResult:
    """Synchronous FedAvg: per round sample 20% of clients, wait for the
    slowest, aggregate weighted by client data size. With the cohort engine
    the whole round trains as one device call and the global model stays a
    flat (d,) vector between rounds."""
    streams = make_streams(sim)
    latency = streams.latency
    avail, avail_rng = streams.avail, streams.avail_rng
    trace = streams.trace
    use_trace, use_avail = streams.use_trace, streams.use_avail
    # Round sampling draws from its own _subseed stream: the bare dispatch
    # RandomState(tseed) belongs to the async schedulers, and sharing it
    # here let the sync path perturb async reproducibility at equal seeds.
    choice_rng = np.random.RandomState(
        _subseed(streams.tseed, STREAM_SYNC_CHOICE))
    evaluate = _make_eval(cfg, test_ds, sim)
    engine = _resolve_engine(sim, cfg)
    batched = engine == "cohort"
    result = SimResult(engine=engine)
    m = max(1, int(round(sim.concurrency * sim.num_clients)))
    data_sizes = _data_sizes(client_datasets)
    if batched:
        spec = tu.FlatSpec(init_params)
        engine = _make_cohort_engine(cfg, client_datasets, spec, init_params,
                                     sim, prox=prox)
        flat = jnp.array(spec.flatten(init_params), copy=True)
        params = None
    else:
        params = init_params
    t = 0.0
    next_eval = 0.0
    rnd = 0
    while t < sim.horizon:
        while next_eval <= t:
            acc = evaluate(spec.unflatten(flat) if batched else params)
            result.times.append(next_eval)
            result.accuracies.append(acc)
            next_eval += sim.eval_every
        chosen = choice_rng.choice(sim.num_clients, size=m, replace=False)
        result.launched += len(chosen)
        round_time = float(latency.sample_for(chosen).max())
        if use_trace or use_avail:
            ok = (trace.on_at(chosen, np.full(m, t)) if use_trace
                  else avail_rng.rand(m) < avail[chosen])
            result.dropped += int(np.sum(~ok))
            active = [int(c) for c, o in zip(chosen, ok) if o]
        else:
            active = [int(c) for c in chosen]
        lr = sim.lr * (sim.lr_decay ** rnd)
        if active:
            sizes = np.asarray([data_sizes[c] for c in active], np.float32)
            w = jnp.asarray(sizes / np.sum(sizes))
            seeds = [sim.seed * 100003 + rnd * 51 + c for c in active]
            if batched:
                snapshots = jnp.broadcast_to(flat, (len(active), flat.shape[0]))
                deltas, _ = engine.cohort_update(snapshots, active,
                                                 [lr] * len(active), seeds)
                flat = flat + jnp.einsum("b,bd->d", w, deltas)
                result.cohorts += 1
            else:
                deltas = []
                for c, s in zip(active, seeds):
                    d, _ = client_lib.local_update(
                        params, cfg, client_datasets[c],
                        epochs=sim.local_epochs, batch_size=sim.batch_size,
                        lr=lr, seed=s, prox=prox)
                    deltas.append(d)
                params = tu.tree_add(params, tu.tree_weighted_sum(deltas, w))
        t += round_time
        rnd += 1
        result.dispatches += len(active)
    final_params = spec.unflatten(flat) if batched else params
    result.final_accuracy = evaluate(final_params)
    result.times.append(min(t, sim.horizon))
    result.accuracies.append(result.final_accuracy)
    result.versions = rnd
    return result


ALGORITHMS = ("fedavg", "fedasync", "fedbuff", "fedpsa", "ca2fl", "fedfa",
              "fedpac", "asyncfeded")


def run_algorithm(name: str, cfg: ModelConfig, init_params, client_datasets,
                  test_ds, sim: SimConfig, **kw) -> SimResult:
    if name == "fedavg":
        kw.pop("psa_cfg", None)
        kw.pop("calib_batch", None)
        return run_fedavg(cfg, init_params, client_datasets, test_ds, sim, **kw)
    return run_async(name, cfg, init_params, client_datasets, test_ds, sim, **kw)
