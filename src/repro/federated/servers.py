"""Server-side aggregation strategies.

Implemented: FedAsync [14], FedBuff [39], FedPSA (ours), CA2FL [15],
FedFa [27], FedPAC-lite [40] (async servers share one interface), plus the
synchronous FedAvg [5] which the simulator runs round-based.

Interface:
    receive(delta, client_params, meta) -> bool   # True if global updated
    params                                        # current global pytree
    version                                       # number of global updates
``meta`` carries tau (version gap), client_id, data_size and, for FedPSA,
the uploaded sensitivity sketch.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import tree as tu
from repro.core import aggregation as agg
from repro.core import psa as psa_lib
from repro.core import sketch as sketch_lib


class BaseServer:
    name = "base"
    needs_sketch = False

    def __init__(self, params):
        self.params = params
        self.version = 0
        self.log: List[dict] = []

    def receive(self, delta, client_params, meta) -> bool:
        raise NotImplementedError


class FedAsyncServer(BaseServer):
    """FedAsync: immediate mixing w <- (1-a)w + a*w_i, a = alpha*s(tau)."""
    name = "fedasync"

    def __init__(self, params, alpha: float = 0.6, a: float = 0.5):
        super().__init__(params)
        self.alpha, self.a = alpha, a

    def receive(self, delta, client_params, meta) -> bool:
        s = float(agg.staleness_polynomial(meta["tau"], self.alpha, self.a))
        self.params = jax.tree_util.tree_map(
            lambda w, wi: (1 - s) * w + s * wi, self.params, client_params)
        self.version += 1
        self.log.append({"tau": meta["tau"], "weight": s})
        return True


class FedBuffServer(BaseServer):
    """FedBuff: buffer K staleness-scaled deltas, apply their mean."""
    name = "fedbuff"

    def __init__(self, params, buffer_size: int = 5, server_lr: float = 1.0,
                 a: float = 0.5):
        super().__init__(params)
        self.buffer_size = buffer_size
        self.server_lr = server_lr
        self.a = a
        self.buffer: List = []

    def receive(self, delta, client_params, meta) -> bool:
        scale = float(agg.staleness_polynomial(meta["tau"], 1.0, self.a))
        self.buffer.append(tu.tree_scale(delta, scale))
        if len(self.buffer) < self.buffer_size:
            return False
        w = agg.uniform_weights(len(self.buffer)) * self.server_lr
        self.params = agg.aggregate_buffer(self.params, self.buffer, w)
        self.buffer.clear()
        self.version += 1
        return True


class FedPSAServer(BaseServer):
    """FedPSA (Algorithm 1): behavioral-staleness softmax over the buffer."""
    name = "fedpsa"
    needs_sketch = True

    def __init__(self, params, cfg_psa: psa_lib.PSAConfig,
                 sketch_fn: Callable):
        super().__init__(params)
        self.psa = psa_lib.init_state(cfg_psa)
        self.sketch_fn = sketch_fn  # params -> k-vector (shared calib batch)
        self.psa.global_sketch = sketch_fn(params)

    def receive(self, delta, client_params, meta) -> bool:
        psa_lib.server_receive(self.psa, delta, meta["sketch"])
        if not psa_lib.buffer_full(self.psa):
            return False
        self.params, info = psa_lib.server_aggregate(self.psa, self.params)
        self.version += 1
        self.psa.global_sketch = self.sketch_fn(self.params)
        self.log.append({
            "weights": np.asarray(info["weights"]),
            "kappas": np.asarray(info["kappas"]),
            "temp": None if info["temp"] is None else float(info["temp"]),
        })
        return True


class CA2FLServer(BaseServer):
    """CA2FL: cached-update calibration. Keeps the latest delta h_i per
    client; aggregation calibrates the buffer mean with the cache mean."""
    name = "ca2fl"

    def __init__(self, params, num_clients: int, buffer_size: int = 5,
                 server_lr: float = 1.0):
        super().__init__(params)
        self.buffer_size = buffer_size
        self.server_lr = server_lr
        self.buffer: List = []
        self.cache: Dict[int, object] = {}
        self.num_clients = num_clients
        self.h_sum = None  # running sum of cached deltas

    def receive(self, delta, client_params, meta) -> bool:
        cid = meta["client_id"]
        prev = self.cache.get(cid)
        self.buffer.append((delta, prev))
        # update cache & running sum
        if self.h_sum is None:
            self.h_sum = tu.tree_zeros_like(delta)
        if prev is not None:
            self.h_sum = tu.tree_sub(self.h_sum, prev)
        self.h_sum = tu.tree_add(self.h_sum, delta)
        self.cache[cid] = delta
        if len(self.buffer) < self.buffer_size:
            return False
        n_cached = max(len(self.cache), 1)
        h_mean = tu.tree_scale(self.h_sum, 1.0 / n_cached)
        resid = [tu.tree_sub(d, p) if p is not None else d
                 for d, p in self.buffer]
        v = tu.tree_add(
            tu.tree_scale(
                jax.tree_util.tree_map(lambda *xs: sum(xs), *resid)
                if len(resid) > 1 else resid[0],
                1.0 / len(resid)),
            h_mean)
        self.params = tu.tree_axpy(self.server_lr, v, self.params)
        self.buffer.clear()
        self.version += 1
        return True


class FedFaServer(BaseServer):
    """FedFa: fully-asynchronous queue of recent client models; the global
    model is a recency-weighted average of the queue, refreshed per arrival."""
    name = "fedfa"

    def __init__(self, params, queue_len: int = 5, beta: float = 0.5):
        super().__init__(params)
        self.queue_len = queue_len
        self.beta = beta
        self.queue: List = []

    def receive(self, delta, client_params, meta) -> bool:
        self.queue.append(client_params)
        if len(self.queue) > self.queue_len:
            self.queue.pop(0)
        n = len(self.queue)
        w = np.array([self.beta ** (n - 1 - j) for j in range(n)], np.float32)
        w /= w.sum()
        self.params = tu.tree_weighted_sum(list(self.queue), jnp.asarray(w))
        self.version += 1
        return True


class FedPACLiteServer(BaseServer):
    """FedPAC-lite: FedBuff-style buffering; clients train with an extra
    classifier-alignment term (see client.local_update(align=...)). The
    feature-alignment of the full method is approximated by the head
    alignment — enough to reproduce its qualitative async behavior."""
    name = "fedpac"
    client_align = 0.1

    def __init__(self, params, buffer_size: int = 5, server_lr: float = 1.0):
        super().__init__(params)
        self.buffer_size = buffer_size
        self.server_lr = server_lr
        self.buffer: List = []

    def receive(self, delta, client_params, meta) -> bool:
        self.buffer.append(delta)
        if len(self.buffer) < self.buffer_size:
            return False
        w = agg.uniform_weights(len(self.buffer)) * self.server_lr
        self.params = agg.aggregate_buffer(self.params, self.buffer, w)
        self.buffer.clear()
        self.version += 1
        return True


def make_server(name: str, params, *, num_clients: int = 50,
                psa_cfg: Optional[psa_lib.PSAConfig] = None,
                sketch_fn: Optional[Callable] = None, **kw) -> BaseServer:
    if name == "fedasync":
        return FedAsyncServer(params, **kw)
    if name == "fedbuff":
        return FedBuffServer(params, **kw)
    if name == "fedpsa":
        assert psa_cfg is not None and sketch_fn is not None
        return FedPSAServer(params, psa_cfg, sketch_fn)
    if name == "ca2fl":
        return CA2FLServer(params, num_clients=num_clients, **kw)
    if name == "fedfa":
        return FedFaServer(params, **kw)
    if name == "fedpac":
        return FedPACLiteServer(params, **kw)
    raise ValueError(f"unknown async server {name!r}")
