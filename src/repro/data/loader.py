"""Client-side data loading: epoch iterators + device-resident stacking.

``ClientDataset`` is the per-client host view (shuffled epoch batches).
``StackedClients`` is the cohort engine's device view: every client's data
padded into one ``(C, n_max, ...)`` slab with sizes and validity masks, so
local training for a whole cohort is a single gather + vmapped scan instead
of C python loops.

Both views are layout-polymorphic over the registry's two data kinds:
*image* shards hold ``x (n, ...) float32`` features and ``y (n,) int``
labels and batch as ``{"x", "y"}``; *token* shards (federated LM
fine-tuning) hold ``x = y = (n, seq) int32`` token sequences and batch as
``{"tokens", "labels"}`` — the keys ``models.registry``'s token
``client_loss`` (i.e. ``model_lib.loss_fn``) speaks. The kind is inferred
from the feature dtype (integer => tokens), so the cohort slab becomes a
``(C, n_max, seq)`` int32 token/label pair with the SAME sizes/mask/shuffle
machinery as the image slab.

Both views draw batch order from ``epoch_batch_indices`` — the one shuffle
routine — so the vectorized engine visits exactly the batches the legacy
per-client loop would (same ``np.random.RandomState`` stream, same
drop-last rule), which is what makes the 1e-5 parity tests meaningful.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Iterator, List, Sequence

import numpy as np

from repro.data.synthetic import SyntheticClassification


def epoch_batch_indices(n: int, num_epochs: int, batch_size: int,
                        seed: int) -> np.ndarray:
    """Batch schedule for one client: ``(steps, bs)`` int32 indices into its
    ``n`` samples, ``bs = min(batch_size, n)``, drop-last, one fresh
    permutation per epoch from ``RandomState(seed)``."""
    rng = np.random.RandomState(seed)
    bs = min(batch_size, n)
    m = n // bs                       # drop-last batch count per epoch
    out = np.empty((num_epochs * m, bs), np.int32)
    for e in range(num_epochs):
        out[e * m:(e + 1) * m] = rng.permutation(n)[:m * bs].reshape(m, bs)
    return out


def data_kind_of(x: np.ndarray) -> str:
    """The registry data kind a feature array implies: integer dtypes are
    token-id sequences, everything else image/feature rows."""
    return "tokens" if np.issubdtype(np.asarray(x).dtype, np.integer) \
        else "image"


@dataclass
class ClientDataset:
    data: SyntheticClassification

    def __len__(self):
        return len(self.data)

    @property
    def kind(self) -> str:
        return data_kind_of(self.data.x)

    def epochs(self, num_epochs: int, batch_size: int, seed: int) -> Iterator[dict]:
        tokens = self.kind == "tokens"
        for idx in epoch_batch_indices(len(self.data), num_epochs,
                                       batch_size, seed):
            if tokens:
                yield {"tokens": self.data.x[idx].astype(np.int32),
                       "labels": self.data.y[idx].astype(np.int32)}
            else:
                yield {"x": self.data.x[idx].astype(np.float32),
                       "y": self.data.y[idx].astype(np.int32)}


@dataclass
class StackedClients:
    """All clients' data as one padded slab (the cohort engine's layout).

    ``x[c, :sizes[c]]`` are client ``c``'s real samples; rows beyond that are
    zero padding with ``mask`` False. Padding never reaches a loss term: the
    batch schedules index only real rows, and ragged batch tails are masked
    inside the engine's loss (for token shards, by turning the padded rows'
    labels into the ``-1`` no-target sentinel).

    ``kind == "image"``: x (C, n_max, ...) float32, y (C, n_max) int32.
    ``kind == "tokens"``: x and y both (C, n_max, seq) int32.
    """
    x: np.ndarray        # (C, n_max, ...) float32 features | int32 tokens
    y: np.ndarray        # (C, n_max[, seq]) int32 labels
    sizes: np.ndarray    # (C,) int32 true per-client sample counts
    mask: np.ndarray     # (C, n_max) bool — True on real rows
    num_classes: int
    kind: str = "image"

    def __len__(self):
        return self.x.shape[0]

    @property
    def n_max(self) -> int:
        return self.x.shape[1]

    @classmethod
    def from_datasets(cls, datasets: Sequence[ClientDataset]) -> "StackedClients":
        sizes = np.asarray([len(d) for d in datasets], np.int32)
        n_max = int(sizes.max())
        d0 = datasets[0].data
        kind = data_kind_of(d0.x)
        feat = d0.x.shape[1:]
        lab = d0.y.shape[1:]
        C = len(datasets)
        x = np.zeros((C, n_max) + feat,
                     np.int32 if kind == "tokens" else np.float32)
        y = np.zeros((C, n_max) + lab, np.int32)
        mask = np.zeros((C, n_max), bool)
        for c, d in enumerate(datasets):
            n = sizes[c]
            x[c, :n] = d.data.x.astype(x.dtype)
            y[c, :n] = d.data.y.astype(np.int32)
            mask[c, :n] = True
        return cls(x=x, y=y, sizes=sizes, mask=mask,
                   num_classes=d0.num_classes, kind=kind)


class _ListSource:
    """Row source over a materialized client-dataset list — the small-C
    adapter that lets the streaming slab path run on exactly the data the
    monolithic ``StackedClients`` slab would hold (digest-parity tests)."""

    def __init__(self, datasets: Sequence[ClientDataset]):
        self._datasets = list(datasets)
        self.sizes = np.asarray([len(d) for d in self._datasets], np.int64)
        self.n_max = int(self.sizes.max())
        d0 = self._datasets[0].data
        self.kind = data_kind_of(d0.x)
        self.num_classes = d0.num_classes
        self._xdtype = np.int32 if self.kind == "tokens" else np.float32
        self._feat = d0.x.shape[1:]
        self._lab = d0.y.shape[1:]

    def member_rows(self, cids):
        cids = np.asarray(cids, np.int64)
        B = cids.shape[0]
        x = np.zeros((B, self.n_max) + self._feat, self._xdtype)
        y = np.zeros((B, self.n_max) + self._lab, np.int32)
        for i, c in enumerate(cids):
            d = self._datasets[int(c)]
            n = int(self.sizes[c])
            x[i, :n] = d.data.x.astype(self._xdtype)
            y[i, :n] = d.data.y.astype(np.int32)
        return x, y


class ClientSlabStore:
    """Chunked/streaming ``StackedClients``: fixed-size client shards with
    lazy device upload behind a bounded LRU.

    The monolithic slab holds all C clients on device at once —
    O(C * n_max) memory, the population-scale blocker. This store keys
    device residency by the *wave's member set* instead: ``gather(cids)``
    returns the members' ``(B, n_max, ...)`` rows, serving each member
    either from a cached device shard (clients ``[s*shard_size, (s+1) *
    shard_size)`` as one array) or, for shards the wave barely touches,
    from a direct host materialization of just those members ("row path" —
    uploaded with the wave, never cached). A shard is materialized and
    cached only when a wave wants >= ``promote`` of its clients, and at
    most ``cache_shards`` shards stay resident (LRU), so host+device data
    memory is O(cache_shards * shard_size * n_max) — set by the shard
    geometry, not by C.

    Rows come from a deterministic source (``member_rows`` is a pure
    function of client id), so evictions can never change results — only
    which path serves a member. ``stats`` counts both paths for the tests
    and the population benchmark.
    """

    def __init__(self, source, *, shard_size: int, cache_shards: int = 32,
                 promote: int = 8):
        self.source = source
        self.sizes = np.asarray(source.sizes, np.int64)
        self.num_clients = int(self.sizes.shape[0])
        self.shard_size = int(shard_size)
        assert self.shard_size >= 1
        self.num_shards = -(-self.num_clients // self.shard_size)
        self.cache_shards = max(1, int(cache_shards))
        self.promote = max(1, int(promote))
        self._cache: OrderedDict = OrderedDict()   # sid -> (x_dev, y_dev)
        self.hits = 0            # members served from cached shards
        self.row_fetches = 0     # members served via the row path
        self.shard_loads = 0     # full-shard materializations
        self.evictions = 0

    @classmethod
    def build(cls, client_datasets, *, shard_size: int = 0,
              cache_shards: int = 32, promote: int = 8) -> "ClientSlabStore":
        """Wrap either a lazy population (anything with ``member_rows``) or
        a plain client-dataset list; ``shard_size=0`` picks a default."""
        source = (client_datasets
                  if hasattr(client_datasets, "member_rows")
                  else _ListSource(client_datasets))
        if shard_size <= 0:
            shard_size = min(1024, int(np.asarray(source.sizes).shape[0]))
        return cls(source, shard_size=shard_size, cache_shards=cache_shards,
                   promote=promote)

    @property
    def n_max(self) -> int:
        return self.source.n_max

    @property
    def kind(self) -> str:
        return self.source.kind

    @property
    def num_classes(self) -> int:
        return self.source.num_classes

    @property
    def stats(self) -> dict:
        return {"hits": self.hits, "row_fetches": self.row_fetches,
                "shard_loads": self.shard_loads, "evictions": self.evictions,
                "resident_shards": len(self._cache)}

    def _load_shard(self, sid: int):
        import jax.numpy as jnp
        lo = sid * self.shard_size
        hi = min(lo + self.shard_size, self.num_clients)
        x, y = self.source.member_rows(np.arange(lo, hi))
        entry = (jnp.asarray(x), jnp.asarray(y))
        self._cache[sid] = entry
        self.shard_loads += 1
        while len(self._cache) > self.cache_shards:
            self._cache.popitem(last=False)
            self.evictions += 1
        return entry

    def gather(self, cids):
        """Members' rows as device ``(B, n_max, ...)`` arrays, one gather
        per touched cached shard plus at most one row-path upload, restored
        to input order (mirrors ``simulator._gather_snapshots``)."""
        import jax.numpy as jnp
        cids = np.asarray(cids, np.int64)
        B = cids.shape[0]
        by_shard: dict = {}
        for pos, c in enumerate(cids):
            by_shard.setdefault(int(c) // self.shard_size, []).append(pos)
        parts_x, parts_y, positions, miss = [], [], [], []
        for sid, poss in by_shard.items():
            entry = self._cache.get(sid)
            if entry is None and len(poss) >= self.promote:
                entry = self._load_shard(sid)
            if entry is None:
                miss.extend(poss)
                self.row_fetches += len(poss)
                continue
            self._cache.move_to_end(sid)
            self.hits += len(poss)
            rows = cids[poss] - sid * self.shard_size
            rows_j = jnp.asarray(rows.astype(np.int32))
            parts_x.append(entry[0][rows_j])
            parts_y.append(entry[1][rows_j])
            positions.extend(poss)
        if miss:
            x_h, y_h = self.source.member_rows(cids[miss])
            parts_x.append(jnp.asarray(x_h))
            parts_y.append(jnp.asarray(y_h))
            positions.extend(miss)
        x = parts_x[0] if len(parts_x) == 1 else jnp.concatenate(parts_x)
        y = parts_y[0] if len(parts_y) == 1 else jnp.concatenate(parts_y)
        if positions != list(range(B)):
            inv = np.empty(B, np.int32)
            inv[np.asarray(positions)] = np.arange(B, dtype=np.int32)
            inv_j = jnp.asarray(inv)
            x, y = x[inv_j], y[inv_j]
        return x, y


def batch_iterator(ds: SyntheticClassification, batch_size: int,
                   seed: int = 0) -> Iterator[dict]:
    """Endless shuffled batches (evaluation/training streams)."""
    rng = np.random.RandomState(seed)
    n = len(ds)
    while True:
        order = rng.permutation(n)
        for start in range(0, n - batch_size + 1, batch_size):
            idx = order[start:start + batch_size]
            yield {"x": ds.x[idx].astype(np.float32),
                   "y": ds.y[idx].astype(np.int32)}
