"""arctic-480b [moe] — 128 experts top-2 + dense residual [hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2. Arctic
is a dense-MoE hybrid: every layer has a (small) dense residual FFN in
parallel with the routed-expert FFN (ffn kind "moe+dense"). 128 experts
shard 8-per-device over the 16-way model axis (expert parallelism).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    block_pattern=("attn",),
    ffn_pattern=("moe+dense",),
    num_experts=128,
    top_k=2,
    moe_d_ff=4864,
    # §Perf opt: group-local dispatch (see qwen2-moe; same mechanism)
    dispatch_groups=16,
    long_context_window=8192,
)
