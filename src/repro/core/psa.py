"""FedPSA — the paper's contribution as a *functional* composable module.

Client side: ``client_sketch`` computes the Eq. 8 sensitivity on the shared
calibration batch and compresses it to a k-vector (Eq. 11) — by default via
the fused Pallas sensitivity+sketch kernel. Server side: ``PSAState`` is an
immutable NamedTuple pytree holding a fixed-size stacked ``(L_s, d)`` update
ring buffer; ``server_receive`` / ``server_aggregate`` are pure functions
and ``server_step`` fuses them (receive + conditional aggregate + optional
global-sketch refresh) into ONE jit-compilable device step with
``lax.cond`` replacing all host-side branching.

The buffered Eq. 20 apply runs through the Pallas ``buffer_agg`` kernel over
the flat contiguous parameter layout (compiled on TPU, interpreter fallback
elsewhere). The event-driven federated simulator consumes this module via
``repro.federated.policies``; ``launch/dryrun.py`` lowers ``client_sketch``
under the production meshes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.common import sharding
from repro.common import tree as tu
from repro.core import aggregation, sketch, thermometer
from repro.core.sensitivity import fisher_diagonal, sensitivity as _compute_sensitivity


@dataclass(frozen=True)
class PSAConfig:
    buffer_size: int = 5          # L_s (paper: 5)
    queue_len: int = 50           # L_q (paper: 50)
    gamma: float = 5.0            # temperature slope (paper: 5)
    delta: float = 0.5            # temperature floor (paper: 0.5)
    sketch_k: int = 16            # compressed dimension k (paper: 16)
    sketch_seed: int = 42         # shared projection seed (stands in for R)
    fisher_microbatches: int = 4
    server_lr: float = 1.0
    use_sensitivity: bool = True  # False => raw-parameter sketch (w/o S ablation)
    use_thermometer: bool = True  # False => fixed Temp = delta+gamma (w/o T ablation)


def structural(cfg: PSAConfig) -> tuple:
    """The shape/program-determining subset of a PSAConfig — what a compiled
    server step actually closes over. gamma/delta/server_lr/use_thermometer
    are *traced* hyperparameters (they may vary per sweep lane), so two
    configs with equal ``structural()`` share one compiled step."""
    return (cfg.buffer_size, cfg.queue_len, cfg.sketch_k, cfg.sketch_seed,
            cfg.fisher_microbatches, cfg.use_sensitivity)


def client_sketch(loss_fn: Callable, params, calib_batch, cfg: PSAConfig,
                  *, fused: Optional[bool] = None) -> jnp.ndarray:
    """What a client uploads alongside its update: the k-dim sensitivity
    sketch evaluated on the shared calibration batch.

    ``fused=True`` routes through the Pallas sensitivity+sketch kernel (the
    d-sized sensitivity vector is never materialized in HBM); ``fused=False``
    keeps the reference two-pass jnp pipeline. Default (None) picks the
    kernel on TPU and the cheaper reference path elsewhere (interpreting the
    kernel off-TPU costs more than the jnp pipeline it fuses).
    """
    if fused is None:
        from repro.kernels.buffer_agg import resolve_interpret
        fused = not resolve_interpret(None)  # fused kernel only on TPU
    if not cfg.use_sensitivity:  # w/o S ablation: sketch the raw parameters
        return sketch.sketch_tree(params, cfg.sketch_seed, cfg.sketch_k)
    if fused:
        from repro.kernels import ops  # deferred: avoids import cycle at pkg init
        g = jax.grad(loss_fn)(params, calib_batch)
        f = fisher_diagonal(loss_fn, params, calib_batch, cfg.fisher_microbatches)
        return ops.sketch_tree_fused(params, g, f, k=cfg.sketch_k,
                                     seed=cfg.sketch_seed)
    s = _compute_sensitivity(loss_fn, params, calib_batch,
                             cfg.fisher_microbatches)
    return sketch.sketch_tree(s, cfg.sketch_seed, cfg.sketch_k)


class PSAState(NamedTuple):
    """Server-side Algorithm-1 state as an immutable pytree of arrays.

    ``buffer`` is a stacked ``(L_s, d)`` ring over the flat f32 parameter
    layout; ``count`` is the fill level since the last aggregation (the slot
    cycling makes clearing implicit — aggregation resets ``count`` to 0 and
    stale slots are simply overwritten on the next cycle).
    """
    buffer: jnp.ndarray          # (L_s, d) stacked update ring
    kappas: jnp.ndarray          # (L_s,) behavioral similarity per slot
    count: jnp.ndarray           # int32 fill level since last aggregate
    thermo: thermometer.ThermometerState
    global_sketch: jnp.ndarray   # (k,) sketch of the current global model

    @property
    def buffer_size(self) -> int:
        return self.buffer.shape[0]


class PSAInfo(NamedTuple):
    """Per-step diagnostics with fixed shapes (jit-friendly; ``temp_valid``
    distinguishes the uniform-averaging phase where legacy code used None)."""
    updated: jnp.ndarray         # bool — did this step apply an aggregation
    weights: jnp.ndarray         # (L_s,) aggregation weights (zeros if not)
    kappas: jnp.ndarray          # (L_s,) buffer kappa snapshot
    temp: jnp.ndarray            # f32 softmax temperature
    temp_valid: jnp.ndarray      # bool — temp meaningful (queue was full)
    m_cur: jnp.ndarray           # f32 thermometer current mean


def init_state(cfg: PSAConfig, d: int,
               global_sketch: Optional[jnp.ndarray] = None) -> PSAState:
    """Fresh server state for a d-parameter model."""
    if global_sketch is None:
        global_sketch = jnp.zeros((cfg.sketch_k,), jnp.float32)
    return PSAState(
        buffer=jnp.zeros((cfg.buffer_size, d), jnp.float32),
        kappas=jnp.zeros((cfg.buffer_size,), jnp.float32),
        count=jnp.int32(0),
        thermo=thermometer.init_thermometer(cfg.queue_len),
        global_sketch=jnp.asarray(global_sketch, jnp.float32),
    )


def server_receive(state: PSAState, update_vec: jnp.ndarray,
                   client_sketch_vec: jnp.ndarray) -> PSAState:
    """Algorithm 1 lines 14-16 (pure): write (dw, kappa) into the next ring
    slot and push the update magnitude into the thermometer queue.

    Contract: aggregate once ``buffer_full`` — the fixed-size ring means a
    push beyond ``buffer_size`` unflushed receives overwrites the oldest
    slot (the legacy list buffer grew unboundedly instead). The fused
    ``server_step`` honors this by construction."""
    kappa = sketch.cosine(client_sketch_vec, state.global_sketch)
    buffer, slot = tu.ring_update(state.buffer,
                                  update_vec.astype(jnp.float32), state.count)
    kappas = state.kappas.at[slot].set(kappa)
    # Eq. 16 — param_axis_sum: psum-completed when traced per-shard
    m = sharding.param_axis_sum(jnp.square(update_vec.astype(jnp.float32)))
    return state._replace(buffer=buffer, kappas=kappas,
                          count=state.count + 1,
                          thermo=thermometer.push(state.thermo, m))


def buffer_full(state: PSAState) -> jnp.ndarray:
    return state.count >= state.buffer_size


def _weights_and_temp(state: PSAState, cfg: PSAConfig, *, gamma=None,
                      delta=None, thermo_on=None):
    """Eq. 18-19 with the Algorithm-1 phase switch as a jnp select: uniform
    averaging until the thermometer queue first fills, temperature softmax
    afterwards (or always, with a fixed temp, under the w/o T ablation).

    ``gamma``/``delta``/``thermo_on`` default to the static config values;
    passing traced scalars instead (the policy core reads them from
    ``ServerState.hyper``) compiles ONE program that serves every value —
    including a lane-stacked grid under vmap. With ``thermo_on`` given, the
    w/o-T ablation becomes a jnp select with arithmetic identical to both
    static branches."""
    L = state.buffer_size
    uniform = aggregation.uniform_weights(L)
    gamma = cfg.gamma if gamma is None else gamma
    delta = cfg.delta if delta is None else delta
    if thermo_on is None:
        if cfg.use_thermometer:
            queue_ready = thermometer.is_full(state.thermo)
            temp = thermometer.temperature(state.thermo, gamma, delta)
            weights = jnp.where(queue_ready,
                                aggregation.psa_weights(state.kappas, temp),
                                uniform)
            return weights, temp, queue_ready
        temp = (jnp.asarray(gamma, jnp.float32)
                + jnp.asarray(delta, jnp.float32))
        return aggregation.psa_weights(state.kappas, temp), temp, \
            jnp.bool_(True)
    thermo_on = jnp.asarray(thermo_on, jnp.bool_)
    temp = jnp.where(thermo_on,
                     thermometer.temperature(state.thermo, gamma, delta),
                     jnp.asarray(gamma, jnp.float32)
                     + jnp.asarray(delta, jnp.float32))
    queue_ready = jnp.logical_or(jnp.logical_not(thermo_on),
                                 thermometer.is_full(state.thermo))
    weights = jnp.where(queue_ready,
                        aggregation.psa_weights(state.kappas, temp), uniform)
    return weights, temp, queue_ready


def server_aggregate(state: PSAState, global_vec: jnp.ndarray,
                     cfg: PSAConfig, *, gamma=None, delta=None,
                     server_lr=None, thermo_on=None):
    """Algorithm 1 lines 17-31 (pure): weight the buffered updates and apply
    them to the flat global vector via the Pallas buffer_agg kernel.

    Returns ``(new_state, new_global_vec, PSAInfo)`` — the same ordering as
    the fused ``server_step``. Call only when ``buffer_full`` (``server_step``
    handles the gating for you). The keyword hyperparameters accept traced
    scalars (defaulting to the static config values) — see
    ``_weights_and_temp``.
    """
    weights, temp, temp_valid = _weights_and_temp(
        state, cfg, gamma=gamma, delta=delta, thermo_on=thermo_on)
    new_global = aggregation.aggregate_flat(
        global_vec, state.buffer, weights,
        cfg.server_lr if server_lr is None else server_lr)
    info = PSAInfo(updated=jnp.bool_(True), weights=weights,
                   kappas=state.kappas, temp=temp,
                   temp_valid=jnp.asarray(temp_valid),
                   m_cur=thermometer.current_mean(state.thermo))
    return state._replace(count=jnp.int32(0)), new_global, info


def server_step(state: PSAState, global_vec: jnp.ndarray,
                update_vec: jnp.ndarray, client_sketch_vec: jnp.ndarray,
                cfg: PSAConfig,
                refresh_fn: Optional[Callable] = None, *, gamma=None,
                delta=None, server_lr=None, thermo_on=None):
    """One fused Algorithm-1 server step: receive, and — iff the buffer just
    filled — aggregate and refresh the global sketch, all under ``lax.cond``
    so the whole arrival path compiles to a single device call.

    ``refresh_fn(global_vec) -> (k,)`` recomputes the global model's
    sensitivity sketch after an update (traced into the taken branch only).
    The keyword hyperparameters accept traced scalars (default: the static
    config values). Returns ``(new_state, new_global_vec, PSAInfo)``.
    """
    state = server_receive(state, update_vec, client_sketch_vec)
    L = state.buffer_size

    def do_aggregate(state, global_vec):
        state, new_global, info = server_aggregate(
            state, global_vec, cfg, gamma=gamma, delta=delta,
            server_lr=server_lr, thermo_on=thermo_on)
        if refresh_fn is not None:
            state = state._replace(global_sketch=refresh_fn(new_global))
        return state, new_global, info

    def no_aggregate(state, global_vec):
        info = PSAInfo(updated=jnp.bool_(False),
                       weights=jnp.zeros((L,), jnp.float32),
                       kappas=state.kappas,
                       temp=jnp.float32(0.0), temp_valid=jnp.bool_(False),
                       m_cur=thermometer.current_mean(state.thermo))
        return state, global_vec.astype(jnp.float32), info

    return jax.lax.cond(buffer_full(state), do_aggregate, no_aggregate,
                        state, global_vec)


# ---------------------------------------------------------------------------
# Distance-metric staleness family (generalizing AsyncFedED's Euclidean
# drift; the metric taxonomy of "Revisiting Gradient Staleness")
# ---------------------------------------------------------------------------

DISTANCE_METRICS = ("l2", "cosine", "sketch")

# Traced ``PolicyParams.dist_mode`` codes for the arithmetic variants: l2 and
# cosine differ only in scalar math over the same d-contractions, so the
# metric can be selected by a traced scalar and swept per lane. "sketch"
# adds k extra contractions to the program and is a STRUCTURAL policy key.
DIST_MODE_L2 = 0.0
DIST_MODE_COSINE = 1.0


def distance_staleness_scale(global_vec: jnp.ndarray, wi: jnp.ndarray,
                             dw: jnp.ndarray, *, alpha, eps, dist_mode):
    """AsyncFedED-family mixing coefficient s for  w <- w + s * dw, with the
    staleness metric selected by the traced scalar ``dist_mode``:

    l2 (``dist_mode=0``):  s = alpha * min(1, ||dw|| / (||w_i - w|| + eps))
        — the original AsyncFedED rule, bit-identical arithmetic to the
        pre-family ``asyncfeded`` step (golden streams are pinned to it).
    cosine (``dist_mode=1``):
        s = alpha * 0.5 * (1 + <dw, w_i - w> / (||dw||*||w_i - w|| + eps))
        — direction-only staleness: a client whose drift still points along
        its update gets the full alpha; an orthogonal or opposed drift is
        damped toward 0 regardless of magnitude.

    Every d-contraction goes through ``sharding.param_axis_sum``, so the
    same expression psums per-shard partials under the sharded server's
    shard_map (scalar-psum contract: only (1,)-sized values cross shards).
    """
    drift = wi - global_vec
    dist = jnp.sqrt(sharding.param_axis_sum(jnp.square(drift)))
    norm = jnp.sqrt(sharding.param_axis_sum(jnp.square(dw)))
    s_l2 = jnp.minimum(1.0, norm / (dist + eps))
    dot = sharding.param_axis_sum(dw * drift)
    s_cos = 0.5 * (1.0 + dot / (norm * dist + eps))
    return alpha * jnp.where(dist_mode < 0.5, s_l2, s_cos)


def magnitude_sketch(vec: jnp.ndarray, *, k: int, seed: int) -> jnp.ndarray:
    """(k,) JL magnitude sketch  z = R|vec| / sqrt(k)  with the SAME
    Rademacher hash as the fused sensitivity kernel, so ||z|| estimates
    ||vec||_2 (||R|v|||  ~=  |||v|||_2  =  ||v||_2 by Johnson-Lindenstrauss).

    Single-device: routes through the Pallas ``sens_sketch`` kernel with
    (g=1, F=0), under which the Eq. 8 sensitivity |g*theta - 0.5*F*theta^2|
    degenerates to exactly |vec| — the kernel's streaming one-pass HBM
    profile for free. Under a ``sharding.param_axis`` trace the kernel's
    static ``index_offset`` cannot follow the traced shard index, so the
    rows are hashed in-trace at GLOBAL indices (bit-identical ``pcg_hash``)
    and each row reduces through one scalar psum — k scalars total, keeping
    the sharded step's scalar-psum contract.
    """
    ax = sharding.current_param_axis()
    if ax is None:
        from repro.kernels import ops  # deferred: avoids import cycle at pkg init
        return ops.sens_sketch(vec, jnp.ones_like(vec), jnp.zeros_like(vec),
                               k=k, seed=seed)
    d_local = vec.shape[0]
    off = jax.lax.axis_index(ax).astype(jnp.uint32) * jnp.uint32(d_local)
    lin = off + jnp.arange(d_local, dtype=jnp.uint32)
    s = jnp.abs(vec.astype(jnp.float32))
    rows = [sharding.param_axis_sum(s * sketch.rademacher_row(
        jnp.uint32(seed), lin, r, k)) for r in range(k)]
    return jnp.stack(rows) / jnp.sqrt(jnp.float32(k))


def sketch_distance_scale(global_vec: jnp.ndarray, wi: jnp.ndarray,
                          dw: jnp.ndarray, *, alpha, eps, k: int,
                          seed: int) -> jnp.ndarray:
    """The l2 rule evaluated in k-dim sketch space:

        s = alpha * min(1, ||R|dw||| / (||R|w_i - w||| + eps))

    a JL estimate of the l2 ratio at O(k) cross-shard traffic instead of
    exact norms — the "sketch" member of ``DISTANCE_METRICS``, sharing the
    paper's compressed-staleness machinery with FedPSA."""
    z_dw = magnitude_sketch(dw, k=k, seed=seed)
    z_drift = magnitude_sketch(wi - global_vec, k=k, seed=seed)
    norm = jnp.sqrt(jnp.sum(jnp.square(z_dw)))
    dist = jnp.sqrt(jnp.sum(jnp.square(z_drift)))
    return alpha * jnp.minimum(1.0, norm / (dist + eps))
