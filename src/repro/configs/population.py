"""Population presets: named geometries for population-scale simulation.

A preset bundles the three knobs a population-scale run has to agree on —
the lazy client population (``data.synthetic.SyntheticPopulation``), the
streaming-slab geometry (``SimConfig.shard_size/shard_cache/shard_promote``)
and the dispatch load (a FIXED absolute in-flight count, so cells at
different C run comparable device waves and per-dispatch cost is an
apples-to-apples number). ``benchmarks/population_throughput.py`` iterates
presets; ``pop-smoke`` is the CI cell (tiny C, deliberately fragmented
shards so the chunked path + LRU eviction is exercised, not bypassed).

Memory model (see ARCHITECTURE.md "population / streaming-slab contract"):
resident client data is O(shard_cache * shard_size * n_max) plus O(C)
metadata (sizes, latency means), never the O(C * n_max) monolithic slab.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class PopulationPreset:
    num_clients: int
    # streaming-slab geometry (SimConfig.shard_*)
    shard_size: int = 512
    shard_cache: int = 8
    shard_promote: int = 8
    # absolute number of concurrently-training clients (NOT a fraction:
    # the bench holds this fixed across C so waves stay comparable)
    n_inflight: int = 1024
    # overlap the next wave's shard/row materialization + H2D upload with
    # device compute (SimConfig.prefetch; results bit-identical either way)
    prefetch: bool = False
    # population shape (SyntheticPopulation)
    num_classes: int = 10
    dim: int = 32
    size_mean: int = 64
    size_spread: float = 0.5
    size_lo: int = 16
    size_hi: int = 128

    def population(self, seed: int = 0):
        from repro.data.synthetic import SyntheticPopulation
        return SyntheticPopulation(
            self.num_clients, self.num_classes, self.dim, seed=seed,
            size_mean=self.size_mean, size_spread=self.size_spread,
            size_lo=self.size_lo, size_hi=self.size_hi)

    def sim_kwargs(self) -> dict:
        """The SimConfig fields a preset pins (merge with run-specific
        horizon/eval/engine settings)."""
        return dict(num_clients=self.num_clients,
                    concurrency=self.n_inflight / self.num_clients,
                    shard_size=self.shard_size,
                    shard_cache=self.shard_cache,
                    shard_promote=self.shard_promote,
                    prefetch=self.prefetch)

    @property
    def resident_mb(self) -> float:
        """The contract's data-memory bound for this geometry (float32
        features + int32 labels), independent of num_clients."""
        rows = self.shard_cache * self.shard_size * self.size_hi
        return rows * (self.dim * 4 + 4) / 2**20


POPULATION_PRESETS = {
    # the bench baseline / headline pair (ISSUE 7 acceptance gate)
    "pop-5k": PopulationPreset(5_000),
    "pop-100k": PopulationPreset(100_000),
    # the ROADMAP north star; same resident bound as pop-100k. At C=1M a
    # <=256-member wave spreads over ~977 shards and essentially never
    # crosses the promote threshold, so the row path serves everything —
    # prefetch overlaps those row-block materializations (and any shard
    # loads) with device compute.
    "pop-1m": PopulationPreset(1_000_000, shard_size=1024, shard_cache=4,
                               prefetch=True),
    # CI smoke: tiny C but FORCED multi-shard chunked path (8 shards,
    # 2-resident LRU, promote=1 so shards actually cache and evict)
    "pop-smoke": PopulationPreset(240, shard_size=32, shard_cache=2,
                                  shard_promote=1, n_inflight=48,
                                  size_mean=24, size_lo=8, size_hi=40),
    # CI smoke in the pop-1m shape: prefetch on over a fragmented
    # multi-shard cache (16 shards, 2-resident LRU) whose promote=4
    # threshold both caches shards (eviction-crossing) and leaves a
    # row-path residue, so every prefetch path — shard futures, row
    # blocks, stale-key fallback — runs in tier-1
    "pop-1m-smoke": PopulationPreset(2_000, shard_size=128, shard_cache=2,
                                     shard_promote=4, n_inflight=128,
                                     size_mean=24, size_lo=8, size_hi=40,
                                     prefetch=True),
}


def get_population_preset(name: str) -> PopulationPreset:
    if name not in POPULATION_PRESETS:
        raise KeyError(f"unknown population preset {name!r}; "
                       f"known: {sorted(POPULATION_PRESETS)}")
    return POPULATION_PRESETS[name]
