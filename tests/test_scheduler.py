"""Unit tests for the pluggable dispatch-scheduler layer.

The load-bearing guarantee is backward compatibility: the default
``UniformRefillScheduler`` must consume the MT19937 dispatch stream
bit-for-bit as the pre-refactor inline ``rng.randint`` path did (every
golden digest stream under ``tests/golden/`` is pinned to it). The rest
covers the scheduler contract — ``launch_times >= ts`` wave safety, the
staleness scheduler's weighted selection and its batch == scalar stream
discipline — plus the AULC-NaN and fedavg stream-separation regressions.
"""
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.federated.latency import (STREAM_SYNC_CHOICE, _subseed,
                                     per_client_availability,
                                     per_client_latency)
from repro.federated.scheduler import (SCHEDULERS, PeriodTriggeredScheduler,
                                       StalenessAwareScheduler,
                                       UniformRefillScheduler,
                                       make_scheduler, make_streams)
from repro.federated.simulator import SimConfig, SimResult

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def _bound(sched, num_clients=12, seed=7, **kw):
    sched.bind(num_clients=num_clients, rng=np.random.RandomState(seed), **kw)
    return sched


# ---------------------------------------------------------------------------
# UniformRefill: bit-identical to the pre-refactor inline dispatch path
# ---------------------------------------------------------------------------


def test_uniform_bit_identical_to_inline_path():
    """Replay the historical inline rule — ``rng.randint(C, size=n)`` on the
    bare ``RandomState(tseed)`` — against the scheduler over a mixed batch/
    scalar call pattern: every draw must match exactly."""
    C, tseed = 50, 123
    inline = np.random.RandomState(tseed)
    sched = _bound(UniformRefillScheduler(), num_clients=C, seed=tseed)
    for n in (10, 1, 3, 1, 1, 7):   # initial fill, waves, single re-dispatch
        ts = np.linspace(0.0, 100.0, n)
        expect = inline.randint(C, size=n)
        got = sched.select(sched.launch_times(ts),
                           np.zeros(n, np.int64))
        np.testing.assert_array_equal(got, expect)


def test_uniform_launch_times_identity():
    ts = np.array([0.0, 13.7, 999.2])
    sched = _bound(UniformRefillScheduler())
    np.testing.assert_array_equal(sched.launch_times(ts), ts)


# ---------------------------------------------------------------------------
# Period-triggered: deferred launches on wall-clock ticks
# ---------------------------------------------------------------------------


def test_period_launch_times_on_ticks():
    sched = _bound(PeriodTriggeredScheduler(period=20.0))
    ts = np.array([0.0, 0.1, 19.9, 20.0, 20.1, 55.0])
    got = sched.launch_times(ts)
    np.testing.assert_allclose(got, [0.0, 20.0, 20.0, 20.0, 40.0, 60.0])
    # wave-safety contract: a launch may be deferred, never advanced
    assert np.all(got >= ts)


def test_period_selection_stream_matches_uniform():
    """The period scheduler defers WHEN, not WHO: selection consumes the
    dispatch stream exactly as the uniform rule."""
    u = _bound(UniformRefillScheduler(), seed=3)
    p = _bound(PeriodTriggeredScheduler(period=5.0), seed=3)
    ts = np.array([1.0, 2.0, 3.0])
    np.testing.assert_array_equal(
        u.select(ts, np.zeros(3, np.int64)),
        p.select(p.launch_times(ts), np.zeros(3, np.int64)))


def test_period_rejects_nonpositive():
    with pytest.raises(ValueError, match="period"):
        PeriodTriggeredScheduler(period=0.0)


# ---------------------------------------------------------------------------
# Staleness-aware: utility/lag-weighted selection
# ---------------------------------------------------------------------------


def test_staleness_prefers_most_lagged_client():
    sched = _bound(StalenessAwareScheduler(staleness_weight=8.0),
                   num_clients=4)
    # client 2 was never dispatched at a high server version: enormous lag
    sched.last_version[:] = [100.0, 100.0, 0.0, 100.0]
    picks = set()
    for _ in range(8):
        c = int(sched.select(np.array([0.0]), np.array([100]))[0])
        picks.add(c)
        sched.last_version[:] = [100.0, 100.0, 0.0, 100.0]  # re-arm
    assert picks == {2}, picks


def test_staleness_select_updates_lag_table():
    sched = _bound(StalenessAwareScheduler(), num_clients=4)
    c = int(sched.select(np.array([0.0]), np.array([17]))[0])
    assert sched.last_version[c] == 17.0


def test_staleness_batch_equals_scalar_stream():
    """One batched select must consume the RNG exactly as scalar selects —
    the cohort drain and the sequential oracle stay stream-identical."""
    a = _bound(StalenessAwareScheduler(), num_clients=9, seed=11)
    b = _bound(StalenessAwareScheduler(), num_clients=9, seed=11)
    ts = np.arange(5.0)
    versions = np.array([3, 3, 4, 5, 5])
    batched = a.select(ts, versions)
    scalar = [int(b.select(ts[i:i + 1], versions[i:i + 1])[0])
              for i in range(5)]
    np.testing.assert_array_equal(batched, scalar)


def test_staleness_exact_flag_replays_historical_loop():
    """``scheduler_params={"exact": True}`` is the pre-PR-10 oracle: the
    full C-length weight recompute + ``rng.choice(p=...)`` per slot. Replay
    that rule on a twin RNG and require bit-identical draws."""
    C, seed, sw = 12, 5, 2.0
    sched = _bound(StalenessAwareScheduler(staleness_weight=sw, exact=True),
                   num_clients=C, seed=seed)
    twin = np.random.RandomState(seed)
    last = np.zeros(C)
    versions = np.array([1, 3, 3, 8, 9, 15], np.int64)
    got = sched.select(np.arange(6.0), versions)
    for i, v in enumerate(versions):
        w = np.power(1.0 + np.maximum(v - last, 0.0), sw)
        c = int(twin.choice(C, p=w / w.sum()))
        last[c] = v
        assert int(got[i]) == c


def _staleness_pmf(sched, v):
    lag = np.maximum(v - sched.last_version, 0.0)
    w = sched._base * np.power(1.0 + lag, sched.staleness_weight)
    return w / w.sum()


@pytest.mark.parametrize("sw,lags", [
    (1.0, "none"), (2.0, "mixed"), (0.5, "one_hot"), (3.0, "mixed"),
])
def test_staleness_fast_sampler_matches_exact_distribution(sw, lags):
    """The rejection sampler draws from EXACTLY the oracle's distribution.
    Freeze a lag table, take many single draws (re-arming the table after
    each so they are i.i.d.), and chi-square the empirical counts against
    the analytic pmf the exact loop normalizes."""
    from scipy import stats

    C, N = 8, 4000
    table = {"none": np.zeros(C),
             "mixed": np.array([0., 5., 1., 9., 0., 3., 7., 2.]),
             "one_hot": np.array([4.] * 7 + [0.])}[lags]
    sched = _bound(StalenessAwareScheduler(staleness_weight=sw),
                   num_clients=C, seed=int(sw * 10))
    v = 10.0
    sched.last_version[:] = v - table          # lag == table at version v
    pmf = _staleness_pmf(sched, v)
    counts = np.zeros(C)
    for _ in range(N):
        c = int(sched.select(np.array([0.0]), np.array([v]))[0])
        counts[c] += 1
        sched.last_version[:] = v - table      # re-arm: draws stay i.i.d.
        sched._lv_floor = 0.0
    assert stats.chisquare(counts, pmf * N).pvalue > 1e-3, (counts, pmf * N)
    # the sampler really took the sublinear path: rejection proposals, with
    # the exact O(C) fallback never (or almost never) engaged
    st = sched.sample_stats
    assert st["draws"] == N
    assert st["exact_fallbacks"] <= N // 100


def test_staleness_fast_sampler_trajectory_stats():
    """On a realistic sequential trajectory (versions advancing, lag table
    self-mutating) the fast path stays cheap: bounded proposals per draw
    and no drift into the exact fallback."""
    C = 512
    sched = _bound(StalenessAwareScheduler(staleness_weight=1.5),
                   num_clients=C, seed=0)
    v = 0.0
    for i in range(400):
        v += 1.0
        sched.select(np.array([float(i)]), np.array([v]))
    st = sched.sample_stats
    assert st["draws"] == 400
    assert st["proposals"] / st["draws"] < 8.0, st
    assert st["exact_fallbacks"] == 0, st


@pytest.mark.slow
def test_staleness_population_scale_per_draw_budget():
    """C=100k staleness-aware selection must be usable on the streaming
    path: the fast sampler's per-draw cost stays within a hard budget and
    beats the exact O(C) oracle by a wide margin."""
    import time

    C, warm, timed = 100_000, 16, 256
    fast = _bound(StalenessAwareScheduler(), num_clients=C, seed=1)
    v = 0.0
    for i in range(warm):
        v += 1.0
        fast.select(np.array([float(i)]), np.array([v]))
    t0 = time.perf_counter()
    for i in range(timed):
        v += 1.0
        fast.select(np.array([float(i)]), np.array([v]))
    per_draw_fast = (time.perf_counter() - t0) / timed

    exact = _bound(StalenessAwareScheduler(exact=True), num_clients=C,
                   seed=1)
    t0 = time.perf_counter()
    for i in range(8):
        exact.select(np.array([float(i)]), np.array([float(i + 1)]))
    per_draw_exact = (time.perf_counter() - t0) / 8

    assert per_draw_fast < 200e-6, per_draw_fast     # < 200 us/draw
    assert per_draw_exact / per_draw_fast > 10.0, (per_draw_exact,
                                                   per_draw_fast)


def test_staleness_uses_size_and_availability_state():
    """size/avail weights shape the base preference: with no lag signal the
    larger, more-available client dominates."""
    sizes = np.array([1.0, 400.0])
    avail = np.array([0.05, 0.95])
    sched = StalenessAwareScheduler(size_weight=3.0, avail_weight=3.0)
    sched.bind(num_clients=2, rng=np.random.RandomState(0),
               data_sizes=sizes, avail_probs=avail)
    draws = sched.select(np.zeros(50), np.zeros(50, np.int64))
    assert np.mean(draws == 1) > 0.9


# ---------------------------------------------------------------------------
# Factory + SimConfig plumbing
# ---------------------------------------------------------------------------


def test_make_scheduler_names_and_params():
    assert set(SCHEDULERS) == {"uniform", "period", "staleness"}
    sim = SimConfig(num_clients=10, scheduler="period",
                    scheduler_params={"period": 7.0})
    sched = make_scheduler(sim)
    assert isinstance(sched, PeriodTriggeredScheduler)
    assert sched.period == 7.0
    # default period scales with the latency floor
    sched = make_scheduler(SimConfig(num_clients=10, scheduler="period",
                                     latency_lo=10.0))
    assert sched.period == 20.0
    with pytest.raises(ValueError, match="unknown scheduler"):
        make_scheduler(SimConfig(num_clients=10, scheduler="nope"))


def test_stateless_flags():
    assert UniformRefillScheduler.stateless
    assert PeriodTriggeredScheduler.stateless
    assert not StalenessAwareScheduler.stateless


def test_make_streams_matches_historical_layout():
    """``make_streams`` must reproduce the exact RNG objects the entry
    points used to build inline: dispatch = bare RandomState(tseed),
    latency/availability on their own sub-streams."""
    sim = SimConfig(num_clients=20, seed=5, latency_kind="uniform",
                    availability_kind="hetero", dropout_rate=0.3)
    st = make_streams(sim)
    assert st.tseed == 5
    np.testing.assert_array_equal(st.rng.rand(4),
                                  np.random.RandomState(5).rand(4))
    lat, means = per_client_latency("uniform", sim.latency_lo,
                                    sim.latency_hi, 20, 5)
    np.testing.assert_array_equal(st.lat_means, means)
    np.testing.assert_array_equal(
        st.avail, per_client_availability("hetero", 0.3, 20, 5,
                                          latency_means=means))
    assert st.use_avail and not st.use_trace and st.trace is None
    # timeline_seed splits the event timeline off the model seed
    st2 = make_streams(SimConfig(num_clients=20, seed=5, timeline_seed=99))
    assert st2.tseed == 99


# ---------------------------------------------------------------------------
# Regressions: AULC NaN + fedavg round-sampling stream separation
# ---------------------------------------------------------------------------


def test_aulc_nan_with_fewer_than_two_points():
    """A run recording < 2 eval points has no area to integrate: AULC must
    be NaN, never a silent 0.0 that poisons comparison tables."""
    assert np.isnan(SimResult().aulc)
    assert np.isnan(SimResult(times=[100.0], accuracies=[0.5]).aulc)
    assert np.isnan(SimResult(times=[5.0, 5.0], accuracies=[0.5, 0.6]).aulc)
    ok = SimResult(times=[0.0, 10.0], accuracies=[0.0, 1.0])
    assert ok.aulc == pytest.approx(0.5)


def test_bench_writers_surface_nan_aulc():
    from benchmarks import common as bench_common
    assert bench_common.aulc_json(float("nan")) is None
    assert bench_common.aulc_json(0.37) == pytest.approx(0.37)


def _paper_world(num_clients=4):
    import jax
    from repro.configs import get_config
    from repro.data import (ClientDataset, iid_partition,
                            make_classification, train_test_split)
    from repro.models import model as M

    cfg = get_config("paper-synthetic-mlp")
    full = make_classification(200, 10, 32, seed=0)
    train, test = train_test_split(full, 0.2)
    clients = [ClientDataset(train.subset(ix))
               for ix in iid_partition(train, num_clients, 0)]
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, clients, test, params


def test_checkpoint_rejects_stateful_scheduler_without_roundtrip(
        tmp_path, monkeypatch):
    """A stateful scheduler that does NOT implement the state_arrays
    round-trip (checkpoint_state=False) must be refused up front rather
    than resumed wrongly with a reset lag table."""
    from repro.federated import run_algorithm
    from repro.federated import simulator as sim_mod

    class Opaque(StalenessAwareScheduler):
        name = "opaque"
        checkpoint_state = False

    cfg, clients, test, params = _paper_world()
    orig = sim_mod.make_scheduler
    monkeypatch.setattr(
        sim_mod, "make_scheduler",
        lambda sim: Opaque() if sim.scheduler == "opaque" else orig(sim))
    sim = SimConfig(num_clients=4, horizon=100.0, scheduler="opaque",
                    checkpoint_dir=str(tmp_path), engine="sequential")
    with pytest.raises(ValueError, match="state_arrays"):
        run_algorithm("fedasync", cfg, params, clients, test, sim)


@pytest.mark.parametrize("exact", [False, True])
def test_staleness_checkpoint_resume_roundtrip(tmp_path, exact):
    """The staleness scheduler's lag table (+ envelope floor) round-trips
    through simulator checkpoints: a run resumed mid-stream from a pruned
    snapshot reproduces the uninterrupted digest stream exactly, under
    both the fast sampler and the exact oracle."""
    import os
    import shutil

    from repro.federated import run_algorithm

    cfg, clients, test, params = _paper_world()
    kw = dict(num_clients=4, horizon=2_000.0, eval_every=1_000.0, seed=0,
              scheduler="staleness",
              scheduler_params={"staleness_weight": 2.0, "exact": exact},
              record_trajectory=True, engine="sequential")
    base = run_algorithm("fedasync", cfg, params, clients, test,
                         SimConfig(**kw))
    ckdir = str(tmp_path / "ck")
    ck = run_algorithm("fedasync", cfg, params, clients, test,
                       SimConfig(checkpoint_dir=ckdir, checkpoint_every=500.0,
                                 **kw))
    np.testing.assert_array_equal(np.asarray(ck.digests),
                                  np.asarray(base.digests))
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckdir))
    mid = [s for s in steps if 0 < s < base.dispatches]
    assert mid, steps
    for s in steps:
        if s > mid[-1]:
            shutil.rmtree(os.path.join(ckdir, f"step_{s:08d}"))
    res = run_algorithm("fedasync", cfg, params, clients, test,
                        SimConfig(checkpoint_dir=ckdir,
                                  checkpoint_every=500.0, resume=True, **kw))
    np.testing.assert_array_equal(np.asarray(res.digests),
                                  np.asarray(base.digests))
    assert res.dispatches == base.dispatches


def test_fedavg_round_sampling_has_own_stream():
    """The synchronous fedavg round choice must come from STREAM_SYNC_CHOICE,
    not the bare dispatch stream the async schedulers own: at equal base
    seeds the two streams must differ (the old behavior replayed the async
    cid draws as round cohorts)."""
    for seed in (0, 1, 42, 12345):
        sub = _subseed(seed, STREAM_SYNC_CHOICE)
        assert sub != seed
        dispatch = np.random.RandomState(seed).choice(50, size=10,
                                                      replace=False)
        sync = np.random.RandomState(sub).choice(50, size=10, replace=False)
        assert not np.array_equal(dispatch, sync), seed
