"""Event-driven virtual-time AFL simulator (FLGO-style: 86,400 units/day).

Asynchronous runners keep ``concurrency`` clients training at all times: a
heap of completion events; on completion the server ingests the update, a
new client is sampled and dispatched with the *current* global model, and
the learning curve is sampled on a fixed virtual-time grid. The synchronous
FedAvg runner advances rounds at the pace of each round's slowest client —
exactly the straggler behaviour the paper contrasts against.

The paper's defaults (§6.1): 50 clients, 20% concurrency/sampling, 5 local
epochs, batch 64, SGD lr 0.01 with x0.999 decay per (dispatch) round,
latency ~ U(10, 500).
"""
from __future__ import annotations

import dataclasses
import heapq
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import tree as tu
from repro.core import psa as psa_lib
from repro.data.loader import ClientDataset
from repro.federated import client as client_lib
from repro.federated import servers as servers_lib
from repro.federated.latency import per_client_latency
from repro.models import model as model_lib
from repro.models.config import ModelConfig


@dataclass
class SimConfig:
    num_clients: int = 50
    concurrency: float = 0.2          # fraction of clients training at once
    local_epochs: int = 5
    batch_size: int = 64
    lr: float = 0.01
    lr_decay: float = 0.999
    horizon: float = 86_400.0         # virtual time units (1 day default)
    eval_every: float = 2_000.0
    latency_kind: str = "uniform"
    latency_lo: float = 10.0
    latency_hi: float = 500.0
    seed: int = 0
    eval_batches: int = 8
    eval_batch_size: int = 512


@dataclass
class SimResult:
    times: List[float] = field(default_factory=list)
    accuracies: List[float] = field(default_factory=list)
    final_accuracy: float = 0.0
    versions: int = 0
    dispatches: int = 0
    server_log: List[dict] = field(default_factory=list)
    receive_log: List[dict] = field(default_factory=list)

    @property
    def aulc(self) -> float:
        """Area under the learning curve, normalized by the horizon so the
        unit matches the paper's Table 3 (accuracy-days)."""
        if len(self.times) < 2:
            return 0.0
        t = np.asarray(self.times)
        a = np.asarray(self.accuracies)
        return float(np.trapezoid(a, t) / 86_400.0)


def _make_eval(cfg: ModelConfig, test_ds, sim: SimConfig):
    rng = np.random.RandomState(1234)
    n = len(test_ds)
    bs = min(sim.eval_batch_size, n)
    idxs = [rng.choice(n, size=bs, replace=False) for _ in range(sim.eval_batches)]
    batches = [{"x": jnp.asarray(test_ds.x[ix]), "y": jnp.asarray(test_ds.y[ix])}
               for ix in idxs]

    @jax.jit
    def acc1(params, x, y):
        return jnp.mean((model_lib.predict(params, x, cfg) == y).astype(jnp.float32))

    def evaluate(params) -> float:
        return float(np.mean([float(acc1(params, b["x"], b["y"])) for b in batches]))

    return evaluate


def make_sketch_fn(cfg: ModelConfig, calib_batch: dict, psa_cfg: psa_lib.PSAConfig):
    calib = {k: jnp.asarray(v) for k, v in calib_batch.items()}
    from repro.common.sharding import SINGLE_DEVICE_RULES as R

    def loss(params, batch):
        return model_lib.loss_fn(params, batch, cfg, R)

    @jax.jit
    def fn(params):
        return psa_lib.client_sketch(loss, params, calib, psa_cfg)

    return fn


def run_async(server_name: str, cfg: ModelConfig, init_params,
              client_datasets: List[ClientDataset], test_ds,
              sim: SimConfig, *, psa_cfg: Optional[psa_lib.PSAConfig] = None,
              calib_batch: Optional[dict] = None,
              server_kwargs: Optional[dict] = None,
              receive_hook: Optional[Callable] = None) -> SimResult:
    """Run one asynchronous algorithm to the virtual-time horizon."""
    rng = np.random.RandomState(sim.seed)
    latency, _ = per_client_latency(sim.latency_kind, sim.latency_lo,
                                    sim.latency_hi, sim.num_clients, sim.seed)
    sketch_fn = None
    if server_name == "fedpsa":
        psa_cfg = psa_cfg or psa_lib.PSAConfig()
        assert calib_batch is not None
        sketch_fn = make_sketch_fn(cfg, calib_batch, psa_cfg)
    server = servers_lib.make_server(
        server_name, init_params, num_clients=sim.num_clients,
        psa_cfg=psa_cfg, sketch_fn=sketch_fn, **(server_kwargs or {}))
    align = getattr(server, "client_align", 0.0)

    evaluate = _make_eval(cfg, test_ds, sim)
    result = SimResult()
    concurrency = max(1, int(round(sim.concurrency * sim.num_clients)))
    # (t_done, seq, cid, snapshot, version_at_dispatch)
    heap: List[Tuple[float, int, int, object, int]] = []
    seq = 0
    data_sizes = np.array([len(d) for d in client_datasets], np.float64)

    def dispatch(t: float):
        nonlocal seq
        cid = int(rng.randint(sim.num_clients))
        t_done = t + latency(cid)
        heapq.heappush(heap, (t_done, seq, cid, server.params, server.version))
        seq += 1

    for _ in range(concurrency):
        dispatch(0.0)

    next_eval = 0.0
    t = 0.0
    while heap and t < sim.horizon:
        t, _, cid, snapshot, v_dispatch = heapq.heappop(heap)
        if t > sim.horizon:
            break
        while next_eval <= t:
            acc = evaluate(server.params)
            result.times.append(next_eval)
            result.accuracies.append(acc)
            next_eval += sim.eval_every
        lr = sim.lr * (sim.lr_decay ** result.dispatches)
        delta, w_client = client_lib.local_update(
            snapshot, cfg, client_datasets[cid],
            epochs=sim.local_epochs, batch_size=sim.batch_size, lr=lr,
            seed=sim.seed * 100003 + result.dispatches, align=align)
        meta = {
            "tau": server.version - v_dispatch,
            "client_id": cid,
            "data_size": float(data_sizes[cid]),
        }
        if server.needs_sketch:
            meta["sketch"] = sketch_fn(w_client)
        if receive_hook is not None:
            receive_hook(server, w_client, delta, meta, t)
        server.receive(delta, w_client, meta)
        result.dispatches += 1
        result.receive_log.append({"t": t, "tau": meta["tau"], "client": cid})
        dispatch(t)

    result.final_accuracy = evaluate(server.params)
    result.times.append(min(t, sim.horizon))
    result.accuracies.append(result.final_accuracy)
    result.versions = server.version
    result.server_log = server.log
    return result


def run_fedavg(cfg: ModelConfig, init_params, client_datasets: List[ClientDataset],
               test_ds, sim: SimConfig, *, prox: float = 0.0) -> SimResult:
    """Synchronous FedAvg: per round sample 20% of clients, wait for the
    slowest, aggregate weighted by client data size."""
    rng = np.random.RandomState(sim.seed)
    latency, _ = per_client_latency(sim.latency_kind, sim.latency_lo,
                                    sim.latency_hi, sim.num_clients, sim.seed)
    evaluate = _make_eval(cfg, test_ds, sim)
    result = SimResult()
    params = init_params
    m = max(1, int(round(sim.concurrency * sim.num_clients)))
    t = 0.0
    next_eval = 0.0
    rnd = 0
    while t < sim.horizon:
        while next_eval <= t:
            acc = evaluate(params)
            result.times.append(next_eval)
            result.accuracies.append(acc)
            next_eval += sim.eval_every
        chosen = rng.choice(sim.num_clients, size=m, replace=False)
        round_time = max(latency(int(c)) for c in chosen)
        lr = sim.lr * (sim.lr_decay ** rnd)
        deltas, sizes = [], []
        for c in chosen:
            d, _ = client_lib.local_update(
                params, cfg, client_datasets[int(c)],
                epochs=sim.local_epochs, batch_size=sim.batch_size, lr=lr,
                seed=sim.seed * 100003 + rnd * 51 + int(c), prox=prox)
            deltas.append(d)
            sizes.append(len(client_datasets[int(c)]))
        w = jnp.asarray(np.asarray(sizes, np.float32) / np.sum(sizes))
        params = tu.tree_add(params, tu.tree_weighted_sum(deltas, w))
        t += round_time
        rnd += 1
        result.dispatches += m
    result.final_accuracy = evaluate(params)
    result.times.append(min(t, sim.horizon))
    result.accuracies.append(result.final_accuracy)
    result.versions = rnd
    return result


ALGORITHMS = ("fedavg", "fedasync", "fedbuff", "fedpsa", "ca2fl", "fedfa",
              "fedpac", "asyncfeded")


def run_algorithm(name: str, cfg: ModelConfig, init_params, client_datasets,
                  test_ds, sim: SimConfig, **kw) -> SimResult:
    if name == "fedavg":
        kw.pop("psa_cfg", None)
        kw.pop("calib_batch", None)
        return run_fedavg(cfg, init_params, client_datasets, test_ds, sim, **kw)
    return run_async(name, cfg, init_params, client_datasets, test_ds, sim, **kw)
