"""SGD and SGD+momentum (the paper's client optimizer is plain SGD)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        updates = jax.tree_util.tree_map(lambda g: -lr * g.astype(jnp.float32), grads)
        return updates, state

    return Optimizer(init, update)


def sgd_momentum(beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params, lr):
        state = jax.tree_util.tree_map(
            lambda m, g: beta * m + g.astype(jnp.float32), state, grads)
        if nesterov:
            upd = jax.tree_util.tree_map(
                lambda m, g: -(lr * (beta * m + g.astype(jnp.float32))), state, grads)
        else:
            upd = jax.tree_util.tree_map(lambda m: -lr * m, state)
        return upd, state

    return Optimizer(init, update)
