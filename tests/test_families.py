"""Model-family registry + federated LM scenario.

Fast tier: registry contract (membership, fallback warning, engine
recording) and the document-level token partition. Slow tier (the
LM-scenario marker CI runs in its own matrix entry): cohort-vs-sequential
parity on the non-paper families — the dense/ssm/moe fed-lm smokes must
train under ``engine="cohort"`` end to end with trajectories pinned to the
sequential oracle within 1e-5.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import tree as tu
from repro.configs import get_config
from repro.data import StackedClients, document_partition
from repro.federated import SimConfig, run_algorithm
from repro.federated import client as client_lib
from repro.federated import simulator as sim_mod
from repro.federated.cohort import CohortEngine, bucket_size
from repro.launch.train import build_task
from repro.models import model as M
from repro.models import registry

LM_ARCHS = ("fed-lm-smoke", "fed-lm-ssm-smoke", "fed-lm-moe-smoke")


# ---------------------------------------------------------------------------
# Registry contract (fast tier)
# ---------------------------------------------------------------------------


def test_registry_membership():
    assert registry.is_registered("cnn") and registry.is_registered("mlp")
    for fam in ("dense", "ssm", "moe", "hybrid"):
        assert registry.is_registered(fam), fam
    assert not registry.is_registered("audio")
    assert not registry.is_registered("vlm")
    with pytest.raises(KeyError, match="not in the model-family registry"):
        registry.get_family("audio")


def test_registry_entry_shapes():
    for arch in LM_ARCHS:
        cfg = get_config(arch)
        fam = registry.get_family(cfg)
        assert fam.data_kind == "tokens"
        assert fam.name == cfg.family
    assert registry.get_family(get_config("paper-synthetic-mlp")).data_kind \
        == "image"


def test_register_family_rejects_duplicates():
    entry = registry.get_family("dense")
    with pytest.raises(ValueError, match="already registered"):
        registry.register_family(entry)
    # override=True replaces (and restores) without complaint
    registry.register_family(entry, override=True)


def test_token_masked_batch_is_noop_when_unmasked():
    fam = registry.get_family("dense")
    xb = jnp.arange(12, dtype=jnp.int32).reshape(3, 4)
    yb = xb + 1
    batch = fam.masked_batch(xb, yb, jnp.ones(3, jnp.float32), 3.0)
    np.testing.assert_array_equal(np.asarray(batch["labels"]), np.asarray(yb))
    masked = fam.masked_batch(xb, yb, jnp.asarray([1.0, 0.0, 1.0]), 2.0)
    assert np.all(np.asarray(masked["labels"])[1] == -1)
    np.testing.assert_array_equal(np.asarray(masked["labels"])[0],
                                  np.asarray(yb)[0])


def test_resolve_engine_consults_registry():
    sim = SimConfig(engine="cohort")
    assert sim_mod._resolve_engine(sim, get_config("paper-synthetic-mlp")) \
        == "cohort"
    assert sim_mod._resolve_engine(sim, get_config("fed-lm-smoke")) == "cohort"
    audio = get_config("hubert-xlarge").reduced()
    sim_mod._FALLBACK_WARNED.discard(audio.family)
    with pytest.warns(RuntimeWarning, match="'audio'.*sequential"):
        assert sim_mod._resolve_engine(sim, audio) == "sequential"
    # one-time: the second resolve for the same family stays silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert sim_mod._resolve_engine(sim, audio) == "sequential"
    sim_mod._FALLBACK_WARNED.discard(audio.family)


def test_bucket_size_grid():
    # token families: coarse {pow2, 1.5*pow2} grid (compile cost is seconds
    # per program), image families: the legacy fine multiples-of-4 grid
    assert [bucket_size(b) for b in (1, 4, 5, 6, 7, 9, 13, 17, 25, 33)] == \
        [4, 4, 6, 6, 8, 12, 16, 24, 32, 48]
    assert [bucket_size(b, "image") for b in (1, 4, 5, 9, 31)] == \
        [4, 4, 8, 12, 32]
    for b in range(1, 300):
        for kind in ("tokens", "image"):
            assert b <= bucket_size(b, kind) <= max(4, (3 * b + 1) // 2)


# ---------------------------------------------------------------------------
# Document-level token partition (fast tier)
# ---------------------------------------------------------------------------


def test_document_partition_covers_and_windows():
    seq, doc = 8, 32
    corpus = np.arange(40 * doc, dtype=np.int32)
    parts = document_partition(corpus, 5, seq, doc_len=doc, seed=0)
    assert len(parts) == 5
    rows = np.concatenate(parts)
    assert rows.shape == (40 * doc // seq, seq)
    # windows never straddle documents: every row is a consecutive run
    # starting at a multiple of seq (corpus == arange makes this checkable)
    assert np.all(rows[:, 1:] - rows[:, :-1] == 1)
    assert np.all(rows[:, 0] % seq == 0)
    # whole documents per client: each client's row count is a multiple of
    # windows-per-document
    for p in parts:
        assert p.shape[0] % (doc // seq) == 0 and p.shape[0] > 0


def test_document_partition_alpha_skews_sizes():
    corpus = np.arange(4000, dtype=np.int32)
    flat = document_partition(corpus, 4, 8, alpha=0.0, seed=0)
    skew = document_partition(corpus, 4, 8, alpha=0.1, seed=0)
    sizes_flat = [len(p) for p in flat]
    sizes_skew = [len(p) for p in skew]
    assert sum(sizes_flat) == sum(sizes_skew)
    assert max(sizes_flat) - min(sizes_flat) <= 4      # near-uniform
    assert np.std(sizes_skew) > np.std(sizes_flat)     # Dirichlet skew
    assert min(sizes_skew) >= 1


def test_token_stacked_clients_slab():
    cfg, clients, test, calib = build_task("fed-lm-smoke", 120, 0.5, 4, 0,
                                           seq_len=8)
    stacked = StackedClients.from_datasets(clients)
    assert stacked.kind == "tokens"
    assert stacked.x.dtype == np.int32 and stacked.x.ndim == 3
    assert stacked.y.shape == stacked.x.shape
    for c, d in enumerate(clients):
        n = stacked.sizes[c]
        np.testing.assert_array_equal(stacked.x[c, :n], d.data.x)
        assert not stacked.mask[c, n:].any()
    # token batches speak the loss_fn convention
    batch = next(iter(clients[0].epochs(1, 4, seed=0)))
    assert set(batch) == {"tokens", "labels"}
    assert set(calib) == {"tokens", "labels"}


# ---------------------------------------------------------------------------
# Cohort-vs-sequential parity on non-paper families (slow / LM tier)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_cohort_engine_parity_lm(arch):
    """The compiled vmap x scan engine reproduces client.local_update for
    dense, ssm, and moe smoke configs (ragged shards included)."""
    cfg, clients, _, _ = build_task(arch, 120, 0.5, 5, 0, seq_len=16)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    spec = tu.FlatSpec(params)
    eng = CohortEngine(cfg, StackedClients.from_datasets(clients), spec,
                       params, local_epochs=2, batch_size=8)
    flat = jnp.array(spec.flatten(params), copy=True)
    cids, lrs, seeds = [0, 2, 4], [0.01, 0.02, 0.01], [7, 8, 9]
    deltas, w = eng.cohort_update(jnp.stack([flat] * 3), cids, lrs, seeds)
    for i, (c, lr, s) in enumerate(zip(cids, lrs, seeds)):
        ref, w_ref = client_lib.local_update(params, cfg, clients[c],
                                             epochs=2, batch_size=8,
                                             lr=lr, seed=s)
        assert float(jnp.max(jnp.abs(deltas[i] - spec.flatten(ref)))) <= 1e-5
        assert float(jnp.max(jnp.abs(w[i] - spec.flatten(w_ref)))) <= 1e-5


LM_QUICK = dict(num_clients=8, horizon=3_000.0, eval_every=1_500.0, seed=0,
                local_epochs=2, batch_size=8, record_trajectory=True)


@pytest.mark.slow
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_drain_matches_sequential(arch):
    """Full async sim on each non-paper family: the cohort engine runs end
    to end (no silent fallback) and pins the sequential oracle's receive
    order and digest trajectory within 1e-5."""
    cfg, clients, test, _ = build_task(arch, 240, 0.3, 8, 0, seq_len=8)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    seq = run_algorithm("fedasync", cfg, params, clients, test,
                        SimConfig(engine="sequential", **LM_QUICK))
    coh = run_algorithm("fedasync", cfg, params, clients, test,
                        SimConfig(engine="cohort", **LM_QUICK))
    assert seq.engine == "sequential" and coh.engine == "cohort"
    assert coh.cohorts > 0 and coh.dispatches > 0
    assert [(e["t"], e["client"], e["tau"]) for e in seq.receive_log] == \
        [(e["t"], e["client"], e["tau"]) for e in coh.receive_log]
    assert seq.versions == coh.versions
    np.testing.assert_allclose(np.asarray(coh.digests),
                               np.asarray(seq.digests),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(coh.final_accuracy, seq.final_accuracy,
                               atol=1e-4)


@pytest.mark.slow
def test_lm_fedavg_and_prox_variants():
    """Synchronous FedAvg + FedProx run the token path too (the cohort
    engine's prox pull is family-agnostic flat-vector arithmetic)."""
    cfg, clients, test, _ = build_task("fed-lm-smoke", 160, 0.0, 6, 0,
                                       seq_len=8)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    quick = dict(num_clients=6, horizon=2_000.0, eval_every=1_000.0, seed=0,
                 local_epochs=2, batch_size=8)
    seq = run_algorithm("fedavg", cfg, params, clients, test,
                        SimConfig(engine="sequential", **quick), prox=0.1)
    coh = run_algorithm("fedavg", cfg, params, clients, test,
                        SimConfig(engine="cohort", **quick), prox=0.1)
    assert seq.versions == coh.versions and seq.dispatches == coh.dispatches
    np.testing.assert_allclose(coh.final_accuracy, seq.final_accuracy,
                               atol=1e-4)


@pytest.mark.slow
def test_lm_sim_records_engine_and_lognormal_latency():
    """SimConfig plumbing on the LM scenario: lognormal latency runs end to
    end and the result records the engine actually used."""
    cfg, clients, test, _ = build_task("fed-lm-smoke", 160, 0.3, 6, 0,
                                       seq_len=8)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    sim = SimConfig(num_clients=6, horizon=2_000.0, eval_every=1_000.0,
                    seed=0, local_epochs=2, batch_size=8,
                    latency_kind="lognormal")
    r = run_algorithm("fedbuff", cfg, params, clients, test, sim)
    assert r.engine == "cohort"
    assert r.dispatches > 0 and np.isfinite(r.final_accuracy)
