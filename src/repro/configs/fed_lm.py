"""Federated LM fine-tuning scenario configs (the AsyncFedED regime).

Related work evaluates staleness policies where update cost and parameter
count are large — federated language-model fine-tuning. These are the
CPU-trainable smoke instances of that scenario: one tiny config per
non-paper model family so the cohort engine's registry dispatch, the token
slab, and the policy servers are exercised end to end on dense / ssm / moe
backbones (``launch.train --arch fed-lm-smoke`` etc., golden-pinned in
``tests/golden/fed-lm-smoke.json``). All run in float32 with lossless MoE
capacity so the cohort engine's parity with the sequential oracle is exact
to float tolerance.
"""
from repro.models.config import ModelConfig


def _lm(name: str, family: str, **kw):
    # Deliberately tiny: the simulator's regime is many small clients where
    # per-dispatch overhead (not device math) bounds throughput — that is
    # the regime the cohort engine exists for, and the one the family
    # throughput gate (benchmarks/sim_throughput.py --family) measures.
    defaults = dict(
        num_layers=2, d_model=16, num_heads=2, num_kv_heads=2, d_ff=32,
        vocab_size=32, block_pattern=("attn",), ffn_pattern=("dense",),
        dtype="float32", param_dtype="float32", remat="none",
        q_chunk=64, kv_chunk=64, pad_vocab_to=32,
    )
    defaults.update(kw)
    return ModelConfig(name=name, family=family, **defaults)


CONFIGS = {
    # dense transformer — the headline federated LM scenario
    "fed-lm-smoke": _lm("fed-lm-smoke", "dense"),
    # state-space backbone (mamba mixer)
    "fed-lm-ssm-smoke": _lm("fed-lm-ssm-smoke", "ssm",
                            block_pattern=("mamba",), ssm_state_dim=8),
    # mixture-of-experts FFN. Two knobs keep the MoE objective row-decoupled
    # so the cohort engine's masked padding rows are exact no-ops:
    # capacity_factor >= E/top_k (no token drops => each token's output
    # depends only on its own routing) and router_aux_coef = 0 (the Switch
    # load-balance term sums over ALL batch tokens, so padded rows would
    # perturb ragged-batch gradients at well above float tolerance).
    "fed-lm-moe-smoke": _lm("fed-lm-moe-smoke", "moe",
                            ffn_pattern=("moe",), d_ff=0,
                            num_experts=4, top_k=2, moe_d_ff=16,
                            capacity_factor=2.0, router_aux_coef=0.0),
}
