"""Benchmark orchestrator — one entry per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run            # fast mode
    BENCH_FULL=1 PYTHONPATH=src python -m benchmarks.run   # paper horizons

Prints `table,key,value` CSV lines; JSON payloads land in artifacts/bench/.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (f2_motivation, f4_hyperparams, f5_overhead,
                            f6_kappa_alignment, kernel_micro, roofline,
                            sweep_throughput, t1_t2_accuracy, t3_aulc,
                            t4_latency, t5_calibration, t6_ablation)
    stages = [
        ("roofline", roofline.main),
        ("kernel_micro", kernel_micro.main),
        ("f5_overhead", f5_overhead.main),
        ("t1_t2_accuracy", t1_t2_accuracy.main),
        ("t3_aulc", t3_aulc.main),
        ("t6_ablation", t6_ablation.main),
        ("t5_calibration", t5_calibration.main),
        ("t4_latency", t4_latency.main),
        ("f6_kappa_alignment", f6_kappa_alignment.main),
        ("f2_motivation", f2_motivation.main),
        ("f4_hyperparams", f4_hyperparams.main),
        ("sweep_throughput", sweep_throughput.main),
    ]
    t_all = time.time()
    failures = []
    for name, fn in stages:
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # keep the suite going; report at the end
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
        print(f"[{name}] {time.time() - t0:.0f}s")
    print(f"\n[benchmarks] total {time.time() - t_all:.0f}s; "
          f"{len(stages) - len(failures)}/{len(stages)} stages ok")
    if failures:
        for n, e in failures:
            print(f"[benchmarks] FAILED {n}: {e}")
        sys.exit(1)


if __name__ == "__main__":
    main()
