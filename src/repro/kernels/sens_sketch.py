"""Fused sensitivity + sketch Pallas TPU kernel.

The hot loop of FedPSA's client upload path: for every parameter block,
compute the Eq. 8 sensitivity s = |g*theta - 0.5*F*theta^2| and immediately
contract it against the on-the-fly Rademacher projection rows, accumulating
the k-vector sketch in VMEM. HBM traffic is exactly one streaming read of
(theta, g, F) per block — the d-sized sensitivity vector is NEVER written to
HBM, and the (k x d) projection matrix is never materialized (it is hashed
from the block's linear indices inside the kernel).

TPU adaptation notes (DESIGN.md §3): the paper's GPU implementation builds s
in device memory and multiplies by a broadcast dense R. On TPU we fuse both
into one VMEM-resident pass; the per-row sign generation is VPU integer work
that overlaps the float multiply-accumulate. Block size is a multiple of
(8, 128) lanes.

Grid: one program per parameter block; the (k,) output block is revisited by
every program (index_map -> 0) and accumulated sequentially, the standard
Pallas reduction pattern.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.buffer_agg import resolve_interpret

DEFAULT_BLOCK = 8 * 128 * 8  # 8192 f32 lanes per program


def _pcg(x):
    x = x.astype(jnp.uint32)
    state = x * jnp.uint32(747796405) + jnp.uint32(2891336453)
    word = ((state >> ((state >> jnp.uint32(28)) + jnp.uint32(4))) ^ state)
    word = word * jnp.uint32(277803737)
    return (word >> jnp.uint32(22)) ^ word


def _sens_sketch_kernel(theta_ref, g_ref, f_ref, out_ref, *, k: int,
                        seed: int, block: int, index_offset: int):
    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    theta = theta_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    f = f_ref[...].astype(jnp.float32)
    # Eq. 8 sensitivity, fused
    s = jnp.abs(g * theta - 0.5 * f * jnp.square(theta))

    lin = jnp.uint32(index_offset) + \
        pid.astype(jnp.uint32) * jnp.uint32(block) + \
        jax.lax.broadcasted_iota(jnp.uint32, (block,), 0)
    seed_u = jnp.uint32(seed)
    partial = []
    for r in range(k):  # unrolled: k is small (paper: 16)
        h = _pcg(seed_u ^ _pcg(lin * jnp.uint32(k) + jnp.uint32(r)))
        sign = jnp.where((h >> jnp.uint32(31)) == 0, 1.0, -1.0).astype(jnp.float32)
        partial.append(jnp.sum(s * sign))
    out_ref[...] += jnp.stack(partial)


def sens_sketch_pallas(theta: jnp.ndarray, g: jnp.ndarray, f: jnp.ndarray,
                       *, k: int = 16, seed: int = 0,
                       block: int = DEFAULT_BLOCK,
                       index_offset: int = 0,
                       interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused sensitivity+sketch of FLAT vectors theta/g/f -> (k,) f32.

    Inputs are zero-padded to a block multiple (padded entries have s = 0, so
    they contribute nothing regardless of their projection sign). The result
    includes the 1/sqrt(k) JL scale, matching ``repro.core.sketch``.
    ``interpret=None`` auto-selects: compiled on TPU, interpreter elsewhere.

    ``index_offset`` shifts the Rademacher hash to GLOBAL parameter indices:
    a caller holding shard ``theta[o : o + d_local]`` of a d-sharded flat
    vector passes ``index_offset=o``, and the psum of the per-shard partial
    sketches equals the single-device sketch of the full vector exactly
    (the projection sign of element i depends only on its global index).
    """
    interpret = resolve_interpret(interpret)
    (d,) = theta.shape
    block = min(block, -(-d // 1024) * 1024)  # don't pad small shards to 8k
    n = -(-d // block)
    dp = n * block
    pad = [(0, dp - d)]
    theta, g, f = (jnp.pad(x.astype(jnp.float32), pad) for x in (theta, g, f))

    out = pl.pallas_call(
        functools.partial(_sens_sketch_kernel, k=k, seed=seed, block=block,
                          index_offset=index_offset),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((k,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((k,), jnp.float32),
        interpret=interpret,
    )(theta, g, f)
    return out / jnp.sqrt(jnp.float32(k))
