"""Pure-jnp oracles for the Pallas kernels (bit-compatible hashing)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sk
from repro.core.sensitivity import sensitivity_from_parts


def sens_sketch_ref(theta, g, f, *, k: int = 16, seed: int = 0) -> jnp.ndarray:
    """Sensitivity (Eq. 8) of flat vectors followed by the hashed Rademacher
    projection — identical math to repro.core.sketch on a single flat leaf."""
    s = jnp.abs(g.astype(jnp.float32) * theta.astype(jnp.float32)
                - 0.5 * f.astype(jnp.float32) * jnp.square(theta.astype(jnp.float32)))
    lin = jnp.arange(s.shape[0], dtype=jnp.uint32)
    rows = [jnp.sum(s * sk.rademacher_row(jnp.uint32(seed), lin, r, k))
            for r in range(k)]
    return jnp.stack(rows) / np.sqrt(k)


def buffer_agg_ref(weights, global_vec, updates) -> jnp.ndarray:
    """global + sum_l w_l * updates_l in f32."""
    return global_vec.astype(jnp.float32) + jnp.einsum(
        "l,ld->d", weights.astype(jnp.float32), updates.astype(jnp.float32))
