"""Population-scale dispatch cost: streaming slabs at C=5k / 100k / 1M.

The claim under test (ISSUE 7 + ISSUE 10 / ROADMAP "million-client
simulator"): with the vectorized timeline + chunked/streaming client slabs
+ async shard prefetch, per-dispatch wall cost is set by the WAVE (how many
clients train at once), not by the population size, and resident memory is
set by the shard-cache geometry, not by C. Each cell dispatches from a lazy
``SyntheticPopulation`` through the streaming cohort engine with the SAME
absolute in-flight count (1024 clients training at once), so C=5k, C=100k
and C=1M run comparable device waves and their per-dispatch costs are
directly comparable. The ``pop-1m`` cell runs with ``prefetch=True`` — the
next wave's host materialization + H2D upload overlaps device compute.

Per cell we run one full-length warmup (jit caches, shard cache, eval) and
one timed run while a sampler thread tracks peak host RSS; every cell row
records the slab store's full serving stats (hit/row-fetch rates, prefetch
hits, evictions). A separate column benchmarks staleness-aware selection
at C=100k: the PR-10 sublinear rejection sampler vs the historical exact
O(C) recompute loop, per draw. Writes artifacts/bench/BENCH_population.json.

Acceptance gates (exit 1 with a WARNING when violated):
  * per-dispatch wall cost at C=100k <= 1.3x the C=5k cell;
  * per-dispatch wall cost at C=1M <= 1.3x the C=100k cell;
  * the C=1M timed run completes within POP_BENCH_1M_BUDGET_S wall seconds
    (default 60);
  * the fast staleness sampler's per-draw cost at C=100k improves on the
    exact loop by >= 10x;
  * peak RSS of the largest cell <= smallest cell's peak +
    POP_BENCH_RSS_MARGIN_MB (default 600 MB — far below the ~1.6 GB a
    monolithic C=100k slab would add, generous to allocator noise).

Override the cells with POP_BENCH_PRESETS (comma list of
``repro.configs.population`` preset names; CI runs ``pop-smoke`` plus
``pop-1m-smoke`` — tiny C forced through fragmented multi-shard caches,
the latter with prefetch on — gating only RSS and the sampler speedup).
"""
from __future__ import annotations

import argparse
import os
import sys
import threading
import time

import jax

from repro.configs import get_population_preset
from repro.data.loader import ClientSlabStore
from repro.federated import SimConfig, run_async
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from benchmarks import common

LATENCY_LO, LATENCY_HI = 100.0, 500.0
LOCAL_EPOCHS = 2
BATCH_SIZE = 32
# Receives per timed run, roughly, at every C. The default is sized so a
# run spans MANY waves: per-dispatch cost then measures steady state (the
# O(C) per-run setup — e.g. drawing 1M per-client latency means —
# amortizes away) and the prefetch pipeline actually has next waves to
# stage. POP_BENCH_TARGET=200 gives a quick single-wave smoke.
TARGET_DISPATCHES = int(os.environ.get("POP_BENCH_TARGET", "1000"))
DEFAULT_PRESETS = "pop-5k,pop-100k,pop-1m"
GATE_RATIO = 1.3
GATE_CELLS = ("pop-5k", "pop-100k")
GATE_RATIO_1M = 1.3
GATE_CELLS_1M = ("pop-100k", "pop-1m")
BUDGET_1M_S = 60.0             # wall budget for the pop-1m timed run
STALENESS_GATE = 10.0          # fast sampler >= 10x the exact loop
STALENESS_C = 100_000
STALENESS_DRAWS = 256          # fast-path draws timed (after warmup)
STALENESS_EXACT_DRAWS = 8      # exact O(C) draws timed (each is ~ms-scale)


class RssSampler:
    """Peak resident set size (bytes) over a timed region, sampled from
    /proc/self/statm — per-cell, unlike the monotonic ru_maxrss."""

    def __init__(self, interval: float = 0.02):
        self.interval = interval
        self.page = os.sysconf("SC_PAGE_SIZE")
        self.peak = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True)

    def _read(self) -> int:
        try:
            with open("/proc/self/statm") as f:
                return int(f.read().split()[1]) * self.page
        except OSError:          # non-linux: no per-cell sampling
            return 0

    def _loop(self):
        while not self._stop.is_set():
            self.peak = max(self.peak, self._read())
            self._stop.wait(self.interval)

    def __enter__(self):
        self.peak = self._read()
        self._thread.start()
        return self

    def __exit__(self, *exc):
        self._stop.set()
        self._thread.join()
        self.peak = max(self.peak, self._read())
        return False


def model_config(preset) -> ModelConfig:
    """The paper MLP sized to the preset's feature dim."""
    from repro.configs import get_config
    cfg = get_config("paper-synthetic-mlp")
    assert cfg.input_hw[0] == preset.dim and cfg.num_classes == preset.num_classes
    return cfg


def horizon_for(n_inflight: int, target: int) -> float:
    """Completions arrive from t=latency_lo at ~n_inflight/mean_latency per
    virtual-time unit; size the horizon for ~target receives."""
    mean_lat = 0.5 * (LATENCY_LO + LATENCY_HI)
    return LATENCY_LO + target * mean_lat / n_inflight


def bench_cell(name: str, seed: int = 0) -> dict:
    preset = get_population_preset(name)
    pop = preset.population(seed=seed)
    cfg = model_config(preset)
    test = pop.test_dataset(1024)
    params = model_lib.init_params(jax.random.PRNGKey(seed), cfg)
    horizon = horizon_for(preset.n_inflight, TARGET_DISPATCHES)
    sim = SimConfig(local_epochs=LOCAL_EPOCHS, batch_size=BATCH_SIZE,
                    horizon=horizon, eval_every=horizon,
                    latency_kind="uniform", latency_lo=LATENCY_LO,
                    latency_hi=LATENCY_HI, seed=seed, eval_batches=2,
                    engine="cohort", **preset.sim_kwargs())

    stores = []
    orig_build = ClientSlabStore.build.__func__

    def spy_build(cls, datasets, **kw):
        s = orig_build(cls, datasets, **kw)
        stores.append(s)
        return s

    ClientSlabStore.build = classmethod(spy_build)
    try:
        run_async("fedasync", cfg, params, pop, test, sim)     # warmup
        with RssSampler() as rss:
            t0 = time.perf_counter()
            res = run_async("fedasync", cfg, params, pop, test, sim)
            wall = time.perf_counter() - t0
    finally:
        ClientSlabStore.build = classmethod(orig_build)
    assert res.engine == "cohort", res.engine        # no silent fallback
    assert res.dispatches > 0
    store = stores[-1]                               # the timed run's store
    cell = {
        "preset": name,
        "num_clients": preset.num_clients,
        "n_inflight": preset.n_inflight,
        "horizon": horizon,
        "prefetch": preset.prefetch,
        "shard_size": preset.shard_size,
        "shard_cache": preset.shard_cache,
        "resident_bound_mb": preset.resident_mb,
        "dispatches": res.dispatches,
        "launched": res.launched,
        "cohorts": res.cohorts,
        "mean_cohort_size": res.dispatches / max(res.cohorts, 1),
        "wall_s": wall,
        "per_dispatch_ms": 1e3 * wall / res.dispatches,
        "dispatches_per_s": res.dispatches / wall,
        "peak_rss_mb": rss.peak / 2**20,
        "slab_stats": store.stats,
        "final_accuracy": res.final_accuracy,
    }
    print(f"population,preset={name},C={preset.num_clients},"
          f"dispatches={res.dispatches},wall_s={wall:.2f},"
          f"per_dispatch_ms={cell['per_dispatch_ms']:.2f},"
          f"peak_rss_mb={cell['peak_rss_mb']:.0f},"
          f"slab={store.stats}", flush=True)
    return cell


def bench_staleness_select(C: int = STALENESS_C, seed: int = 1) -> dict:
    """Per-draw cost of staleness-aware selection at population scale: the
    sublinear rejection sampler (the default) vs the historical exact O(C)
    full-recompute loop (``exact=True``), on identical bound state over a
    realistic advancing-version trajectory."""
    import numpy as np

    from repro.federated.scheduler import StalenessAwareScheduler

    def bound(**kw):
        s = StalenessAwareScheduler(**kw)
        s.bind(num_clients=C, rng=np.random.RandomState(seed))
        return s

    fast = bound()
    v = 0.0
    for i in range(16):                       # warm the envelope/cumsum
        v += 1.0
        fast.select(np.array([float(i)]), np.array([v]))
    t0 = time.perf_counter()
    for i in range(STALENESS_DRAWS):
        v += 1.0
        fast.select(np.array([float(i)]), np.array([v]))
    per_fast = (time.perf_counter() - t0) / STALENESS_DRAWS

    exact = bound(exact=True)
    t0 = time.perf_counter()
    for i in range(STALENESS_EXACT_DRAWS):
        exact.select(np.array([float(i)]), np.array([float(i + 1)]))
    per_exact = (time.perf_counter() - t0) / STALENESS_EXACT_DRAWS

    col = {
        "num_clients": C,
        "timed_draws_fast": STALENESS_DRAWS,
        "timed_draws_exact": STALENESS_EXACT_DRAWS,
        "per_draw_us_fast": 1e6 * per_fast,
        "per_draw_us_exact": 1e6 * per_exact,
        "speedup": per_exact / per_fast,
        "sample_stats": dict(fast.sample_stats),
    }
    print(f"population,staleness_select,C={C},"
          f"per_draw_us_fast={col['per_draw_us_fast']:.1f},"
          f"per_draw_us_exact={col['per_draw_us_exact']:.1f},"
          f"speedup={col['speedup']:.1f} (gate >= {STALENESS_GATE})",
          flush=True)
    return col


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--presets", default=None,
                    help="comma list of population preset names "
                         "(default POP_BENCH_PRESETS or pop-5k,pop-100k)")
    args = ap.parse_args(argv)
    names = (args.presets or os.environ.get("POP_BENCH_PRESETS",
                                            DEFAULT_PRESETS)).split(",")
    cells = [bench_cell(n.strip()) for n in names if n.strip()]
    by_name = {c["preset"]: c for c in cells}
    payload = {
        "model": "paper-synthetic-mlp",
        "backend": jax.default_backend(),
        "local_epochs": LOCAL_EPOCHS,
        "batch_size": BATCH_SIZE,
        "target_dispatches": TARGET_DISPATCHES,
        "cells": cells,
    }
    failures = []
    if all(n in by_name for n in GATE_CELLS):
        ratio = (by_name[GATE_CELLS[1]]["per_dispatch_ms"]
                 / by_name[GATE_CELLS[0]]["per_dispatch_ms"])
        payload["per_dispatch_ratio_100k_vs_5k"] = ratio
        print(f"population,per_dispatch_ratio={ratio:.3f} (gate <= "
              f"{GATE_RATIO})", flush=True)
        if ratio > GATE_RATIO:
            failures.append(f"per-dispatch cost at C=100k is {ratio:.2f}x "
                            f"the C=5k cell (> {GATE_RATIO}x)")
    if all(n in by_name for n in GATE_CELLS_1M):
        ratio = (by_name[GATE_CELLS_1M[1]]["per_dispatch_ms"]
                 / by_name[GATE_CELLS_1M[0]]["per_dispatch_ms"])
        payload["per_dispatch_ratio_1m_vs_100k"] = ratio
        print(f"population,per_dispatch_ratio_1m={ratio:.3f} (gate <= "
              f"{GATE_RATIO_1M})", flush=True)
        if ratio > GATE_RATIO_1M:
            failures.append(f"per-dispatch cost at C=1M is {ratio:.2f}x "
                            f"the C=100k cell (> {GATE_RATIO_1M}x)")
    if "pop-1m" in by_name:
        budget = float(os.environ.get("POP_BENCH_1M_BUDGET_S",
                                      str(BUDGET_1M_S)))
        wall = by_name["pop-1m"]["wall_s"]
        payload["budget_1m_s"] = budget
        print(f"population,pop_1m_wall_s={wall:.1f} (budget <= "
              f"{budget:.0f}s)", flush=True)
        if wall > budget:
            failures.append(f"the C=1M timed run took {wall:.1f}s "
                            f"(> {budget:.0f}s budget)")
    sched_col = bench_staleness_select(
        int(os.environ.get("STALENESS_BENCH_CLIENTS", str(STALENESS_C))))
    payload["staleness_select"] = sched_col
    if sched_col["speedup"] < STALENESS_GATE:
        failures.append(
            f"staleness-aware fast sampler is only "
            f"{sched_col['speedup']:.1f}x the exact loop at "
            f"C={sched_col['num_clients']} (gate >= {STALENESS_GATE}x)")
    if len(cells) >= 2:
        margin = float(os.environ.get("POP_BENCH_RSS_MARGIN_MB", "600"))
        small = min(cells, key=lambda c: c["num_clients"])
        big = max(cells, key=lambda c: c["num_clients"])
        delta = big["peak_rss_mb"] - small["peak_rss_mb"]
        payload["rss_delta_mb"] = delta
        payload["rss_margin_mb"] = margin
        print(f"population,rss_delta_mb={delta:.0f} (gate <= {margin:.0f})",
              flush=True)
        if delta > margin:
            failures.append(
                f"peak RSS grew {delta:.0f} MB from C={small['num_clients']}"
                f" to C={big['num_clients']} (> {margin:.0f} MB margin — "
                f"resident memory must be set by shard geometry, not C)")
    path = common.save("BENCH_population", payload)
    print(f"wrote {path}")
    for msg in failures:
        print(f"WARNING: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
