"""The paper's own evaluation models (FedPSA §6.1 Network Architectures).

* MNIST: CNN — two 5x5 convs (32, 64 ch) each + ReLU + 2x2 maxpool, fc 512.
* FMNIST: single linear layer 784 -> 10, bias init 0.
* CIFAR-10/100: CNN — two 5x5 convs (64, 64) + fc 384 + fc 192.
* synthetic-mlp: the small model the synthetic-data benchmarks train (the
  offline stand-in for the image datasets; see repro/data).
"""
from repro.models.config import ModelConfig


def _base(**kw):
    defaults = dict(
        num_layers=1, d_model=0, num_heads=0, num_kv_heads=0, d_ff=0,
        vocab_size=0, head_dim=0, block_pattern=("attn",), ffn_pattern=("dense",),
        dtype="float32", param_dtype="float32", remat="none",
    )
    defaults.update(kw)
    return ModelConfig(**defaults)


CONFIGS = {
    "paper-mnist-cnn": _base(
        name="paper-mnist-cnn", family="cnn",
        cnn_channels=(32, 64), cnn_kernel=5, mlp_hidden=(512,),
        input_hw=(28, 28, 1), num_classes=10,
    ),
    "paper-fmnist-linear": _base(
        name="paper-fmnist-linear", family="mlp",
        mlp_hidden=(), input_hw=(784, 0, 0), num_classes=10,
    ),
    "paper-cifar10-cnn": _base(
        name="paper-cifar10-cnn", family="cnn",
        cnn_channels=(64, 64), cnn_kernel=5, mlp_hidden=(384, 192),
        input_hw=(32, 32, 3), num_classes=10,
    ),
    "paper-cifar100-cnn": _base(
        name="paper-cifar100-cnn", family="cnn",
        cnn_channels=(64, 64), cnn_kernel=5, mlp_hidden=(384, 192),
        input_hw=(32, 32, 3), num_classes=100,
    ),
    "paper-synthetic-mlp": _base(
        name="paper-synthetic-mlp", family="mlp",
        mlp_hidden=(64, 32), input_hw=(32, 0, 0), num_classes=10,
    ),
}
