"""Optimizers decrease convex losses; checkpoints roundtrip exactly."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (latest_step, load_pytree, load_train_state,
                              save_pytree, save_train_state)
from repro.optim import (adamw, apply_updates, clip_by_global_norm,
                         constant_lr, cosine_decay, exponential_decay, sgd,
                         sgd_momentum, warmup_cosine)


def _quad(params):
    return jnp.sum(jnp.square(params["w"] - 3.0)) + jnp.sum(jnp.square(params["b"]))


def _run(opt, steps=200, lr=0.05):
    params = {"w": jnp.ones((4,)), "b": jnp.full((2,), 2.0)}
    state = opt.init(params)
    for _ in range(steps):
        g = jax.grad(_quad)(params)
        upd, state = opt.update(g, state, params, lr)
        params = apply_updates(params, upd)
    return float(_quad(params))


def test_sgd_converges():
    assert _run(sgd()) < 1e-3


def test_momentum_converges():
    assert _run(sgd_momentum(0.9), lr=0.02) < 1e-3


def test_nesterov_converges():
    assert _run(sgd_momentum(0.9, nesterov=True), lr=0.02) < 1e-3


def test_adamw_converges():
    assert _run(adamw(), lr=0.05) < 1e-3


def test_clip_by_global_norm():
    g = {"w": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.sqrt(jnp.sum(jnp.square(clipped["w"])))) - 1.0) < 1e-5
    assert float(norm) == 20.0
    small = {"w": jnp.full((4,), 0.01)}
    same, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(same["w"], small["w"], rtol=1e-6)


def test_schedules():
    assert float(constant_lr(0.1)(100)) == np.float32(0.1)
    ed = exponential_decay(0.01, 0.999)
    assert abs(float(ed(0)) - 0.01) < 1e-9
    assert float(ed(100)) < 0.01
    cd = cosine_decay(1.0, 100)
    assert float(cd(0)) == 1.0 and abs(float(cd(100)) - 0.1) < 1e-5
    wc = warmup_cosine(1.0, 10, 110)
    assert float(wc(0)) == 0.0 and abs(float(wc(10)) - 1.0) < 1e-5


def test_checkpoint_roundtrip():
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "nested": {"b": np.ones(4, np.int32)},
            "list": [np.zeros(2), np.full((1, 2), 7.0)]}
    with tempfile.TemporaryDirectory() as d:
        save_pytree(tree, d, step=3)
        save_pytree(tree, d, step=10)
        assert latest_step(d) == 10
        back = load_pytree(d, tree, step=10)
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(a, b)


def test_train_state_roundtrip():
    params = {"w": jnp.ones((3,))}
    opt = adamw()
    state = opt.init(params)
    with tempfile.TemporaryDirectory() as d:
        save_train_state(params, state, 42, d)
        p2, s2, step = load_train_state(d, params, state)
        assert step == 42
        np.testing.assert_array_equal(np.asarray(p2["w"]), np.ones(3))
