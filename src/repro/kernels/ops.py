"""Jit'd public wrappers around the Pallas kernels.

Kernels auto-select their execution mode (``interpret=None``): compiled on
TPU, interpreter fallback on CPU (the interpreter traces the kernel body to
plain XLA ops). ``sketch_tree_fused`` is the drop-in accelerated version of
``repro.core.sketch.sketch_tree`` applied to the Eq. 8 parts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import sketch as sk
from repro.kernels.sens_sketch import sens_sketch_pallas
from repro.kernels.buffer_agg import buffer_agg_pallas


@functools.partial(jax.jit, static_argnames=("k", "seed", "block"))
def sens_sketch(theta, g, f, *, k: int = 16, seed: int = 0,
                block: int = 8 * 128 * 8):
    """Fused Eq. 8 sensitivity + sketch of flat vectors -> (k,) f32."""
    return sens_sketch_pallas(theta, g, f, k=k, seed=seed, block=block)


def sketch_tree_fused(params, grads, fisher, *, k: int = sk.DEFAULT_K,
                      seed: int = 0) -> jnp.ndarray:
    """Whole-model sensitivity sketch via the fused kernel, one leaf at a
    time (leaf seeds match ``repro.core.sketch.sketch_tree`` semantics)."""
    p_leaves = jax.tree_util.tree_leaves(params)
    g_leaves = jax.tree_util.tree_leaves(grads)
    f_leaves = jax.tree_util.tree_leaves(fisher)
    total = jnp.zeros((k,), jnp.float32)
    for i, (p, g, f) in enumerate(zip(p_leaves, g_leaves, f_leaves)):
        seed_i = sk.leaf_seed_host(seed, i)  # static, safe under outer jit
        total = total + sens_sketch(p.reshape(-1), g.reshape(-1),
                                    f.reshape(-1), k=k, seed=seed_i)
    return total


@jax.jit
def buffer_agg(weights, global_vec, updates):
    """FedPSA Eq. 20: global + sum_l w_l * update_l over flat vectors."""
    return buffer_agg_pallas(weights, global_vec, updates)
