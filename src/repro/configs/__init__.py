"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>``.

One module per assigned architecture (source cited in each file), plus the
paper's own CNN/MLP models. ``ARCHS`` maps id -> ModelConfig factory.
"""
from __future__ import annotations

import importlib
from typing import Callable, Dict

from repro.models.config import ModelConfig
from repro.configs.population import (PopulationPreset, POPULATION_PRESETS,
                                      get_population_preset)
from repro.configs.sched import (SchedBenchPreset, SCHED_PRESETS,
                                 get_sched_preset)

_ARCH_MODULES = {
    "xlstm-350m": "repro.configs.xlstm_350m",
    "llama3-405b": "repro.configs.llama3_405b",
    "codeqwen1.5-7b": "repro.configs.codeqwen15_7b",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "minitron-8b": "repro.configs.minitron_8b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_38b",
    "internvl2-1b": "repro.configs.internvl2_1b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a27b",
    "arctic-480b": "repro.configs.arctic_480b",
    # federated LM fine-tuning scenario (CPU-trainable smokes per family)
    "fed-lm-smoke": "repro.configs.fed_lm",
    "fed-lm-ssm-smoke": "repro.configs.fed_lm",
    "fed-lm-moe-smoke": "repro.configs.fed_lm",
    # paper models
    "paper-mnist-cnn": "repro.configs.paper_models",
    "paper-fmnist-linear": "repro.configs.paper_models",
    "paper-cifar10-cnn": "repro.configs.paper_models",
    "paper-cifar100-cnn": "repro.configs.paper_models",
    "paper-synthetic-mlp": "repro.configs.paper_models",
}

ASSIGNED = [k for k in _ARCH_MODULES
            if not k.startswith(("paper-", "fed-lm"))]


def get_config(arch: str) -> ModelConfig:
    # explicit registrations win over the "-smoke => reduced()" convention
    # (the fed-lm-* scenario configs are themselves registered smokes)
    if arch not in _ARCH_MODULES and arch.endswith("-smoke"):
        return get_config(arch[: -len("-smoke")]).reduced()
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(_ARCH_MODULES[arch])
    return mod.CONFIGS[arch] if hasattr(mod, "CONFIGS") else mod.CONFIG


def list_archs():
    return sorted(_ARCH_MODULES)
