"""Shared calibration batch D_b (paper §5.2 / Table 5).

The server constructs one small batch, broadcasts it once, and every client
evaluates its sensitivity on it. ``source="gaussian"`` uses pure N(0,1)
noise inputs with uniform labels — the paper shows this is as good as real
data (Table 5) and leaks nothing.
"""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import SyntheticClassification


def make_calibration_batch(ds: SyntheticClassification, batch_size: int = 64,
                           source: str = "gaussian", seed: int = 123) -> dict:
    rng = np.random.RandomState(seed)
    if np.issubdtype(ds.x.dtype, np.integer):
        # token task (federated LM): "gaussian" becomes the content-free
        # analogue — uniform random token ids; "real" samples held-out
        # sequences. Labels mirror the tokens (loss_fn shifts causally).
        if source == "real":
            idx = rng.choice(len(ds), size=min(batch_size, len(ds)),
                             replace=False)
            toks = ds.x[idx].astype(np.int32)
        elif source == "gaussian":
            toks = rng.randint(0, ds.num_classes,
                               size=(batch_size,) + ds.x.shape[1:]
                               ).astype(np.int32)
        else:
            raise ValueError(f"unknown calibration source {source!r}")
        return {"tokens": toks, "labels": toks.copy()}
    if source == "real":
        idx = rng.choice(len(ds), size=batch_size, replace=False)
        return {"x": ds.x[idx].astype(np.float32), "y": ds.y[idx].astype(np.int32)}
    if source == "gaussian":
        shape = (batch_size,) + ds.x.shape[1:]
        return {
            "x": rng.randn(*shape).astype(np.float32),
            "y": rng.randint(0, ds.num_classes, size=batch_size).astype(np.int32),
        }
    raise ValueError(f"unknown calibration source {source!r}")
