"""Assigned input shapes and ``input_specs()``.

Four shapes, each mapping to one lowered entry point:

  train_4k    seq=4,096   global_batch=256   -> train_step   (loss + grads)
  prefill_32k seq=32,768  global_batch=32    -> prefill_step (or encode)
  decode_32k  seq=32,768  global_batch=128   -> serve_step   (1 token + cache)
  long_500k   seq=524,288 global_batch=1     -> serve_step   (sub-quadratic)

``input_specs(cfg, shape)`` returns ``(mode, specs, axes)``:
* ``mode``  — "train" | "prefill" | "encode" | "decode"
* ``specs`` — pytree of jax.ShapeDtypeStruct (weak-type-correct, shardable,
              no device allocation), keyword args of the lowered function
* ``axes``  — matching pytree of logical-axis tuples for in_shardings

Encoder-only archs (hubert) have no decode; dense archs swap in the
sliding-window config variant for long_500k (cfg.for_long_context()).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.models.config import ModelConfig


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "training" | "inference-prefill" | "inference-decode" | "long-context-decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "training"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "inference-prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "inference-decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "long-context-decode"),
}


def shape_supported(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(supported, reason-if-not)."""
    s = SHAPES[shape]
    if s.kind in ("inference-decode", "long-context-decode") and not cfg.has_decode:
        return False, f"{cfg.name} is encoder-only (no decode step)"
    if shape == "long_500k" and cfg.family == "dense" and cfg.long_context_window is None:
        return False, f"{cfg.name} is pure full-attention with no sub-quadratic variant"
    return True, ""


def config_for_shape(cfg: ModelConfig, shape: str) -> ModelConfig:
    """long_500k uses the sliding-window variant for attention layers."""
    if shape == "long_500k":
        return cfg.for_long_context()
    return cfg


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _train_specs(cfg: ModelConfig, B: int, S: int):
    if cfg.frontend == "audio":
        specs = {
            "features": _sds((B, S, cfg.d_model), cfg.dtype),
            "labels": _sds((B, S), jnp.int32),
        }
        axes = {
            "features": ("batch", "seq", "embed_act"),
            "labels": ("batch", "seq"),
        }
    elif cfg.frontend == "vision":
        P = cfg.num_prefix_tokens
        specs = {
            "tokens": _sds((B, S - P), jnp.int32),
            "patches": _sds((B, P, cfg.d_model), cfg.dtype),
            "labels": _sds((B, S - P), jnp.int32),
        }
        axes = {
            "tokens": ("batch", "seq"),
            "patches": ("batch", "seq", "embed_act"),
            "labels": ("batch", "seq"),
        }
    else:
        specs = {
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
        axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    return specs, axes


def _prefill_specs(cfg: ModelConfig, B: int, S: int):
    specs, axes = _train_specs(cfg, B, S)
    specs.pop("labels")
    axes.pop("labels")
    return specs, axes


def input_specs(cfg: ModelConfig, shape: str):
    """Returns (mode, specs, axes). Raises if the pair is a noted skip."""
    ok, why = shape_supported(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} x {shape}: {why}")
    s = SHAPES[shape]
    cfg = config_for_shape(cfg, shape)
    B, S = s.global_batch, s.seq_len

    if s.kind == "training":
        specs, axes = _train_specs(cfg, B, S)
        return "train", {"batch": specs}, {"batch": axes}

    if s.kind == "inference-prefill":
        specs, axes = _prefill_specs(cfg, B, S)
        mode = "encode" if cfg.is_encoder_only else "prefill"
        return mode, {"batch": specs}, {"batch": axes}

    # decode: one new token against a seq_len-deep cache
    cache_specs = jax.eval_shape(
        lambda: model_lib.init_cache(cfg, B, S))
    c_axes = model_lib.cache_axes(cfg)
    specs = {
        "cache": cache_specs,
        "tokens": _sds((B, 1), jnp.int32),
        "pos": _sds((), jnp.int32),
    }
    axes = {
        "cache": c_axes,
        "tokens": ("batch", "seq"),
        "pos": (),
    }
    return "decode", specs, axes


def all_pairs(arch_ids, shape_names=None):
    """Enumerate (arch, shape, supported, reason) over the assignment matrix."""
    from repro.configs import get_config
    shape_names = shape_names or list(SHAPES)
    out = []
    for a in arch_ids:
        cfg = get_config(a)
        for s in shape_names:
            ok, why = shape_supported(cfg, s)
            out.append((a, s, ok, why))
    return out
