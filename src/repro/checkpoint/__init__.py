from repro.checkpoint.store import save_pytree, load_pytree, latest_step, save_train_state, load_train_state
