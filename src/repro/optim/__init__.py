from repro.optim.sgd import sgd, sgd_momentum
from repro.optim.adamw import adamw
from repro.optim.schedule import constant_lr, exponential_decay, cosine_decay, warmup_cosine
from repro.optim.base import Optimizer, apply_updates, clip_by_global_norm
