"""Legacy class-based servers — the pre-policy reference implementations.

These are the original mutable Python-object servers (one unjitted pytree op
at a time, list/deque buffers). They are kept as the numerical oracle for
``tests/test_policies.py`` — every jit-compiled policy in
``repro.federated.policies`` must reproduce its legacy trajectory — and as
the baseline for the server-step microbenchmark. Production traffic goes
through the policy shims in ``repro.federated.servers``.

Interface:
    receive(delta, client_params, meta) -> bool   # True if global updated
    params                                        # current global pytree
    version                                       # number of global updates
"""
from __future__ import annotations

import collections
from typing import Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import tree as tu
from repro.core import aggregation as agg
from repro.core import psa as psa_lib
from repro.core import sketch as sketch_lib
from repro.core import thermometer


class BaseServer:
    name = "base"
    needs_sketch = False

    def __init__(self, params):
        self.params = params
        self.version = 0
        self.log: List[dict] = []

    def receive(self, delta, client_params, meta) -> bool:
        raise NotImplementedError


class FedAsyncServer(BaseServer):
    """FedAsync: immediate mixing w <- (1-a)w + a*w_i, a = alpha*s(tau)."""
    name = "fedasync"

    def __init__(self, params, alpha: float = 0.6, a: float = 0.5):
        super().__init__(params)
        self.alpha, self.a = alpha, a

    def receive(self, delta, client_params, meta) -> bool:
        s = float(agg.staleness_polynomial(meta["tau"], self.alpha, self.a))
        self.params = jax.tree_util.tree_map(
            lambda w, wi: (1 - s) * w + s * wi, self.params, client_params)
        self.version += 1
        self.log.append({"tau": meta["tau"], "weight": s})
        return True


class FedBuffServer(BaseServer):
    """FedBuff: buffer K staleness-scaled deltas, apply their mean."""
    name = "fedbuff"

    def __init__(self, params, buffer_size: int = 5, server_lr: float = 1.0,
                 a: float = 0.5):
        super().__init__(params)
        self.buffer_size = buffer_size
        self.server_lr = server_lr
        self.a = a
        self.buffer: List = []

    def receive(self, delta, client_params, meta) -> bool:
        scale = float(agg.staleness_polynomial(meta["tau"], 1.0, self.a))
        self.buffer.append(tu.tree_scale(delta, scale))
        if len(self.buffer) < self.buffer_size:
            return False
        w = agg.uniform_weights(len(self.buffer)) * self.server_lr
        self.params = agg.aggregate_buffer(self.params, self.buffer, w)
        self.buffer.clear()
        self.version += 1
        return True


class _PSAEntry(NamedTuple):
    update: object           # pytree dw_i
    kappa: jnp.ndarray       # behavioral similarity vs the global sketch


class FedPSAServer(BaseServer):
    """FedPSA (Algorithm 1) with the original python-list buffer: kappa
    scoring + thermometer + temperature-softmax aggregation, one host-driven
    pytree op per arrival."""
    name = "fedpsa"
    needs_sketch = True

    def __init__(self, params, cfg_psa: psa_lib.PSAConfig,
                 sketch_fn: Callable):
        super().__init__(params)
        self.cfg = cfg_psa
        self.buffer: List[_PSAEntry] = []
        self.thermo = thermometer.init_thermometer(cfg_psa.queue_len)
        self.sketch_fn = sketch_fn  # params -> k-vector (shared calib batch)
        self.global_sketch = sketch_fn(params)

    def receive(self, delta, client_params, meta) -> bool:
        kappa = sketch_lib.cosine(meta["sketch"], self.global_sketch)
        self.buffer.append(_PSAEntry(delta, kappa))
        self.thermo = thermometer.push(self.thermo, tu.tree_sq_norm(delta))
        if len(self.buffer) < self.cfg.buffer_size:
            return False
        cfg = self.cfg
        kappas = jnp.stack([e.kappa for e in self.buffer])
        if cfg.use_thermometer:
            if bool(thermometer.is_full(self.thermo)):
                temp = thermometer.temperature(self.thermo, cfg.gamma,
                                               cfg.delta)
                weights = agg.psa_weights(kappas, temp)
            else:
                weights = agg.uniform_weights(len(self.buffer))
                temp = None
        else:  # w/o T ablation: fixed early-phase temperature
            temp = jnp.float32(cfg.gamma + cfg.delta)
            weights = agg.psa_weights(kappas, temp)
        self.params = agg.aggregate_buffer(
            self.params, [e.update for e in self.buffer], weights,
            cfg.server_lr)
        self.buffer.clear()
        self.version += 1
        self.global_sketch = self.sketch_fn(self.params)
        self.log.append({
            "weights": np.asarray(weights),
            "kappas": np.asarray(kappas),
            "temp": None if temp is None else float(temp),
        })
        return True


class CA2FLServer(BaseServer):
    """CA2FL: cached-update calibration. Keeps the latest delta h_i per
    client; aggregation calibrates the buffer mean with the cache mean."""
    name = "ca2fl"

    def __init__(self, params, num_clients: int, buffer_size: int = 5,
                 server_lr: float = 1.0):
        super().__init__(params)
        self.buffer_size = buffer_size
        self.server_lr = server_lr
        self.buffer: List = []
        self.cache: Dict[int, object] = {}
        self.num_clients = num_clients
        self.h_sum = None  # running sum of cached deltas

    def receive(self, delta, client_params, meta) -> bool:
        cid = meta["client_id"]
        prev = self.cache.get(cid)
        self.buffer.append((delta, prev))
        # update cache & running sum
        if self.h_sum is None:
            self.h_sum = tu.tree_zeros_like(delta)
        if prev is not None:
            self.h_sum = tu.tree_sub(self.h_sum, prev)
        self.h_sum = tu.tree_add(self.h_sum, delta)
        self.cache[cid] = delta
        if len(self.buffer) < self.buffer_size:
            return False
        n_cached = max(len(self.cache), 1)
        h_mean = tu.tree_scale(self.h_sum, 1.0 / n_cached)
        resid = [tu.tree_sub(d, p) if p is not None else d
                 for d, p in self.buffer]
        v = tu.tree_add(
            tu.tree_scale(
                jax.tree_util.tree_map(lambda *xs: sum(xs), *resid)
                if len(resid) > 1 else resid[0],
                1.0 / len(resid)),
            h_mean)
        self.params = tu.tree_axpy(self.server_lr, v, self.params)
        self.buffer.clear()
        self.version += 1
        return True


class FedFaServer(BaseServer):
    """FedFa: fully-asynchronous queue of recent client models; the global
    model is a recency-weighted average of the queue, refreshed per arrival.
    The queue is a deque(maxlen=...) so eviction is O(1)."""
    name = "fedfa"

    def __init__(self, params, queue_len: int = 5, beta: float = 0.5):
        super().__init__(params)
        self.queue_len = queue_len
        self.beta = beta
        self.queue: collections.deque = collections.deque(maxlen=queue_len)

    def receive(self, delta, client_params, meta) -> bool:
        self.queue.append(client_params)
        n = len(self.queue)
        w = np.array([self.beta ** (n - 1 - j) for j in range(n)], np.float32)
        w /= w.sum()
        self.params = tu.tree_weighted_sum(list(self.queue), jnp.asarray(w))
        self.version += 1
        return True


class FedPACLiteServer(BaseServer):
    """FedPAC-lite: FedBuff-style buffering; clients train with an extra
    classifier-alignment term (see client.local_update(align=...)). The
    feature-alignment of the full method is approximated by the head
    alignment — enough to reproduce its qualitative async behavior."""
    name = "fedpac"
    client_align = 0.1

    def __init__(self, params, buffer_size: int = 5, server_lr: float = 1.0):
        super().__init__(params)
        self.buffer_size = buffer_size
        self.server_lr = server_lr
        self.buffer: List = []

    def receive(self, delta, client_params, meta) -> bool:
        self.buffer.append(delta)
        if len(self.buffer) < self.buffer_size:
            return False
        w = agg.uniform_weights(len(self.buffer)) * self.server_lr
        self.params = agg.aggregate_buffer(self.params, self.buffer, w)
        self.buffer.clear()
        self.version += 1
        return True


def make_legacy_server(name: str, params, *, num_clients: int = 50,
                       psa_cfg: Optional[psa_lib.PSAConfig] = None,
                       sketch_fn: Optional[Callable] = None,
                       **kw) -> BaseServer:
    if name == "fedasync":
        return FedAsyncServer(params, **kw)
    if name == "fedbuff":
        return FedBuffServer(params, **kw)
    if name == "fedpsa":
        assert psa_cfg is not None and sketch_fn is not None
        return FedPSAServer(params, psa_cfg, sketch_fn)
    if name == "ca2fl":
        return CA2FLServer(params, num_clients=num_clients, **kw)
    if name == "fedfa":
        return FedFaServer(params, **kw)
    if name == "fedpac":
        return FedPACLiteServer(params, **kw)
    raise ValueError(f"unknown legacy server {name!r}")
