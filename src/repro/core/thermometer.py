"""Training thermometer (paper Eq. 16-18).

A fixed-size queue Q of recent update magnitudes m_i = ||dw_i||_2^2. The
temperature is

    Temp = (M_cur / M_0) * gamma + delta

where M_cur is the current queue mean and M_0 the mean when the queue first
filled. Until the queue is full the weighting scheme is uniform averaging
(Algorithm 1 lines 17-18). Implemented as an immutable NamedTuple of jnp
scalars/arrays so it can live inside jit'd server steps.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class ThermometerState(NamedTuple):
    queue: jnp.ndarray   # (L_q,) f32 ring buffer
    count: jnp.ndarray   # total number of pushes (int32)
    m0: jnp.ndarray      # queue mean when first full (f32, 0 until then)

    @property
    def capacity(self) -> int:
        return self.queue.shape[0]


def init_thermometer(queue_len: int = 50) -> ThermometerState:
    return ThermometerState(
        queue=jnp.zeros((queue_len,), jnp.float32),
        count=jnp.int32(0),
        m0=jnp.float32(0.0),
    )


def push(state: ThermometerState, m: jnp.ndarray) -> ThermometerState:
    """Push one magnitude; oldest entry dropped once full (ring buffer).
    Captures M_0 on the push that fills the queue for the first time."""
    L = state.capacity
    slot = jnp.mod(state.count, L)
    queue = state.queue.at[slot].set(jnp.float32(m))
    count = state.count + 1
    just_full = count == L
    m_cur = jnp.sum(queue) / L
    m0 = jnp.where(just_full, m_cur, state.m0)
    return ThermometerState(queue=queue, count=count, m0=m0)


def is_full(state: ThermometerState) -> jnp.ndarray:
    return state.count >= state.capacity


def current_mean(state: ThermometerState) -> jnp.ndarray:
    """M_cur: mean over valid entries (whole ring once full)."""
    L = state.capacity
    n = jnp.minimum(state.count, L)
    return jnp.sum(state.queue) / jnp.maximum(n, 1).astype(jnp.float32)


def temperature(state: ThermometerState, gamma: float = 5.0,
                delta: float = 0.5) -> jnp.ndarray:
    """Eq. 18. Only meaningful once the queue is full (caller falls back to
    uniform weighting before that — Algorithm 1)."""
    m_cur = current_mean(state)
    ratio = m_cur / jnp.maximum(state.m0, 1e-30)
    return ratio * gamma + delta
