"""End-to-end behaviour of the full FedPSA system (paper Algorithm 1 in the
event-driven runtime, kernels in the loop, serving path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import tree as tu
from repro.common.sharding import SINGLE_DEVICE_RULES as R
from repro.configs import get_config
from repro.core import PSAConfig, cosine
from repro.data import (ClientDataset, dirichlet_partition,
                        make_calibration_batch, make_classification,
                        train_test_split)
from repro.federated import SimConfig, run_algorithm, make_sketch_fn
from repro.models import model as M


@pytest.fixture(scope="module")
def small_world():
    cfg = get_config("paper-synthetic-mlp")
    full = make_classification(4000, 10, 32, seed=1, class_sep=0.7)
    train, test = train_test_split(full, 0.1)
    parts = dirichlet_partition(train, 10, alpha=0.3, seed=1)
    clients = [ClientDataset(train.subset(ix)) for ix in parts]
    calib = make_calibration_batch(train, 64, "gaussian")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    return cfg, clients, test, calib, params


def test_fedpsa_end_to_end_improves_model(small_world):
    cfg, clients, test, calib, params = small_world
    # horizon sized so the threshold holds with margin on the decorrelated
    # latency streams (the 30k-horizon curve sat exactly at the 0.3 line)
    sim = SimConfig(num_clients=10, horizon=50_000, eval_every=10_000, seed=1)
    res = run_algorithm("fedpsa", cfg, params, clients, test, sim,
                        psa_cfg=PSAConfig(), calib_batch=calib)
    first = res.accuracies[0]
    assert res.final_accuracy > max(first + 0.15, 0.3), res.accuracies
    assert res.versions > 0


def test_sketch_fn_detects_behavioral_divergence(small_world):
    """A model trained hard on skewed data must have lower kappa vs the
    global model than a lightly-perturbed copy of the global model — the
    motivation experiment (paper Fig. 1/2) in miniature."""
    cfg, clients, test, calib, params = small_world
    sketch_fn = make_sketch_fn(cfg, calib, PSAConfig())
    s_global = sketch_fn(params)

    twin = jax.tree_util.tree_map(
        lambda p: p + 0.001 * jax.random.normal(jax.random.PRNGKey(0), p.shape), params)
    from repro.federated.client import local_update
    _, diverged = local_update(params, cfg, clients[0], epochs=40,
                               batch_size=64, lr=0.1, seed=0)
    k_twin = float(cosine(sketch_fn(twin), s_global))
    k_div = float(cosine(sketch_fn(diverged), s_global))
    assert k_twin > k_div, (k_twin, k_div)


def test_kernel_path_equals_core_path_in_system(small_world):
    """The Pallas fused kernel is a drop-in for the client upload path."""
    cfg, clients, test, calib, params = small_world
    from repro.core.sensitivity import fisher_diagonal, sensitivity_from_parts
    from repro.core import sketch as sk
    from repro.kernels import ops

    calib_j = {k: jnp.asarray(v) for k, v in calib.items()}
    loss = lambda p, b: M.loss_fn(p, b, cfg, R)
    g = jax.grad(loss)(params, calib_j)
    f = fisher_diagonal(loss, params, calib_j, 4)
    core_sketch = sk.sketch_tree(sensitivity_from_parts(params, g, f), seed=42, k=16)
    kern_sketch = ops.sketch_tree_fused(params, g, f, seed=42, k=16)
    np.testing.assert_allclose(np.asarray(core_sketch), np.asarray(kern_sketch),
                               rtol=1e-3, atol=1e-3)


def test_serve_path_generates():
    cfg = get_config("xlstm-350m").reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    B, S, G = 2, 8, 4
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    cache, logits = M.prefill(params, {"tokens": toks}, cfg, R, max_len=S + G)
    out = []
    cur = jnp.argmax(logits, -1)[:, None]
    for i in range(G):
        cache, lg = M.decode_step(params, cache, cur, jnp.int32(S + i), cfg, R)
        cur = jnp.argmax(lg[:, 0], -1)[:, None]
        out.append(cur)
    gen = jnp.concatenate(out, 1)
    assert gen.shape == (B, G)
    assert int(gen.max()) < cfg.vocab_size


def test_checkpoint_restores_federated_state(small_world, tmp_path):
    cfg, clients, test, calib, params = small_world
    from repro.checkpoint import save_pytree, load_pytree
    sim = SimConfig(num_clients=10, horizon=5_000, eval_every=5_000, seed=2)
    run_algorithm("fedbuff", cfg, params, clients, test, sim)
    save_pytree(params, str(tmp_path), step=0)
    back = load_pytree(str(tmp_path), params, step=0)
    assert float(tu.tree_norm(tu.tree_sub(back, params))) == 0.0
