"""Golden-trajectory regression suite: the paper reproduction, pinned.

For every async policy, a fixed-seed QUICK world is run on the sequential
oracle and its trajectory is *checked in* as a digest stream
(``tests/golden/<policy>.json``): one ``(||w||_2, probe·w)`` fingerprint of
the flat global vector per applied receive, plus the run's final metrics.
The suite then asserts that every execution path — the sequential oracle
itself, the batched cohort engine, and the mesh-sharded server on a 2- and
4-virtual-device CPU mesh — reproduces those digests within float
tolerance. Any layout, kernel, or policy change that silently drifts the
numerics fails here instead of in the paper's tables.

Regenerate after an *intentional* numerical change with::

    make golden-regen        # runs this file with --regen

and commit the resulting ``tests/golden/`` diff (CI re-derives the digests
and fails if the committed files are stale).
"""
import json
import os
import sys

import numpy as np
import pytest

import jax

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config                       # noqa: E402
from repro.core import PSAConfig                           # noqa: E402
from repro.data import (ClientDataset, dirichlet_partition,  # noqa: E402
                        make_calibration_batch, make_classification,
                        train_test_split)
from repro.federated import SimConfig, run_algorithm       # noqa: E402
from repro.federated.policies import POLICY_NAMES          # noqa: E402
from repro.launch.mesh import make_fed_mesh                # noqa: E402
from repro.models import model as M                        # noqa: E402

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

# The golden world. Changing ANY of these constants invalidates the
# checked-in digests — regenerate and commit.
WORLD = dict(model="paper-synthetic-mlp", samples=1_500, classes=10, dim=32,
             clients=8, alpha=0.3, seed=0)
SIM = dict(num_clients=8, horizon=6_000.0, eval_every=3_000.0, seed=0)
PSA = dict(queue_len=10)   # queue fills mid-run: covers both weight phases

# Digests are compared loosely enough to absorb reduction-order float noise
# (engine/layout differences measure ~1e-6 relative) and tightly enough
# that any behavioral change — a weighting rule, a staleness resolution, a
# buffer slot — lands far outside the band within a handful of steps.
RTOL, ATOL = 1e-4, 1e-3


def _build_world():
    cfg = get_config(WORLD["model"])
    full = make_classification(WORLD["samples"], WORLD["classes"],
                               WORLD["dim"], seed=WORLD["seed"],
                               class_sep=0.7)
    train, test = train_test_split(full, 0.1)
    parts = dirichlet_partition(train, WORLD["clients"],
                                alpha=WORLD["alpha"], seed=WORLD["seed"])
    clients = [ClientDataset(train.subset(ix)) for ix in parts]
    calib = make_calibration_batch(train, 64, "gaussian")
    params = M.init_params(jax.random.PRNGKey(WORLD["seed"]), cfg)
    return cfg, clients, test, calib, params


@pytest.fixture(scope="module")
def world():
    return _build_world()


def _run(world, name, engine, mesh=None):
    cfg, clients, test, calib, params = world
    kw = {}
    if name == "fedpsa":
        kw = dict(psa_cfg=PSAConfig(**PSA), calib_batch=calib)
    sim = SimConfig(engine=engine, mesh=mesh, record_trajectory=True, **SIM)
    return run_algorithm(name, cfg, params, clients, test, sim, **kw)


def _golden_path(name):
    return os.path.join(GOLDEN_DIR, f"{name}.json")


def _load(name):
    path = _golden_path(name)
    assert os.path.exists(path), \
        f"missing golden digests {path} — run `make golden-regen` and commit"
    with open(path) as f:
        return json.load(f)


def _final(result):
    return {"final_accuracy": result.final_accuracy,
            "versions": result.versions,
            "dispatches": result.dispatches,
            "dropped": result.dropped,
            "launched": result.launched}


def _check(result, golden):
    want = golden["digests"]
    assert len(result.digests) == len(want), \
        (len(result.digests), len(want))
    np.testing.assert_allclose(np.asarray(result.digests),
                               np.asarray(want), rtol=RTOL, atol=ATOL)
    final = _final(result)
    for key in ("versions", "dispatches", "dropped", "launched"):
        assert final[key] == golden["final"][key], key
    np.testing.assert_allclose(final["final_accuracy"],
                               golden["final"]["final_accuracy"], atol=2e-3)
    # the curve shape, not just its endpoint (catches eval-grid drift)
    np.testing.assert_allclose(result.aulc, golden["final"]["aulc"],
                               atol=2e-3)


@pytest.mark.parametrize("name", POLICY_NAMES)
def test_sequential_matches_golden(world, name):
    """The oracle itself reproduces its checked-in trajectory."""
    _check(_run(world, name, "sequential"), _load(name))


@pytest.mark.parametrize("name", POLICY_NAMES)
def test_cohort_matches_golden(world, name):
    """The batched cohort engine reproduces the oracle's digests."""
    _check(_run(world, name, "cohort"), _load(name))


@pytest.mark.parametrize("name", POLICY_NAMES)
def test_streaming_matches_golden(world, name):
    """The chunked/streaming engine — client slabs split into 3-client
    shards behind a 2-shard LRU cache, so the golden run is forced through
    multiple shard loads AND at least one eviction — reproduces the same
    digest stream as the monolithic stacked-slab engine."""
    cfg, clients, test, calib, params = world
    kw = {}
    if name == "fedpsa":
        kw = dict(psa_cfg=PSAConfig(**PSA), calib_batch=calib)
    sim = SimConfig(engine="cohort", record_trajectory=True,
                    shard_size=3, shard_cache=2, shard_promote=1, **SIM)
    _check(run_algorithm(name, cfg, params, clients, test, sim, **kw),
           _load(name))


@pytest.mark.multidevice
@pytest.mark.parametrize("ndev", (2, 4))
@pytest.mark.parametrize("name", POLICY_NAMES)
def test_sharded_matches_golden(world, name, ndev):
    """The mesh-sharded server + data-parallel cohort engine reproduce the
    same digests on 2- and 4-device CPU meshes
    (``XLA_FLAGS=--xla_force_host_platform_device_count=4``)."""
    if jax.device_count() < ndev:
        pytest.skip(f"needs {ndev} devices, have {jax.device_count()}")
    _check(_run(world, name, "cohort", mesh=make_fed_mesh(ndev)), _load(name))


def test_golden_digests_are_committed():
    """Every policy has its digest file (regen writes all seven at once)."""
    for name in POLICY_NAMES:
        assert os.path.exists(_golden_path(name)), name


# One timeline-preserving hyper override per policy — a lane that must
# DIFFER from the default lane (proving per-lane hyper actually bites).
SWEEP_HYPER = {
    "fedasync": {"alpha": 0.3}, "fedbuff": {"server_lr": 0.7},
    "fedpsa": {"server_lr": 0.5}, "ca2fl": {"server_lr": 0.6},
    "fedfa": {"beta": 0.8}, "fedpac": {"server_lr": 0.8},
    "asyncfeded": {"alpha": 0.4},
}


@pytest.mark.parametrize("name", POLICY_NAMES)
def test_sweep_lane_matches_golden(world, name):
    """The sweep case: lane 0 of a 3-lane ``run_sweep`` (default seeds and
    hyperparameters, shared timeline) reproduces the checked-in golden
    digest stream, while the hyper-varied and reshuffled lanes diverge from
    it — lanes are independent simulations riding one compiled program."""
    from repro.federated import SweepConfig, run_sweep

    cfg, clients, test, calib, params = world
    kw = {}
    if name == "fedpsa":
        kw = dict(psa_cfg=PSAConfig(**PSA), calib_batch=calib)
    sweep = SweepConfig(data_seeds=[SIM["seed"], SIM["seed"], 1234],
                        policy_params=[None, SWEEP_HYPER[name], None])
    sim = SimConfig(engine="cohort", record_trajectory=True, **SIM)
    res = run_sweep(name, cfg, params, clients, test, sim, sweep, **kw)
    golden = _load(name)
    want = np.asarray(golden["digests"])
    got = np.asarray(res.digests[0])
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(res.final_accuracy[0],
                               golden["final"]["final_accuracy"], atol=2e-3)
    assert res.dispatches == golden["final"]["dispatches"]
    assert res.launched == golden["final"]["launched"]
    # the varied lanes must NOT reproduce the default trajectory
    for s in (1, 2):
        assert not np.allclose(np.asarray(res.digests[s]), want,
                               rtol=RTOL, atol=ATOL), s


# ---------------------------------------------------------------------------
# Federated LM scenario golden (fed-lm-smoke, slow / LM tier)
# ---------------------------------------------------------------------------

# The token-slab world: a dense-transformer smoke fine-tuned across
# document-partitioned bigram corpus shards. Changing ANY of these constants
# (or the fed-lm-smoke config) invalidates tests/golden/fed-lm-smoke.json.
FED_LM_WORLD = dict(model="fed-lm-smoke", samples=240, clients=6, alpha=0.3,
                    seed=0, seq=16)
FED_LM_SIM = dict(num_clients=6, horizon=6_000.0, eval_every=3_000.0, seed=0,
                  local_epochs=2, batch_size=8)
FED_LM_POLICIES = ("fedasync", "fedpsa")


def _build_lm_world():
    from repro.launch.train import build_task
    cfg, clients, test, calib = build_task(
        FED_LM_WORLD["model"], FED_LM_WORLD["samples"], FED_LM_WORLD["alpha"],
        FED_LM_WORLD["clients"], FED_LM_WORLD["seed"],
        seq_len=FED_LM_WORLD["seq"])
    params = M.init_params(jax.random.PRNGKey(FED_LM_WORLD["seed"]), cfg)
    return cfg, clients, test, calib, params


@pytest.fixture(scope="module")
def lm_world():
    return _build_lm_world()


def _run_lm(world, name, engine):
    cfg, clients, test, calib, params = world
    kw = {}
    if name == "fedpsa":
        kw = dict(psa_cfg=PSAConfig(**PSA), calib_batch=calib)
    sim = SimConfig(engine=engine, record_trajectory=True, **FED_LM_SIM)
    return run_algorithm(name, cfg, params, clients, test, sim, **kw)


@pytest.mark.slow
@pytest.mark.parametrize("engine", ("sequential", "cohort"))
@pytest.mark.parametrize("name", FED_LM_POLICIES)
def test_fed_lm_matches_golden(lm_world, name, engine):
    """Both engines reproduce the checked-in LM-scenario digest streams
    (and the cohort run must actually BE a cohort run, not a fallback)."""
    result = _run_lm(lm_world, name, engine)
    assert result.engine == engine
    _check(result, _load("fed-lm-smoke")["policies"][name])


# ---------------------------------------------------------------------------
# Regeneration entry point (make golden-regen)
# ---------------------------------------------------------------------------

def _round(x, sig=6):
    """Quantize to 6 significant digits: far below the comparison tolerance,
    above cross-run float noise, so regen on an unchanged tree is a no-op
    diff (the CI staleness gate relies on this)."""
    return float(f"{float(x):.{sig}g}")


def regen():
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    world = _build_world()
    for name in POLICY_NAMES:
        r = _run(world, name, "sequential")
        final = _final(r)
        final["final_accuracy"] = _round(final["final_accuracy"])
        final["aulc"] = _round(r.aulc)
        payload = {
            "world": WORLD, "sim": SIM,
            "psa": PSA if name == "fedpsa" else None,
            "policy": name,
            "digests": [[_round(a), _round(b)] for a, b in r.digests],
            "final": final,
        }
        path = _golden_path(name)
        with open(path, "w") as f:
            json.dump(payload, f, indent=1)
            f.write("\n")
        print(f"wrote {path}  ({len(r.digests)} digests, "
              f"acc={final['final_accuracy']:.4f})")
    lm_world = _build_lm_world()
    policies = {}
    for name in FED_LM_POLICIES:
        r = _run_lm(lm_world, name, "sequential")
        final = _final(r)
        final["final_accuracy"] = _round(final["final_accuracy"])
        final["aulc"] = _round(r.aulc)
        policies[name] = {
            "digests": [[_round(a), _round(b)] for a, b in r.digests],
            "final": final,
        }
    payload = {"world": FED_LM_WORLD, "sim": FED_LM_SIM, "psa": PSA,
               "policies": policies}
    path = _golden_path("fed-lm-smoke")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {path}  ({[len(p['digests']) for p in policies.values()]} "
          f"digests)")


def check() -> int:
    """Staleness gate for CI: re-derive every policy's trajectory from the
    sequential oracle and compare against the COMMITTED digests within the
    suite's tolerance (never bitwise — float low bits differ across
    BLAS/SIMD/jax builds, and a byte-diff gate would flap on them). Exits
    non-zero when a numerical change landed without `make golden-regen` +
    committing the ``tests/golden/`` diff."""
    world = _build_world()
    stale = []
    for name in POLICY_NAMES:
        try:
            _check(_run(world, name, "sequential"), _load(name))
        except AssertionError as e:
            stale.append(name)
            print(f"STALE {name}: {str(e).splitlines()[0]}", file=sys.stderr)
        else:
            print(f"ok {name}")
    lm_world = _build_lm_world()
    for name in FED_LM_POLICIES:
        try:
            _check(_run_lm(lm_world, name, "sequential"),
                   _load("fed-lm-smoke")["policies"][name])
        except AssertionError as e:
            stale.append(f"fed-lm-smoke/{name}")
            print(f"STALE fed-lm-smoke/{name}: {str(e).splitlines()[0]}",
                  file=sys.stderr)
        else:
            print(f"ok fed-lm-smoke/{name}")
    if stale:
        print(f"golden digests stale for {stale} — run `make golden-regen` "
              f"and commit tests/golden/", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    if "--regen" in sys.argv:
        regen()
    elif "--check" in sys.argv:
        sys.exit(check())
    else:
        print("usage: python tests/test_golden.py --regen | --check",
              file=sys.stderr)
        sys.exit(2)
