"""Kernel microbenchmarks: fused Pallas path vs the unfused jnp pipeline.

On this CPU container the Pallas kernels run in interpret mode, so absolute
times are NOT TPU-representative; what the numbers demonstrate is (a) both
paths agree numerically and (b) the analytic HBM-traffic advantage of the
fused kernel (one streaming read of theta/g/F, no d-sized intermediate, no
materialized R) which is the TPU-relevant quantity.
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sk
from repro.core.sensitivity import sensitivity_from_parts
from repro.kernels import grouped_matmul_pallas, ops, ref
from benchmarks import common


def _time(fn, *a, reps=5):
    out = fn(*a)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*a)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps, out


def bench_server_step(n_arrivals: int = 60):
    """Legacy per-arrival FedPSA ingest (unjitted pytree ops, python-list
    buffer) vs the fused jit-compiled policy step (flat stacked ring buffer,
    Pallas buffer_agg, one device call per arrival) on the seed model
    shapes. Writes artifacts/bench/BENCH_server_step.json."""
    from repro.common import tree as tu
    from repro.configs import get_config
    from repro.core import PSAConfig
    from repro.core import sketch as sketch_lib
    from repro.federated import legacy, servers
    from repro.models import model as model_lib

    cfg = get_config("paper-synthetic-mlp")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    d = tu.tree_size(params)
    psa = PSAConfig()
    # raw-parameter sketch: both paths pay the same per-aggregation refresh
    sketch_fn = jax.jit(
        lambda p: sketch_lib.sketch_tree(p, psa.sketch_seed, psa.sketch_k))

    rng = np.random.RandomState(0)
    deltas = [jax.tree_util.tree_map(
        lambda x: jnp.asarray(rng.randn(*x.shape) * 0.01, jnp.float32), params)
        for _ in range(n_arrivals)]
    sketches = [jnp.asarray(rng.randn(psa.sketch_k), jnp.float32)
                for _ in range(n_arrivals)]
    metas = [{"tau": i % 3, "client_id": i % 10, "data_size": 10.0,
              "sketch": sketches[i]} for i in range(n_arrivals)]

    def drive(server):
        for delta, meta in zip(deltas, metas):
            server.receive(delta, delta, meta)
        jax.block_until_ready(jax.tree_util.tree_leaves(server.params))
        return server

    def timed(server):
        drive(server)  # warmup pass: compile every jit in the path
        t0 = time.time()
        drive(server)  # steady-state pass (state carries over, same work)
        return (time.time() - t0) / n_arrivals, server

    t_legacy, srv_l = timed(legacy.make_legacy_server(
        "fedpsa", params, psa_cfg=psa, sketch_fn=sketch_fn))
    t_fused, srv_f = timed(servers.make_server(
        "fedpsa", params, psa_cfg=psa, sketch_fn=sketch_fn))
    # both paths must land on the same global model
    diff = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree_util.tree_leaves(srv_l.params),
        jax.tree_util.tree_leaves(srv_f.params)))
    assert diff < 1e-4, f"legacy/fused trajectories diverged: {diff}"

    rows = {
        "model": cfg.name, "params_d": d, "arrivals": n_arrivals,
        "buffer_size": psa.buffer_size,
        "legacy_us_per_arrival": t_legacy * 1e6,
        "fused_us_per_arrival": t_fused * 1e6,
        "speedup_x": t_legacy / t_fused,
        "max_param_diff": diff,
    }
    print(f"server_step,fedpsa,d={d},legacy_us={t_legacy*1e6:.0f},"
          f"fused_us={t_fused*1e6:.0f},speedup={t_legacy/t_fused:.2f}x")
    common.save("BENCH_server_step", rows)
    return rows


def bench_grouped_matmul():
    """Grouped member-GEMM (one wave of heterogeneous members' dense layers
    as a single Pallas grouped GEMM) vs the vmapped dot_general path the
    cohort engines use by default, at production d_model from the configs/
    zoo (the member contraction dim is the model width; N is one 128-lane
    output tile so the CPU cells stay tractable). The win is only gated
    where the backend actually vectorizes the kernel (TPU); on CPU the
    kernel runs in interpret mode and the cell is recorded ungated.

    Plus the fed-lm compile-time cells: legacy per-row unrolled sketch
    (``sketch_tree(..., unroll=True)``, the committed baseline) vs the
    vectorized default, trace+compile wall time on the fed-lm-smoke
    parameter tree (acceptance: >= 3x drop).

    Writes artifacts/bench/BENCH_grouped_matmul.json.
    """
    from repro.configs import get_config
    from repro.models import model as model_lib

    backend = jax.default_backend()
    gated = backend == "tpu"
    rows = {"backend": backend, "gated": gated,
            "note": ("grouped timings are Pallas interpret mode (not "
                     "TPU-representative) — parity is the checked claim"
                     if not gated else "compiled Pallas timings")}

    vmap_dot = jax.jit(jax.vmap(
        lambda a, b: jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())))))
    grouped = jax.jit(lambda a, b: grouped_matmul_pallas(a, b))

    # (arch, G members in the wave, M rows per member); K = d_model.
    cells = [("phi4-mini-3.8b", 8, 8), ("minitron-8b", 8, 8),
             ("llama3-405b", 4, 8)]
    key = jax.random.PRNGKey(0)
    for arch, g, m in cells:
        k = get_config(arch).d_model
        n = 128
        a = jax.random.normal(key, (g, m, k), jnp.float32)
        b = jax.random.normal(jax.random.fold_in(key, 1), (g, k, n), jnp.float32)
        t_vmap, o_vmap = _time(vmap_dot, a, b)
        t_grp, o_grp = _time(grouped, a, b, reps=1)
        o_ref = ref.grouped_matmul_ref(a, b)
        scale = float(jnp.max(jnp.abs(o_ref))) + 1e-9
        rel = float(jnp.max(jnp.abs(o_grp - o_ref))) / scale
        assert rel < 1e-5, f"grouped kernel diverged from ref at {arch}: {rel}"
        rows[f"member_gemm_{arch}"] = {
            "G": g, "M": m, "K": k, "N": n,
            "vmap_us": t_vmap * 1e6, "grouped_us": t_grp * 1e6,
            "speedup_x": t_vmap / t_grp, "rel_err_vs_ref": rel,
        }
        print(f"kernel,grouped_matmul,{arch},G={g},M={m},K={k},N={n},"
              f"vmap_us={t_vmap*1e6:.0f},grouped_us={t_grp*1e6:.0f},"
              f"relerr={rel:.1e}")

    # Compile-time cells: program size of the unrolled sketch grows as
    # k x n_leaves distinct hash/reduce chains; the vectorized form is one
    # fused chain independent of k.
    cfg = get_config("fed-lm-smoke")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)

    def compile_s(unroll):
        f = jax.jit(lambda p: sk.sketch_tree(p, 0, 16, unroll=unroll))
        t0 = time.time()
        f.lower(params).compile()
        return time.time() - t0

    t_base = compile_s(True)
    t_vec = compile_s(False)
    rows["fed_lm_sketch_compile"] = {
        "model": cfg.name,
        "n_leaves": len(jax.tree_util.tree_leaves(params)),
        "unrolled_baseline_s": t_base, "vectorized_s": t_vec,
        "speedup_x": t_base / t_vec,
    }
    print(f"compile,fed_lm_sketch,{cfg.name},unrolled_s={t_base:.1f},"
          f"vectorized_s={t_vec:.2f},speedup={t_base/t_vec:.1f}x")
    assert t_base / t_vec >= 3.0, (
        f"sketch compile speedup regressed below 3x: {t_base/t_vec:.2f}")

    common.save("BENCH_grouped_matmul", rows)
    return rows


def main(argv=None):
    key = jax.random.PRNGKey(0)
    rows = {}
    for d in (10_000, 100_000):
        theta = jax.random.normal(key, (d,))
        g = jax.random.normal(jax.random.fold_in(key, 1), (d,))
        f = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (d,)))

        @jax.jit
        def unfused(theta, g, f):
            s = sensitivity_from_parts({"x": theta}, {"x": g}, {"x": f})
            return sk.sketch_tree(s, seed=0, k=16)

        t_ref, out_ref_ = _time(unfused, theta, g, f)
        t_kern, out_kern = _time(
            lambda th, gg, ff: ops.sens_sketch(th, gg, ff, k=16, seed=int(sk.leaf_seed(0, 0))),
            theta, g, f, reps=2)
        np.testing.assert_allclose(np.asarray(out_ref_), np.asarray(out_kern),
                                   rtol=5e-3, atol=5e-3)
        # analytic HBM traffic (bytes): fused reads theta,g,F once;
        # unfused additionally writes+reads the d-sized sensitivity
        fused_bytes = 3 * d * 4
        unfused_bytes = 5 * d * 4
        rows[f"sens_sketch_d{d}"] = {
            "jnp_us": t_ref * 1e6, "pallas_interpret_us": t_kern * 1e6,
            "fused_hbm_bytes": fused_bytes, "unfused_hbm_bytes": unfused_bytes,
            "hbm_saving_pct": 100 * (1 - fused_bytes / unfused_bytes),
        }
        print(f"kernel,sens_sketch,d={d},jnp_us={t_ref*1e6:.0f},"
              f"pallas_interp_us={t_kern*1e6:.0f},hbm_saving={100*(1-fused_bytes/unfused_bytes):.0f}%")

    L, d = 5, 100_000
    w = jax.nn.softmax(jax.random.normal(key, (L,)))
    gv = jax.random.normal(key, (d,))
    ups = jax.random.normal(key, (L, d))

    @jax.jit
    def agg_ref(w, gv, ups):
        return ref.buffer_agg_ref(w, gv, ups)

    t_ref, o1 = _time(agg_ref, w, gv, ups)
    t_kern, o2 = _time(ops.buffer_agg, w, gv, ups, reps=2)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-5)
    rows["buffer_agg"] = {"jnp_us": t_ref * 1e6,
                          "pallas_interpret_us": t_kern * 1e6}
    print(f"kernel,buffer_agg,L={L},d={d},jnp_us={t_ref*1e6:.0f},"
          f"pallas_interp_us={t_kern*1e6:.0f}")
    rows["grouped_matmul"] = bench_grouped_matmul()
    rows["server_step"] = bench_server_step()
    common.save("kernel_micro", rows)
    return rows


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
