"""Client availability scenarios (``federated/latency.py``).

Unit-level: the per-client availability distributions behave as documented
(bounds, means, the slow-fragile latency coupling), the batched ``sample(n)``
APIs are bit-identical to scalar draw loops (the golden digests depend on
this), the availability sub-streams are decorrelated from the latency
sub-streams, and trace-driven availability replays deterministically.
Sim-level: ``slow-fragile`` runs drop at the configured rate, a held slot
re-dispatches with the server version *current at the moment the slot frees*
(checked exactly against the event stream), ``availability_kind="always"``
reproduces the dropout-free trajectory bit-for-bit regardless of
``dropout_rate``, and trace runs share the dropout-free run's RNG streams.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import (ClientDataset, dirichlet_partition,
                        make_classification, train_test_split)
from repro.federated import SimConfig, run_algorithm
from repro.federated import simulator as sim_mod
from repro.federated import timeline as tl_mod
from repro.federated.latency import (AVAILABILITY_KINDS, _subseed,
                                     make_availability_trace,
                                     make_latency_sampler,
                                     per_client_availability,
                                     per_client_latency)

# ---------------------------------------------------------------------------
# Unit: latency distributions
# ---------------------------------------------------------------------------


def test_lognormal_latency_heavy_tail():
    """The lognormal kind: bounded support, deterministic by seed, and a
    genuinely heavy tail (mean > median, mass concentrated near lo)."""
    lo, hi = 10.0, 500.0
    sample = make_latency_sampler("lognormal", lo, hi, seed=0)
    draws = np.array([sample() for _ in range(4000)])
    assert np.all((lo <= draws) & (draws <= hi))
    assert np.mean(draws) > np.median(draws) * 1.1        # right-skew
    assert np.median(draws) < lo + 0.25 * (hi - lo)       # mass near lo
    assert np.max(draws) > 0.5 * hi                       # tail reaches out
    replay = make_latency_sampler("lognormal", lo, hi, seed=0)
    np.testing.assert_array_equal(draws[:50],
                                  [replay() for _ in range(50)])


def test_batched_sampler_matches_scalar_stream():
    """``sample(n)`` must consume the RNG stream exactly as n scalar calls
    — element-identical draws AND an interchangeable stream position (the
    vectorized timeline's draws reproduce the per-dispatch goldens)."""
    for kind in ("uniform", "longtail", "lognormal"):
        a = make_latency_sampler(kind, 10.0, 500.0, seed=3)
        b = make_latency_sampler(kind, 10.0, 500.0, seed=3)
        scalars = np.array([a() for _ in range(257)])
        np.testing.assert_array_equal(scalars, b.sample(257))
        # interleaving batch and scalar draws hits the same stream points
        c = make_latency_sampler(kind, 10.0, 500.0, seed=3)
        mixed = np.concatenate([c.sample(100), [c()], c.sample(156)])
        np.testing.assert_array_equal(scalars, mixed)


def test_per_client_latency_batch_jitter_matches_scalar():
    """``sample_for(cids)`` continues the jitter stream exactly where
    scalar ``sampler(cid)`` calls would."""
    a, means_a = per_client_latency("uniform", 10.0, 500.0, 64, seed=5)
    b, means_b = per_client_latency("uniform", 10.0, 500.0, 64, seed=5)
    np.testing.assert_array_equal(means_a, means_b)
    cids = np.array([3, 17, 3, 60, 0, 9])
    scalars = np.array([a(int(c)) for c in cids])
    np.testing.assert_array_equal(scalars, b.sample_for(cids))


def test_lognormal_per_client_latency_plumbs():
    sampler, means = per_client_latency("lognormal", 10.0, 500.0, 200, seed=1)
    assert means.shape == (200,)
    assert np.all((10.0 <= means) & (means <= 500.0))
    assert np.mean(means) > np.median(means)              # skew survives
    draws = np.array([sampler(i) for i in range(200)])
    assert np.all((10.0 <= draws) & (draws <= 500.0))
    with pytest.raises(ValueError, match="unknown latency kind"):
        make_latency_sampler("nope", 10.0, 500.0)


def test_lognormal_latency_runs_in_sim(world):
    """SimConfig.latency_kind='lognormal' drives a full async run, on both
    engines, with identical event streams."""
    cfg, clients, test, params = world
    kw = dict(latency_kind="lognormal", **QUICK)
    seq = run_algorithm("fedasync", cfg, params, clients, test,
                        SimConfig(engine="sequential", **kw))
    coh = run_algorithm("fedasync", cfg, params, clients, test,
                        SimConfig(engine="cohort", **kw))
    assert seq.dispatches == coh.dispatches > 0
    assert seq.receive_log == coh.receive_log
    np.testing.assert_allclose(coh.final_accuracy, seq.final_accuracy,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# Unit: availability distributions
# ---------------------------------------------------------------------------


def test_always_and_zero_rate_disable_dropout():
    assert np.all(per_client_availability("always", 0.5, 20) == 1.0)
    for kind in AVAILABILITY_KINDS:
        assert np.all(per_client_availability(kind, 0.0, 20) == 1.0)


def test_uniform_and_hetero_match_configured_rate():
    p_u = per_client_availability("uniform", 0.3, 1000, seed=1)
    np.testing.assert_allclose(p_u, 0.7)
    p_h = per_client_availability("hetero", 0.3, 4000, seed=1)
    assert np.all((0.0 <= p_h) & (p_h <= 1.0))
    assert abs(p_h.mean() - 0.7) < 0.05        # Beta mean = 1 - rate
    assert p_h.std() > 0.02                    # but chronically flaky tails


def test_slow_fragile_couples_availability_to_latency():
    _, means = per_client_latency("uniform", 10.0, 500.0, 50, seed=3)
    p = per_client_availability("slow-fragile", 0.25, 50, seed=3,
                                latency_means=means)
    order = np.argsort(means)
    # success prob decays monotonically with mean latency (affine in rank)
    assert np.all(np.diff(p[order]) <= 1e-12)
    assert p[order[0]] > 0.95 and p[order[-1]] < 0.6
    assert np.all(p >= 0.05)
    with pytest.raises(ValueError, match="latency_means"):
        per_client_availability("slow-fragile", 0.25, 50)


def test_availability_validation():
    with pytest.raises(ValueError, match="dropout_rate"):
        per_client_availability("uniform", 1.5, 10)
    with pytest.raises(ValueError, match="unknown availability"):
        per_client_availability("nope", 0.2, 10)


def test_rng_streams_decorrelated_at_equal_base_seed():
    """Regression for the ad-hoc ``seed + 0x5EED`` availability seeding: at
    one base seed, the latency-means, jitter and availability streams must
    all start from distinct MT19937 states (no stream may replay another)."""
    for seed in (0, 1, 24306 - 0x5EED, 12345):
        subs = [_subseed(seed, s) for s in range(6)]
        assert len(set(subs)) == len(subs), (seed, subs)
        # the bare dispatch stream (RandomState(seed), owned by the
        # schedulers) must also be distinct from every sub-stream — in
        # particular from the fedavg round-sampling stream (STREAM 5),
        # which used to BE the dispatch stream
        draws = [np.random.RandomState(ss).rand(8) for ss in [seed] + subs]
        for i in range(len(draws)):
            for j in range(i + 1, len(draws)):
                assert not np.array_equal(draws[i], draws[j]), (seed, i, j)
    # the hetero probabilities draw from the dedicated availability stream,
    # not from the latency streams
    _, means = per_client_latency("uniform", 10.0, 500.0, 100, seed=7)
    p = per_client_availability("hetero", 0.3, 100, seed=7)
    assert abs(np.corrcoef(means, p)[0, 1]) < 0.3


# ---------------------------------------------------------------------------
# Unit: trace-driven availability
# ---------------------------------------------------------------------------


def test_trace_deterministic_and_off_fraction():
    tr = make_availability_trace(60, 10_000.0, 0.4, seed=3)
    tr2 = make_availability_trace(60, 10_000.0, 0.4, seed=3)
    np.testing.assert_array_equal(tr.toggles, tr2.toggles)
    np.testing.assert_array_equal(tr.offsets, tr2.offsets)
    np.testing.assert_array_equal(tr.start_on, tr2.start_on)
    # long-run on fraction tracks 1 - off_fraction on average
    frac = tr.on_fraction(10_000.0)
    assert abs(frac.mean() - 0.6) < 0.08, frac.mean()
    assert frac.std() > 0.01          # clients have individual schedules
    tr3 = make_availability_trace(60, 10_000.0, 0.4, seed=4)
    assert not np.array_equal(tr.toggles, tr3.toggles)


def test_trace_on_at_matches_toggle_replay():
    """``on_at`` agrees with a literal replay of each client's toggles."""
    tr = make_availability_trace(10, 2_000.0, 0.5, seed=0)
    ts = np.linspace(0.0, 2_000.0, 101)
    for c in range(10):
        tg = tr.toggles[tr.offsets[c]:tr.offsets[c + 1]]
        assert np.all(np.diff(tg) >= 0.0)
        state = np.asarray(
            [bool(tr.start_on[c]) ^ (int(np.sum(tg <= t)) % 2 == 1)
             for t in ts])
        got = tr.on_at(np.full(len(ts), c), ts)
        np.testing.assert_array_equal(state, got)


def test_trace_zero_off_fraction_always_on():
    tr = make_availability_trace(16, 1_000.0, 0.0, seed=0)
    assert tr.toggles.shape == (0,)
    assert np.all(tr.start_on)
    assert np.all(tr.on_at(np.arange(16), np.full(16, 500.0)))
    with pytest.raises(ValueError, match="off_fraction"):
        make_availability_trace(4, 100.0, 1.0)


# ---------------------------------------------------------------------------
# Sim-level scenarios
# ---------------------------------------------------------------------------

QUICK = dict(num_clients=12, horizon=9_000.0, eval_every=4_500.0, seed=0)


@pytest.fixture(scope="module")
def world():
    cfg = get_config("paper-synthetic-mlp")
    full = make_classification(1_200, 10, 32, seed=0, class_sep=0.7)
    train, test = train_test_split(full, 0.1)
    parts = dirichlet_partition(train, QUICK["num_clients"], alpha=0.3,
                                seed=0)
    clients = [ClientDataset(train.subset(ix)) for ix in parts]
    params = M_init(cfg)
    return cfg, clients, test, params


def M_init(cfg):
    from repro.models import model as M
    return M.init_params(jax.random.PRNGKey(0), cfg)


def test_slow_fragile_drops_at_configured_rate(world):
    """Empirical drop fraction tracks dropout_rate (slow clients also hold
    their slots longer, so the dispatch-weighted rate sits near the mean)."""
    cfg, clients, test, params = world
    rate = 0.3
    r = run_algorithm("fedasync", cfg, params, clients, test,
                      SimConfig(availability_kind="slow-fragile",
                                dropout_rate=rate, **QUICK))
    frac = r.dropped / max(1, r.dropped + r.dispatches)
    assert r.dropped > 0
    assert 0.08 <= frac <= 0.55, frac
    assert r.launched == max(1, round(0.2 * QUICK["num_clients"])) + \
        r.dispatches + r.dropped


def test_held_slots_redispatch_with_current_version(world):
    """A failed dispatch holds its slot, then re-dispatches with the server
    version current at the time the slot frees. Verified exactly: record
    every heap push; replacement j (after the initial concurrency block)
    happens when processing the j-th completed event, so its
    version-at-dispatch must equal the number of global updates applied by
    the events processed up to then (fedasync: one update per ok receive)."""
    cfg, clients, test, params = world
    pushed = []
    orig_extend = tl_mod.Timeline.extend_arrays

    def spy_extend(self, t_done, seqs, cids, versions, oks, snapshots):
        # the timeline's single insertion choke point: every dispatch —
        # scalar or batched — passes through here exactly once
        t = np.asarray(t_done, np.float64)
        s = np.asarray(seqs, np.int64)
        c = np.asarray(cids, np.int64)
        v = np.asarray(versions, np.int64)
        o = np.asarray(oks, bool)
        for i in range(s.shape[0]):
            pushed.append(tl_mod._Event(float(t[i]), int(s[i]), int(c[i]),
                                        None, int(v[i]), bool(o[i])))
        return orig_extend(self, t_done, seqs, cids, versions, oks,
                           snapshots)

    tl_mod.Timeline.extend_arrays = spy_extend
    try:
        r = run_algorithm("fedasync", cfg, params, clients, test,
                          SimConfig(availability_kind="hetero",
                                    dropout_rate=0.35,
                                    engine="sequential", **QUICK))
    finally:
        tl_mod.Timeline.extend_arrays = orig_extend
    assert r.dropped > 0
    conc = max(1, round(0.2 * QUICK["num_clients"]))
    assert len(pushed) == r.launched
    pushed.sort(key=lambda e: e.seq)     # launch (dispatch) order
    # replay: events are processed in (t_done, seq) heap order; replacement
    # conc + j is pushed while processing the j-th processed event
    processed = sorted(pushed, key=lambda e: (e.t_done, e.seq))
    version = 0
    n_replacements = len(pushed) - conc
    for j in range(n_replacements):
        ev = processed[j]
        if ev.ok:
            version += 1        # fedasync: every receive bumps the version
        replacement = pushed[conc + j]
        assert replacement.version == version, (j, ev.ok)
    # in particular every dropped event's replacement carried the version
    # that was current when its slot freed — asserted above for ok=False


def test_always_reproduces_dropout_free_trajectory(world):
    """``availability_kind='always'`` must ignore dropout_rate entirely and
    reproduce the default (pre-availability-modelling) trajectory: same RNG
    stream, same receive log, same curve."""
    cfg, clients, test, params = world
    base = run_algorithm("fedbuff", cfg, params, clients, test,
                         SimConfig(**QUICK))
    always = run_algorithm("fedbuff", cfg, params, clients, test,
                           SimConfig(availability_kind="always",
                                     dropout_rate=0.7, **QUICK))
    assert base.receive_log == always.receive_log
    assert base.times == always.times
    assert base.accuracies == always.accuracies
    assert base.final_accuracy == always.final_accuracy
    assert always.dropped == 0


def test_dropout_identical_across_engines(world):
    cfg, clients, test, params = world
    kw = dict(availability_kind="slow-fragile", dropout_rate=0.3, **QUICK)
    seq = run_algorithm("fedbuff", cfg, params, clients, test,
                        SimConfig(engine="sequential", **kw))
    coh = run_algorithm("fedbuff", cfg, params, clients, test,
                        SimConfig(engine="cohort", **kw))
    assert seq.dropped == coh.dropped > 0
    assert seq.receive_log == coh.receive_log
    np.testing.assert_allclose(coh.final_accuracy, seq.final_accuracy,
                               atol=1e-4)


def test_trace_runs_drop_and_share_timeline_streams(world):
    """``availability_kind='trace'`` drops dispatches issued while a client
    is off — deterministically (two runs agree exactly) — and, because the
    trace consumes NO RNG, the dispatch cid/latency streams are identical
    to the dropout-free run's (same client visit order)."""
    cfg, clients, test, params = world
    kw = dict(availability_kind="trace", dropout_rate=0.4, **QUICK)
    a = run_algorithm("fedasync", cfg, params, clients, test,
                      SimConfig(**kw))
    b = run_algorithm("fedasync", cfg, params, clients, test,
                      SimConfig(**kw))
    assert a.dropped > 0
    assert a.dropped == b.dropped
    assert a.receive_log == b.receive_log
    assert a.final_accuracy == b.final_accuracy
    # same total launches as the no-dropout run would make over the same
    # timeline is NOT guaranteed (drops re-dispatch), but the two engines
    # must agree event-for-event
    seq = run_algorithm("fedasync", cfg, params, clients, test,
                        SimConfig(engine="sequential", **kw))
    assert seq.dropped == a.dropped
    assert seq.receive_log == a.receive_log
