"""Model-family registry: the one seam between models/ and the client runtime.

The federated client stack (``federated/client.local_update``, the compiled
``federated/cohort.CohortEngine``, the simulator's evaluation loop) used to
hard-code the paper's cnn/mlp forwards — every other family in ``models/``
silently dropped to the sequential python loop. This module replaces those
branches with a registry: a family registers ONE ``ModelFamily`` entry and
every engine (sequential, cohort, sharded-cohort) trains and evaluates it
through the same four callables. ``federated/simulator._resolve_engine``
consults :func:`is_registered` instead of a family allow-list.

The contract (all callables are pure and traced under jit/vmap):

``client_loss(params, batch, cfg, rules) -> scalar``
    The local-SGD training loss. ``batch`` follows the family's
    ``data_kind`` convention — ``"image"``: ``{"x","y"}`` plus optional
    ``{"sample_weight","weight_total"}`` row masking; ``"tokens"``:
    ``{"tokens","labels"}`` with ``labels < 0`` masked (the convention
    ``model_lib.loss_fn`` already speaks). Remat, MoE aux losses, etc. are
    the entry's own business — the token entry simply delegates to
    ``model_lib.loss_fn``, which honors ``cfg.remat`` per ``ModelConfig``.

``masked_batch(xb, yb, vm, cnt) -> batch``
    Fold the cohort engine's per-row validity mask ``vm`` (f32, (bs,)) and
    clamped count ``cnt`` into a batch such that masked rows are EXACT
    no-ops in ``client_loss``. With ``vm == 1`` everywhere the result must
    be arithmetically identical to the unmasked batch — that is what makes
    the cohort engine's parity with ``client.local_update`` exact.

``batch_fn(x, y) -> batch``
    Host-side: raw dataset arrays -> a device batch for ``client_loss`` /
    ``eval_accuracy`` (the evaluation loop and golden worlds use it).

``eval_accuracy(params, batch, cfg, rules) -> scalar``
    Test metric in [0, 1] — classification accuracy for image families,
    masked next-token accuracy for token families.

Registering a new family is ~10 lines; see ARCHITECTURE.md.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.models.config import ModelConfig


class ModelFamily(NamedTuple):
    name: str                 # registry key == ModelConfig.family
    data_kind: str            # "image" | "tokens" (selects the slab layout)
    client_loss: Callable     # (params, batch, cfg, rules) -> scalar
    masked_batch: Callable    # (xb, yb, vm, cnt) -> batch dict
    batch_fn: Callable        # (x, y) -> batch dict (host side)
    eval_accuracy: Callable   # (params, batch, cfg, rules) -> scalar


_REGISTRY: Dict[str, ModelFamily] = {}


def register_family(entry: ModelFamily, *, override: bool = False) -> None:
    """Register ``entry`` under ``entry.name``; the cohort engine and the
    simulator pick it up immediately (``engine="cohort"`` stops falling back
    to the sequential loop for that family)."""
    if entry.name in _REGISTRY and not override:
        raise ValueError(f"family {entry.name!r} already registered "
                         f"(pass override=True to replace)")
    assert entry.data_kind in ("image", "tokens"), entry.data_kind
    _REGISTRY[entry.name] = entry


def is_registered(family: str) -> bool:
    return family in _REGISTRY


def registered_families() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_family(family) -> ModelFamily:
    """Resolve a family name (or a ModelConfig) to its registry entry."""
    if isinstance(family, ModelConfig):
        family = family.family
    entry = _REGISTRY.get(family)
    if entry is None:
        raise KeyError(
            f"model family {family!r} is not in the model-family registry; "
            f"registered: {registered_families()} "
            f"(see models/registry.py for the ~10-line contract)")
    return entry


# ---------------------------------------------------------------------------
# Built-in image families (the paper's cnn/mlp models)
# ---------------------------------------------------------------------------

def _image_entry(name: str, forward: Callable, mean_loss: Callable
                 ) -> ModelFamily:
    def client_loss(params, batch, cfg, rules):
        vm = batch.get("sample_weight")
        if vm is None:
            # unmasked path: bit-identical to the legacy per-batch loss
            return mean_loss(params, batch, cfg)
        logits = forward(params, batch["x"], cfg).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
        return jnp.sum((lse - gold) * vm) / batch["weight_total"]

    def masked_batch(xb, yb, vm, cnt):
        return {"x": xb, "y": yb, "sample_weight": vm, "weight_total": cnt}

    def batch_fn(x, y):
        return {"x": jnp.asarray(x), "y": jnp.asarray(y)}

    def eval_accuracy(params, batch, cfg, rules):
        pred = jnp.argmax(forward(params, batch["x"], cfg), axis=-1)
        return jnp.mean((pred == batch["y"]).astype(jnp.float32))

    return ModelFamily(name=name, data_kind="image", client_loss=client_loss,
                       masked_batch=masked_batch, batch_fn=batch_fn,
                       eval_accuracy=eval_accuracy)


# ---------------------------------------------------------------------------
# Built-in token families: every LM-shaped family shares model_lib.loss_fn
# ---------------------------------------------------------------------------

def _token_entry(name: str) -> ModelFamily:
    def client_loss(params, batch, cfg, rules):
        return model_lib.loss_fn(params, batch, cfg, rules)

    def masked_batch(xb, yb, vm, cnt):
        # a masked row's labels all become -1, which model_lib's loss mask
        # already treats as "no target" — the row contributes zero loss and
        # zero gradient, so padded scan steps stay exact no-ops
        labels = jnp.where(vm[:, None] > 0.0, yb, -1)
        return {"tokens": xb, "labels": labels}

    def batch_fn(x, y):
        return {"tokens": jnp.asarray(x, jnp.int32),
                "labels": jnp.asarray(y, jnp.int32)}

    def eval_accuracy(params, batch, cfg, rules):
        logits = model_lib.forward_logits(params, batch, cfg, rules)
        labels = batch["labels"]
        if cfg.causal:   # position t predicts token t+1, as in the loss
            logits = logits[:, :-1]
            labels = labels[:, 1:]
        mask = (labels >= 0).astype(jnp.float32)
        hit = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
        return jnp.sum(hit * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    return ModelFamily(name=name, data_kind="tokens", client_loss=client_loss,
                       masked_batch=masked_batch, batch_fn=batch_fn,
                       eval_accuracy=eval_accuracy)


register_family(_image_entry("cnn", model_lib.cnn_forward, model_lib.cnn_loss))
register_family(_image_entry("mlp", model_lib.mlp_forward, model_lib.mlp_loss))
# All text-token families run through the one loss_fn entry point. The
# audio/vlm families are NOT registered: their batches need precomputed
# frame/patch embeddings the federated data layer does not produce, so the
# simulator falls back to the sequential loop (with a warning) for them.
for _fam in ("dense", "moe", "ssm", "hybrid"):
    register_family(_token_entry(_fam))
