"""FedPSA — the paper's contribution as a composable module.

Client side: ``client_sketch`` computes the Eq. 8 sensitivity on the shared
calibration batch and compresses it to a k-vector (Eq. 11). Server side:
``PSAState``/``server_receive``/``server_aggregate`` implement Algorithm 1 —
buffer + kappa scoring + thermometer + temperature-softmax aggregation.

The module is runtime-agnostic: the event-driven federated simulator uses it
directly, and ``launch/dryrun.py`` lowers ``client_sketch`` / the aggregation
under the production meshes (the sketch shards elementwise; kappa needs one
k-float all-reduce).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.common import tree as tu
from repro.core import aggregation, sketch, thermometer
from repro.core.sensitivity import sensitivity as _compute_sensitivity


@dataclass(frozen=True)
class PSAConfig:
    buffer_size: int = 5          # L_s (paper: 5)
    queue_len: int = 50           # L_q (paper: 50)
    gamma: float = 5.0            # temperature slope (paper: 5)
    delta: float = 0.5            # temperature floor (paper: 0.5)
    sketch_k: int = 16            # compressed dimension k (paper: 16)
    sketch_seed: int = 42         # shared projection seed (stands in for R)
    fisher_microbatches: int = 4
    server_lr: float = 1.0
    use_sensitivity: bool = True  # False => raw-parameter sketch (w/o S ablation)
    use_thermometer: bool = True  # False => fixed Temp = delta+gamma (w/o T ablation)


def client_sketch(loss_fn: Callable, params, calib_batch, cfg: PSAConfig) -> jnp.ndarray:
    """What a client uploads alongside its update: the k-dim sensitivity
    sketch evaluated on the shared calibration batch."""
    if cfg.use_sensitivity:
        s = _compute_sensitivity(loss_fn, params, calib_batch,
                                 cfg.fisher_microbatches)
    else:
        s = params  # w/o S ablation: sketch the raw parameters
    return sketch.sketch_tree(s, cfg.sketch_seed, cfg.sketch_k)


class BufferEntry(NamedTuple):
    update: object           # pytree dw_i
    kappa: jnp.ndarray       # behavioral similarity vs the global sketch


@dataclasses.dataclass
class PSAState:
    """Server-side mutable state (python-level; the math inside is jnp)."""
    cfg: PSAConfig
    thermo: thermometer.ThermometerState
    buffer: List[BufferEntry] = dataclasses.field(default_factory=list)
    global_sketch: Optional[jnp.ndarray] = None


def init_state(cfg: PSAConfig) -> PSAState:
    return PSAState(cfg=cfg, thermo=thermometer.init_thermometer(cfg.queue_len))


def refresh_global_sketch(state: PSAState, loss_fn, global_params, calib_batch):
    """Recompute the server model's sensitivity sketch (after each update)."""
    state.global_sketch = client_sketch(loss_fn, global_params, calib_batch, state.cfg)


def server_receive(state: PSAState, update, client_sketch_vec: jnp.ndarray):
    """Algorithm 1 lines 14-16: push (dw, kappa) into the buffer and the
    update magnitude into the thermometer queue."""
    kappa = sketch.cosine(client_sketch_vec, state.global_sketch)
    state.buffer.append(BufferEntry(update, kappa))
    m = tu.tree_sq_norm(update)  # Eq. 16
    state.thermo = thermometer.push(state.thermo, m)


def buffer_full(state: PSAState) -> bool:
    return len(state.buffer) >= state.cfg.buffer_size


def server_aggregate(state: PSAState, global_params):
    """Algorithm 1 lines 17-31: weight the buffered updates and apply them.

    Uniform averaging until the thermometer queue first fills; afterwards the
    temperature-softmax of the kappa scores (Eq. 18-20).
    """
    cfg = state.cfg
    n = len(state.buffer)
    assert n > 0, "aggregate called with empty buffer"
    kappas = jnp.stack([e.kappa for e in state.buffer])
    if cfg.use_thermometer:
        queue_ready = bool(thermometer.is_full(state.thermo))
        if queue_ready:
            temp = thermometer.temperature(state.thermo, cfg.gamma, cfg.delta)
            weights = aggregation.psa_weights(kappas, temp)
        else:
            weights = aggregation.uniform_weights(n)
            temp = None
    else:  # w/o T ablation: fixed early-phase temperature
        temp = jnp.float32(cfg.gamma + cfg.delta)
        weights = aggregation.psa_weights(kappas, temp)
    new_global = aggregation.aggregate_buffer(
        global_params, [e.update for e in state.buffer], weights, cfg.server_lr)
    state.buffer.clear()
    info = {
        "weights": weights,
        "kappas": kappas,
        "temp": temp,
        "m_cur": thermometer.current_mean(state.thermo),
    }
    return new_global, info
