"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE [arXiv:2403.19887].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16 experts top-2.
One Jamba block = 8 layers with a single attention layer (position 4) and MoE
on every other FFN — matching the paper's attn:mamba = 1:7 and moe:dense = 1:1.
long_500k runs natively: mamba layers are O(1)-state and the few attention
layers use a sliding window.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    ffn_pattern=("dense", "moe", "dense", "moe",
                 "dense", "moe", "dense", "moe"),
    num_experts=16,
    top_k=2,
    moe_d_ff=14336,
    ssm_expand=2,
    ssm_state_dim=16,
    conv_kernel=4,
    # §Perf opt: group-local MoE dispatch
    dispatch_groups=16,
    long_context_window=8192,
)
