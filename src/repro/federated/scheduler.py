"""Pluggable dispatch schedulers: WHO to dispatch and WHEN a slot relaunches.

Historically the dispatch rule — "sample a client uniformly, relaunch the
freed concurrency slot immediately" — was inlined twice, as twin
``dispatch``/``dispatch_many`` closures in ``run_async`` and ``run_sweep``.
This module extracts that copy into ONE shared layer, and makes the rule a
first-class research axis (the ROADMAP's scheduler/staleness-metric
surface):

``Scheduler``
    owns *client selection* (``select``) and *refill timing*
    (``launch_times``). Everything else about a dispatch — latency draw,
    availability draw, snapshot/version capture, timeline insertion —
    stays in ``Dispatcher`` and is scheduler-independent.

``UniformRefillScheduler``  (default, ``SimConfig.scheduler="uniform"``)
    the historical rule, bit-for-bit: ``rng.randint(num_clients, size=n)``
    on the bare ``RandomState(timeline_seed)`` dispatch stream with slots
    relaunching at the instant they free. Every golden digest stream under
    ``tests/golden/`` is pinned to this scheduler.

``PeriodTriggeredScheduler``  (``"period"``)
    FLGo fedasync-style period-triggered sampling: freed slots wait for
    the next wall-clock tick (``ceil(t / period) * period``) before
    relaunching, so dispatches leave the server in synchronized bursts.
    Selection stays uniform on the same dispatch stream.

``StalenessAwareScheduler``  (``"staleness"``)
    CSMAAFL-style utility/staleness-weighted selection: client c is drawn
    with probability proportional to

        (1 + version_lag_c)^staleness_weight
        * (data_size_c / mean_size)^size_weight
        * availability_c^avail_weight

    where ``version_lag_c`` is the server-version gap since c was last
    dispatched — preferring clients whose contribution is most stale
    (participation freshness), larger (utility), and likely to arrive
    (availability state from ``latency.per_client_availability``).
    Selection is sequential per dispatch (each draw updates the lag
    table). The default sampler is SUBLINEAR in C per draw (rejection
    sampling against the static base-utility cumsum — see the class
    docstring), which is what makes staleness-aware selection usable on
    the population-scale streaming path at C=10^5-10^6;
    ``scheduler_params={"exact": True}`` keeps the historical O(C)
    full-recompute loop as the exact-distribution oracle.

RNG-stream contract (see ``latency._subseed``): a scheduler may draw ONLY
from the dispatch stream handed to ``bind`` — the bare
``RandomState(timeline_seed)`` that historically produced the uniform cid
draws. Latency jitter, availability Bernoullis, and the synchronous-fedavg
round sampling live on their own sub-streams and are never the
scheduler's to consume.

Wave-safety contract: ``launch_times(ts) >= ts`` elementwise. The cohort
drain trains a wave up front on the premise that any replacement dispatch
completes no earlier than ``t_first + latency_lo``; deferring a launch
keeps that bound, advancing one would break re-dispatch safety.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.federated.latency import (STREAM_AVAIL_DRAWS, _subseed,
                                     make_availability_trace,
                                     per_client_availability,
                                     per_client_latency)

SCHEDULERS = ("uniform", "period", "staleness")


@dataclass
class SimStreams:
    """The host-side randomness of one simulation run, built once by
    ``make_streams`` (previously triplicated across ``run_async`` /
    ``run_sweep`` / ``run_fedavg``).

    ``rng`` is THE dispatch stream — the bare ``RandomState(tseed)`` that
    client selection draws from (handed to the scheduler at bind time).
    ``latency`` carries its own jitter stream (``latency.rng``); the
    availability Bernoulli draws live on ``avail_rng`` (stream
    ``STREAM_AVAIL_DRAWS``) so batched cid draws never reorder them. The
    ``trace`` kind replays a deterministic schedule and consumes no RNG.
    """
    tseed: int
    rng: np.random.RandomState
    latency: object                  # latency.PerClientLatency
    lat_means: np.ndarray
    avail: np.ndarray                # (C,) per-client success probabilities
    avail_rng: np.random.RandomState
    trace: Optional[object]          # latency.AvailabilityTrace
    use_trace: bool
    use_avail: bool


def make_streams(sim) -> SimStreams:
    """Build every host RNG stream of a run from ``SimConfig`` — one
    implementation for all three entry points, preserving the historical
    stream layout exactly."""
    tseed = sim.seed if sim.timeline_seed is None else sim.timeline_seed
    latency, lat_means = per_client_latency(
        sim.latency_kind, sim.latency_lo, sim.latency_hi, sim.num_clients,
        tseed)
    avail = per_client_availability(sim.availability_kind, sim.dropout_rate,
                                    sim.num_clients, tseed,
                                    latency_means=lat_means)
    use_trace = sim.availability_kind == "trace" and sim.dropout_rate > 0.0
    trace = (make_availability_trace(sim.num_clients, sim.horizon,
                                     sim.dropout_rate, tseed)
             if use_trace else None)
    use_avail = (sim.availability_kind not in ("always", "trace")
                 and sim.dropout_rate > 0.0)
    return SimStreams(
        tseed=tseed, rng=np.random.RandomState(tseed),
        latency=latency, lat_means=lat_means, avail=avail,
        avail_rng=np.random.RandomState(_subseed(tseed, STREAM_AVAIL_DRAWS)),
        trace=trace, use_trace=use_trace, use_avail=use_avail)


class Scheduler:
    """The dispatch-policy protocol (see module docstring for the contract).

    Lifecycle: ``bind`` is called once per run with the run's dispatch RNG
    stream and the scheduler-visible client state; then, per dispatch batch,
    ``launch_times`` maps slot-freed times to launch times (pure, no RNG)
    and ``select`` draws one client per launch (the only RNG consumer).

    ``stateless=True`` promises the scheduler's only mutable state is the
    bound RNG — what simulator checkpointing can already persist. A
    stateful scheduler (``stateless=False``) is checkpointable only when it
    additionally sets ``checkpoint_state=True`` and implements the
    ``state_arrays``/``load_state_arrays`` round-trip (the staleness
    scheduler's lag table does); stateful schedulers without it are
    rejected for checkpointed runs up front.
    """

    name = "scheduler"
    stateless = True
    # stateful schedulers opt in to checkpointing by setting this True and
    # implementing the state_arrays/load_state_arrays round-trip
    checkpoint_state = False

    def bind(self, *, num_clients: int, rng: np.random.RandomState,
             latency_means=None, avail_probs=None, data_sizes=None) -> None:
        self.num_clients = int(num_clients)
        self.rng = rng
        self.latency_means = latency_means
        self.avail_probs = avail_probs
        self.data_sizes = data_sizes

    def launch_times(self, ts) -> np.ndarray:
        """When each freed slot actually relaunches; must be >= ts."""
        return np.asarray(ts, np.float64)

    def select(self, ts: np.ndarray, versions: np.ndarray) -> np.ndarray:
        """(n,) client ids for launches at ``ts`` with the given
        version-at-dispatch per slot. The ONLY method that may draw RNG."""
        raise NotImplementedError

    def state_arrays(self) -> dict:
        """The scheduler's incremental host state as name -> numpy array,
        persisted by simulator checkpoints when ``checkpoint_state``.
        Stateless schedulers have nothing to persist."""
        return {}

    def load_state_arrays(self, arrays: dict) -> None:
        """Restore ``state_arrays`` output into a bound scheduler."""
        if arrays:
            raise NotImplementedError(
                f"scheduler {self.name!r} does not restore state")


class UniformRefillScheduler(Scheduler):
    """The historical inline rule: uniform client sampling, immediate
    refill. ``select`` consumes the MT19937 dispatch stream bit-for-bit as
    the pre-refactor ``rng.randint(num_clients, size=n)`` (numpy's legacy
    array fill equals n scalar calls), so golden digests are unchanged."""

    name = "uniform"

    def select(self, ts, versions):
        return self.rng.randint(self.num_clients, size=len(ts))


class PeriodTriggeredScheduler(UniformRefillScheduler):
    """FLGo fedasync-style period-triggered sampling: a freed slot waits
    for the next wall-clock tick before relaunching (FLGo's ``iterate``
    samples only when ``current_time % period == 0``). Selection stays
    uniform on the same stream.

    The initial concurrency fill at t=0 lands on a tick by construction
    (``ceil(0/p)*p == 0``). Snapshot/version are still captured when the
    slot frees — the period defers only the launch instant, which also
    keeps wave safety: ``tick + latency >= t + latency_lo``."""

    name = "period"

    def __init__(self, period: float = 20.0):
        if not period > 0.0:
            raise ValueError(f"period must be > 0, got {period}")
        self.period = float(period)

    def launch_times(self, ts):
        ts = np.asarray(ts, np.float64)
        return np.ceil(ts / self.period) * self.period


class StalenessAwareScheduler(Scheduler):
    """CSMAAFL-style utility/staleness-weighted client selection (see the
    module docstring for the weight law). Holds a per-client table of the
    server version at last dispatch; each draw updates it, so selection is
    a sequential per-dispatch loop — identical RNG consumption whether
    called with a batch or one slot at a time (the cohort flush and the
    sequential oracle stay stream-identical).

    Two samplers draw from the SAME distribution:

    ``exact=True`` — the historical oracle: rebuild the full C-length
    weight vector and ``rng.choice(p=...)`` per draw, O(C). Fine at paper
    scale, a hot-path blocker on the streaming path at C=10^5-10^6.

    ``exact=False`` (default) — sublinear rejection sampling. The weight
    factors as ``base_c * (1 + lag_c)^w`` where ``base_c`` (size x
    availability) is STATIC after ``bind`` and ``lag_c = v - lv_c`` with
    ``lv_c`` the version at c's last dispatch. Proposals come from the
    static base cumsum (one ``searchsorted``, O(log C)); since versions
    only advance, ``lv_floor <= min_c lv_c`` gives the envelope
    ``base_c * (1 + v - lv_floor)^w >= weight_c``, so accepting a proposal
    with probability ``((1 + lag_c) / (1 + v - lv_floor))^w`` is EXACT.
    Per draw: O(log C) expected — untouched clients (the overwhelming mass
    at population scale) accept at rate ~1, only the O(launched) touched
    clients reject. Pathological states (every client recently dispatched,
    stale floor) self-heal: after ``_REJECT_REFRESH`` rejections the floor
    is recomputed (amortized — only then is an O(C) ``min`` paid), and
    after ``_REJECT_EXACT`` rejections the draw falls back to one exact
    O(C) recompute, still the exact distribution. The fast and exact
    samplers consume the dispatch stream differently (both are valid
    consumptions under the RNG contract); batch == scalar holds for each.
    """

    name = "staleness"
    stateless = False       # lag table — checkpointed via state_arrays
    checkpoint_state = True

    _REJECT_REFRESH = 16    # rejections before recomputing the lag floor
    _REJECT_EXACT = 64      # rejections before one exact O(C) fallback

    def __init__(self, staleness_weight: float = 1.0,
                 size_weight: float = 1.0, avail_weight: float = 1.0,
                 exact: bool = False):
        if staleness_weight < 0.0:
            raise ValueError("staleness_weight must be >= 0")
        self.staleness_weight = float(staleness_weight)
        self.size_weight = float(size_weight)
        self.avail_weight = float(avail_weight)
        self.exact = bool(exact)

    def bind(self, **kw):
        super().bind(**kw)
        self.last_version = np.zeros(self.num_clients, np.float64)
        base = np.ones(self.num_clients, np.float64)
        if self.size_weight != 0.0 and self.data_sizes is not None:
            sizes = np.asarray(self.data_sizes, np.float64)
            base = base * np.power(
                np.maximum(sizes / max(sizes.mean(), 1e-12), 1e-6),
                self.size_weight)
        if self.avail_weight != 0.0 and self.avail_probs is not None:
            base = base * np.power(
                np.clip(np.asarray(self.avail_probs, np.float64), 1e-6, 1.0),
                self.avail_weight)
        self._base = base
        # fast-path proposal structure: cumsum over the STATIC base utility
        # (never updated — lag lives outside it, in last_version)
        self._cum = np.cumsum(base)
        self._total = float(self._cum[-1])
        self._lv_floor = 0.0
        self.sample_stats = {"draws": 0, "proposals": 0,
                             "floor_refreshes": 0, "exact_fallbacks": 0}

    # -- checkpoint round-trip ----------------------------------------------

    def state_arrays(self) -> dict:
        return {"last_version": np.asarray(self.last_version, np.float64),
                "lv_floor": np.asarray([self._lv_floor], np.float64)}

    def load_state_arrays(self, arrays: dict) -> None:
        self.last_version[:] = np.asarray(arrays["last_version"], np.float64)
        self._lv_floor = float(np.asarray(arrays["lv_floor"]).ravel()[0])

    # -- samplers ------------------------------------------------------------

    def _exact_draw(self, v: float) -> int:
        lag = np.maximum(v - self.last_version, 0.0)
        w = self._base * np.power(1.0 + lag, self.staleness_weight)
        return int(self.rng.choice(self.num_clients, p=w / w.sum()))

    def _refresh_floor(self) -> None:
        self.sample_stats["floor_refreshes"] += 1
        self._lv_floor = float(self.last_version.min())

    def _fast_draw(self, v: float) -> int:
        sw = self.staleness_weight
        st = self.sample_stats
        st["draws"] += 1
        env = (1.0 + max(v - self._lv_floor, 0.0)) ** sw
        rejects = 0
        while True:
            st["proposals"] += 1
            u = self.rng.random_sample() * self._total
            c = min(int(np.searchsorted(self._cum, u, side="right")),
                    self.num_clients - 1)
            a = self.rng.random_sample()
            lag = max(v - self.last_version[c], 0.0)
            p = (1.0 + lag) ** sw / env
            if p > 1.0:
                # the floor drifted above the true min (state was mutated
                # externally): re-derive it so the envelope dominates again,
                # then re-test the SAME proposal under the valid envelope
                self._refresh_floor()
                env = (1.0 + max(v - self._lv_floor, 0.0)) ** sw
                p = (1.0 + lag) ** sw / env
            if a < p:
                return c
            rejects += 1
            if rejects == self._REJECT_REFRESH:
                self._refresh_floor()
                env = (1.0 + max(v - self._lv_floor, 0.0)) ** sw
            elif rejects >= self._REJECT_EXACT:
                st["exact_fallbacks"] += 1
                return self._exact_draw(v)

    def select(self, ts, versions):
        versions = np.asarray(versions, np.float64)
        draw = self._exact_draw if self.exact else self._fast_draw
        out = np.empty(len(ts), np.int64)
        for i in range(len(ts)):
            c = draw(versions[i])
            self.last_version[c] = versions[i]
            out[i] = c
        return out


def make_scheduler(sim) -> Scheduler:
    """Build the scheduler named by ``SimConfig.scheduler`` with
    ``SimConfig.scheduler_params`` keyword overrides. The period default
    scales with the latency floor (FLGo's period=20 at latency_lo=10)."""
    params = dict(sim.scheduler_params or {})
    if sim.scheduler == "uniform":
        return UniformRefillScheduler(**params)
    if sim.scheduler == "period":
        params.setdefault("period", max(2.0 * sim.latency_lo, 1.0))
        return PeriodTriggeredScheduler(**params)
    if sim.scheduler == "staleness":
        return StalenessAwareScheduler(**params)
    raise ValueError(f"unknown scheduler {sim.scheduler!r}; "
                     f"known: {SCHEDULERS}")


class Dispatcher:
    """The ONE dispatch path shared by ``run_async`` and ``run_sweep``
    (previously twin inline closures that had already begun to diverge).

    Issues a batch of dispatches as one presorted timeline run: the
    scheduler picks launch times and clients, then latency / availability /
    snapshot / version capture happen here, in the exact historical stream
    order (cids, then latencies, then availability Bernoullis). Stream-
    identical to n scalar dispatches — numpy's legacy array fills consume
    the MT state exactly as n scalar calls, and cid/jitter/ok live on
    separate streams so batching one does not reorder another.
    """

    def __init__(self, sim, streams: SimStreams, scheduler: Scheduler,
                 timeline, server, result, *, batched: bool,
                 data_sizes=None):
        self.sim, self.streams, self.scheduler = sim, streams, scheduler
        self.timeline, self.server, self.result = timeline, server, result
        self.batched = batched
        self.seq = 0
        scheduler.bind(num_clients=sim.num_clients, rng=streams.rng,
                       latency_means=streams.lat_means,
                       avail_probs=streams.avail, data_sizes=data_sizes)

    def dispatch_many(self, ts, snaps=None, versions=None) -> None:
        st = self.streams
        n = len(ts)
        ts = self.scheduler.launch_times(ts)
        if versions is None:
            versions = np.full(n, self.server.version, np.int64)
        else:
            versions = np.asarray(versions, np.int64)
        cids = self.scheduler.select(ts, versions)
        t_done = ts + st.latency.sample_for(cids)
        if st.use_trace:
            oks = st.trace.on_at(cids, ts)
        elif st.use_avail:
            oks = st.avail_rng.rand(n) < st.avail[cids]
        else:
            oks = np.ones(n, bool)
        if snaps is None:
            # (d,) flat vector (cohort), (S, d) lane stack (sweep), or the
            # params pytree (sequential oracle) — shared by the whole batch
            cur = self.server.flat_params if self.batched else self.server.params
            snaps = [cur] * n
        self.timeline.extend_arrays(t_done, np.arange(self.seq, self.seq + n),
                                    cids, versions, oks, snaps)
        self.seq += n
        self.result.launched += n

    def dispatch(self, t: float, snap=None, version=None) -> None:
        self.dispatch_many([t], None if snap is None else [snap],
                           None if version is None else [version])
