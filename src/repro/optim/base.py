"""Hand-rolled pytree optimizers (optax-style (init, update) pairs).

An ``Optimizer`` is a NamedTuple of two functions:
  init(params) -> state
  update(grads, state, params, lr) -> (updates, state)
``updates`` are ADDED to params (sign convention: update = -lr * direction).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.common import tree as tu


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params, updates)


def clip_by_global_norm(grads, max_norm: float):
    norm = tu.tree_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return tu.tree_scale(grads, scale), norm
