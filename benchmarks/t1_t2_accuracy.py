"""Paper Tables 1-2: final accuracy, all algorithms x Dirichlet alpha.

Offline stand-in: the synthetic Gaussian-mixture task replaces
MNIST/FMNIST/CIFAR (DESIGN.md §6); the claim validated is the ORDERING
(FedPSA >= FedBuff and the async baselines, largest gap at alpha=0.1).
Learning curves are stored for t3_aulc.
"""
from __future__ import annotations

import sys

from benchmarks import common

ALGS = ("fedbuff", "fedavg", "fedasync", "ca2fl", "fedfa", "fedpac", "fedpsa")
ALPHAS = (0.1, 0.5, 1.0)


def main(argv=None):
    rows = {}
    curves = {}
    for alpha in ALPHAS:
        for alg in ALGS:
            res = common.run_cell(alg, alpha)
            rows[f"{alg}@a{alpha}"] = res.final_accuracy
            curves[f"{alg}@a{alpha}"] = {
                "times": res.times, "accuracies": res.accuracies,
                "aulc": res.aulc,
            }
            print(f"t1_t2,{alg},alpha={alpha},{res.final_accuracy:.4f},"
                  f"{res.wall_s:.0f}s")
    common.save("t1_t2_accuracy", rows)
    common.save("t3_curves", curves)
    # qualitative claim check (paper Table 2 ordering at alpha=0.1)
    claim = rows["fedpsa@a0.1"] > rows["fedasync@a0.1"] and \
        rows["fedpsa@a0.1"] > rows["fedfa@a0.1"]
    print(f"t1_t2,claim_fedpsa_beats_async_baselines_a0.1,{claim}")
    return rows


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
