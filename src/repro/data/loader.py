"""Client-side data loading: epoch iterators + device-resident stacking.

``ClientDataset`` is the per-client host view (shuffled epoch batches).
``StackedClients`` is the cohort engine's device view: every client's data
padded into one ``(C, n_max, ...)`` slab with sizes and validity masks, so
local training for a whole cohort is a single gather + vmapped scan instead
of C python loops.

Both views draw batch order from ``epoch_batch_indices`` — the one shuffle
routine — so the vectorized engine visits exactly the batches the legacy
per-client loop would (same ``np.random.RandomState`` stream, same
drop-last rule), which is what makes the 1e-5 parity tests meaningful.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Sequence

import numpy as np

from repro.data.synthetic import SyntheticClassification


def epoch_batch_indices(n: int, num_epochs: int, batch_size: int,
                        seed: int) -> np.ndarray:
    """Batch schedule for one client: ``(steps, bs)`` int32 indices into its
    ``n`` samples, ``bs = min(batch_size, n)``, drop-last, one fresh
    permutation per epoch from ``RandomState(seed)``."""
    rng = np.random.RandomState(seed)
    bs = min(batch_size, n)
    m = n // bs                       # drop-last batch count per epoch
    out = np.empty((num_epochs * m, bs), np.int32)
    for e in range(num_epochs):
        out[e * m:(e + 1) * m] = rng.permutation(n)[:m * bs].reshape(m, bs)
    return out


@dataclass
class ClientDataset:
    data: SyntheticClassification

    def __len__(self):
        return len(self.data)

    def epochs(self, num_epochs: int, batch_size: int, seed: int) -> Iterator[dict]:
        for idx in epoch_batch_indices(len(self.data), num_epochs,
                                       batch_size, seed):
            yield {"x": self.data.x[idx].astype(np.float32),
                   "y": self.data.y[idx].astype(np.int32)}


@dataclass
class StackedClients:
    """All clients' data as one padded slab (the cohort engine's layout).

    ``x[c, :sizes[c]]`` are client ``c``'s real samples; rows beyond that are
    zero padding with ``mask`` False. Padding never reaches a loss term: the
    batch schedules index only real rows, and ragged batch tails are masked
    inside the engine's loss.
    """
    x: np.ndarray        # (C, n_max, ...) float32
    y: np.ndarray        # (C, n_max) int32
    sizes: np.ndarray    # (C,) int32 true per-client sample counts
    mask: np.ndarray     # (C, n_max) bool — True on real rows
    num_classes: int

    def __len__(self):
        return self.x.shape[0]

    @property
    def n_max(self) -> int:
        return self.x.shape[1]

    @classmethod
    def from_datasets(cls, datasets: Sequence[ClientDataset]) -> "StackedClients":
        sizes = np.asarray([len(d) for d in datasets], np.int32)
        n_max = int(sizes.max())
        feat = datasets[0].data.x.shape[1:]
        C = len(datasets)
        x = np.zeros((C, n_max) + feat, np.float32)
        y = np.zeros((C, n_max), np.int32)
        mask = np.zeros((C, n_max), bool)
        for c, d in enumerate(datasets):
            n = sizes[c]
            x[c, :n] = d.data.x.astype(np.float32)
            y[c, :n] = d.data.y.astype(np.int32)
            mask[c, :n] = True
        return cls(x=x, y=y, sizes=sizes, mask=mask,
                   num_classes=datasets[0].data.num_classes)


def batch_iterator(ds: SyntheticClassification, batch_size: int,
                   seed: int = 0) -> Iterator[dict]:
    """Endless shuffled batches (evaluation/training streams)."""
    rng = np.random.RandomState(seed)
    n = len(ds)
    while True:
        order = rng.permutation(n)
        for start in range(0, n - batch_size + 1, batch_size):
            idx = order[start:start + batch_size]
            yield {"x": ds.x[idx].astype(np.float32),
                   "y": ds.y[idx].astype(np.int32)}
