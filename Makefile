# Repo CI entry points. `make test` is the tier-1 gate from ROADMAP.md.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast test-multidevice golden golden-regen golden-check \
	bench-smoke bench bench-sim bench-sweep bench-pop bench-sched \
	bench-kernel roofline

test:
	$(PY) -m pytest -x -q

# The tier-1 subset: everything auto-marked tier1 by tests/conftest.py
# (i.e. neither slow paper-world sims nor multidevice layouts).
test-fast:
	$(PY) -m pytest -x -q -m tier1

# Multidevice tier: the sharded-layout tests on 4 virtual CPU devices.
test-multidevice:
	XLA_FLAGS="--xla_force_host_platform_device_count=4" \
		$(PY) -m pytest -x -q -m multidevice

# Golden-trajectory suite: every policy's checked-in digest stream, on the
# sequential oracle and the cohort engine (add test-multidevice for the
# sharded paths).
golden:
	$(PY) -m pytest -x -q tests/test_golden.py

# Re-derive tests/golden/*.json from the sequential oracle after an
# INTENTIONAL numerical change, then commit the diff (CI fails on stale
# digests).
golden-regen:
	$(PY) tests/test_golden.py --regen

# CI staleness gate: re-derive the oracle trajectories and compare against
# the COMMITTED digests within tolerance (robust to float low-bit drift
# across BLAS/SIMD builds; fails when a numerical change landed without a
# committed golden-regen).
golden-check:
	$(PY) tests/test_golden.py --check

# Kernel + server-step microbenchmarks; writes artifacts/bench/*.json
# including BENCH_server_step.json (legacy ingest vs fused jitted step).
bench-smoke:
	$(PY) -m benchmarks.kernel_micro

# Grouped member-GEMM vs the vmapped member path at production d_model from
# the configs/ zoo, plus the fed-lm sketch compile-time cells (unrolled
# baseline vs vectorized; gate: >= 3x); writes
# artifacts/bench/BENCH_grouped_matmul.json.
bench-kernel:
	$(PY) -c "from benchmarks.kernel_micro import bench_grouped_matmul as b; b()"

# Roofline table: generate fresh dry-run records for two cheap configs-zoo
# cells (the dry-run MUST be its own process: it forces 512 host devices via
# XLA_FLAGS at import), then render. Writes artifacts/roofline_pod.json.
roofline:
	$(PY) -m repro.launch.dryrun --arch internvl2-1b --shape train_4k --mesh pod
	$(PY) -m repro.launch.dryrun --arch xlstm-350m --shape train_4k --mesh pod
	$(PY) -m benchmarks.roofline

# Simulator dispatch throughput: legacy per-client loop vs the cohort
# engine; writes artifacts/bench/BENCH_sim_throughput.json, then the
# per-model-family sweep (paper MLP + the fed-lm dense/ssm/moe smokes) to
# BENCH_sim_throughput_family.json. Narrow with e.g. SIM_BENCH_CLIENTS=50,
# SIM_BENCH_FAMILIES=..., SIM_BENCH_FAMILY_CLIENTS=64.
bench-sim:
	$(PY) -m benchmarks.sim_throughput
	$(PY) -m benchmarks.sim_throughput --family

# Fleet sweep throughput: S-lane run_sweep vs a python loop of standalone
# runs; writes artifacts/bench/BENCH_sweep_throughput.json (gate: >= 3x
# aggregate run-throughput at S=8 on the overhead-bound fedasync cell).
# Narrow with SWEEP_BENCH_LANES=4 for a smoke run.
bench-sweep:
	$(PY) -m benchmarks.sweep_throughput

# Population-scale dispatch cost: C=5k / 100k / 1M lazy populations
# through the streaming cohort engine at a fixed in-flight count (pop-1m
# runs with async shard prefetch on); writes
# artifacts/bench/BENCH_population.json with peak host RSS + full slab
# serving stats per cell and the staleness-select fast-vs-exact column
# (gates: per-dispatch <= 1.3x across adjacent cells, pop-1m wall within
# budget, fast staleness sampler >= 10x the exact loop at C=100k, RSS set
# by shard geometry not C). Narrow with
# POP_BENCH_PRESETS=pop-smoke,pop-1m-smoke POP_BENCH_TARGET=200 for the
# CI cells.
bench-pop:
	$(PY) -m benchmarks.population_throughput

# Scheduler x staleness-metric operating points: every dispatch scheduler x
# asyncfeded distance metric x concurrency x tolerance cell as seed-lane
# sweeps on the paper protocol, with a FedPSA AULC baseline per
# (scheduler, concurrency); writes
# artifacts/bench/BENCH_sched_staleness.json. Narrow with
# SCHED_BENCH_PRESET=sched-smoke for the CI cell.
bench-sched:
	$(PY) -m benchmarks.sched_staleness

bench:
	$(PY) -m benchmarks.run
