"""Trip-count-aware HLO analyzer vs known-cost programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_plain_matmul_matches_xla():
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    comp = _compile(lambda x, y: x @ y, a, b)
    r = analyze(comp.as_text(), 1)
    want = 2 * 256 * 512 * 128
    assert abs(r["flops_per_device"] - want) / want < 0.01
    ca = comp.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax: one dict per device
        ca = ca[0]
    assert abs(r["flops_per_device"] - ca["flops"]) / want < 0.01


def test_scan_multiplies_trip_count():
    def f(c, xs):
        return jax.lax.scan(lambda c, x: (c @ x, None), c, xs)[0]
    c = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    for n in (3, 17):
        xs = jax.ShapeDtypeStruct((n, 64, 64), jnp.float32)
        r = analyze(_compile(f, c, xs).as_text(), 1)
        want = n * 2 * 64 ** 3
        assert abs(r["flops_per_device"] - want) / want < 0.05, (n, r)
        assert r["unparsed_loops"] == 0


def test_nested_scan():
    def g(c, xs):
        def outer(c, x):
            return jax.lax.scan(lambda c2, x2: (c2 @ x2, None), c, x)[0], None
        return jax.lax.scan(outer, c, xs)[0]
    c = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    xs = jax.ShapeDtypeStruct((5, 7, 32, 32), jnp.float32)
    r = analyze(_compile(g, c, xs).as_text(), 1)
    want = 35 * 2 * 32 ** 3
    assert abs(r["flops_per_device"] - want) / want < 0.05


def test_scan_bytes_count_slices_not_buffers():
    """Per-iteration traffic = slice bytes, not the whole stacked buffer."""
    n, d = 64, 128
    def f(c, xs):
        return jax.lax.scan(lambda c, x: (c + x, c * 2.0), c, xs)
    c = jax.ShapeDtypeStruct((d,), jnp.float32)
    xs = jax.ShapeDtypeStruct((n, d), jnp.float32)
    r = analyze(_compile(f, c, xs).as_text(), 1)
    buffer_bytes = n * d * 4
    # upper bound: a few slice reads/writes per iter ~ O(n * d * 4) total,
    # far below n * buffer_bytes if buffers were miscounted
    assert r["bytes_per_device"] < 20 * buffer_bytes, r["bytes_per_device"]


def test_backward_counts_more_than_forward():
    def loss(w, x):
        h = jnp.tanh(x @ w)
        return jnp.sum(h ** 2)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    fwd = analyze(_compile(loss, w, x).as_text(), 1)
    bwd = analyze(_compile(jax.grad(loss), w, x).as_text(), 1)
    assert bwd["flops_per_device"] > 1.5 * fwd["flops_per_device"]


def test_transcendentals_counted():
    x = jax.ShapeDtypeStruct((1000,), jnp.float32)
    r = analyze(_compile(lambda v: jnp.exp(v), x).as_text(), 1)
    assert r["transcendentals"] >= 1000
