"""Quickstart: FedPSA vs FedBuff, 3 seeds each, in two batched simulations.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the public API end to end: build data -> partition -> pick the
paper's hyperparameters -> run each algorithm's 3 seeds as ONE ``run_sweep``
call (the seeds ride a shared event timeline as vmapped "lanes", so the
whole multi-seed comparison costs ~one simulation per algorithm instead of
three) -> compare per-seed and mean±std accuracy.
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core import PSAConfig
from repro.data import (ClientDataset, dirichlet_partition,
                        make_calibration_batch, make_classification,
                        train_test_split)
from repro.federated import SimConfig, SweepConfig, run_sweep
from repro.models import model as M

SEEDS = [0, 1, 2]


def main():
    # 1. Task: synthetic 10-class Gaussian mixture, Dirichlet(0.1) split
    full = make_classification(8_000, num_classes=10, dim=32, seed=0,
                               class_sep=0.7)
    train, test = train_test_split(full, test_frac=0.1)
    parts = dirichlet_partition(train, num_clients=30, alpha=0.1, seed=0)
    clients = [ClientDataset(train.subset(ix)) for ix in parts]

    # 2. Shared calibration batch: pure Gaussian noise (paper Table 5 shows
    #    this matches real data, with zero privacy cost)
    calib = make_calibration_batch(train, batch_size=64, source="gaussian")

    # 3. Model + the paper's hyperparameters
    cfg = get_config("paper-synthetic-mlp")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    sim = SimConfig(num_clients=30, concurrency=0.2, horizon=30_000,
                    eval_every=6_000, seed=0)
    psa = PSAConfig(buffer_size=5, queue_len=50, gamma=5.0, delta=0.5,
                    sketch_k=16)

    # 4. The seed sweep: per-lane model-init AND batch-shuffle seeds over a
    #    shared event timeline — one compiled grid per algorithm
    sweep = SweepConfig(model_seeds=SEEDS, data_seeds=SEEDS)

    for alg in ("fedbuff", "fedpsa"):
        res = run_sweep(alg, cfg, params, clients, test, sim, sweep,
                        psa_cfg=psa, calib_batch=calib)
        mean, std = res.accuracy_mean_std()
        per_lane = "  ".join(
            f"seed{s}={a:.3f}" for s, a in zip(SEEDS, res.final_accuracy))
        print(f"{alg:8s} {per_lane}  ->  {mean:.3f}±{std:.3f}  "
              f"(AULC {np.mean(res.aulc):.3f}, "
              f"global updates {res.versions})")


if __name__ == "__main__":
    main()
