from repro.federated.simulator import (
    SimConfig,
    SimResult,
    SweepConfig,
    SweepResult,
    run_algorithm,
    run_async,
    run_fedavg,
    run_sweep,
    make_sketch_fn,
    make_sketch_fn_flat,
    ALGORITHMS,
    ENGINES,
)
from repro.federated.cohort import CohortEngine, StreamingCohortEngine
from repro.federated.timeline import Timeline
from repro.federated.servers import (make_server, make_lane_server,
                                     LanePolicyServer, PolicyServer,
                                     ShardedPolicyServer, server_state_specs)
from repro.federated.policies import (
    Arrival,
    Policy,
    PolicyParams,
    ServerState,
    StepInfo,
    make_hyper,
    make_policy,
    POLICY_NAMES,
)
from repro.federated.scheduler import (SCHEDULERS, Dispatcher, Scheduler,
                                       PeriodTriggeredScheduler,
                                       StalenessAwareScheduler,
                                       UniformRefillScheduler,
                                       make_scheduler, make_streams)
from repro.federated.legacy import make_legacy_server
from repro.federated.client import local_update
from repro.federated.latency import (AvailabilityTrace,
                                     make_availability_trace,
                                     make_latency_sampler,
                                     per_client_availability,
                                     per_client_latency)
