"""Serving demo: prefill + batched greedy decode with the KV/state cache.

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-350m-smoke \
        --batch 4 --prompt-len 32 --gen 16

Runs on CPU with the reduced config by default; pass a full arch id plus
--dry to lower/compile the serve path for the production mesh instead of
executing it (equivalent to dryrun.py on the decode shapes).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.sharding import SINGLE_DEVICE_RULES
from repro.configs import get_config
from repro.models import model as model_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    assert cfg.has_decode, f"{cfg.name} is encoder-only"
    rules = SINGLE_DEVICE_RULES
    key = jax.random.PRNGKey(args.seed)
    params = model_lib.init_params(key, cfg)
    max_len = args.prompt_len + args.gen + (
        cfg.num_prefix_tokens if cfg.frontend == "vision" else 0)

    toks = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.num_prefix_tokens, cfg.d_model), jnp.float32)

    prefill = jax.jit(lambda p, b: model_lib.prefill(p, b, cfg, rules, max_len=max_len))
    decode = jax.jit(lambda p, c, t, pos: model_lib.decode_step(p, c, t, pos, cfg, rules))

    t0 = time.time()
    cache, logits = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    pos0 = args.prompt_len + (cfg.num_prefix_tokens if cfg.frontend == "vision" else 0)
    out = [jnp.argmax(logits, axis=-1)[:, None]]
    t0 = time.time()
    for i in range(args.gen - 1):
        cache, lg = decode(params, cache, out[-1], jnp.int32(pos0 + i))
        out.append(jnp.argmax(lg[:, 0], axis=-1)[:, None])
    tokens = jnp.concatenate(out, axis=1)
    tokens.block_until_ready()
    t_decode = time.time() - t0
    print(f"[serve] {cfg.name}: prefill({args.batch}x{args.prompt_len}) "
          f"{t_prefill*1e3:.1f} ms; {args.gen} decode steps "
          f"{t_decode*1e3:.1f} ms ({t_decode/max(args.gen-1,1)*1e3:.1f} ms/tok)")
    print("[serve] generated token ids:\n", np.asarray(tokens))


if __name__ == "__main__":
    main()
