"""Paper Fig. 6 / §6.6: kappa as a behavioral-staleness indicator.

During a FedPSA run, for every received update we record
(kappa_i, align_i = cos(grad(w_client; D_test), grad(w_server; D_test))).
Claims validated: (1) weak-but-positive sample-level correlation, (2) strong
positive correlation of the kappa-binned mean alignment (bin width 0.1).
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import tree as tu
from repro.common.sharding import SINGLE_DEVICE_RULES as R
from repro.core import PSAConfig, cosine
from repro.federated import run_algorithm, make_sketch_fn
from repro.models import model as model_lib
from benchmarks import common


def main(argv=None):
    cfg, clients, test, calib, params = common.world(0.1)
    psa = PSAConfig()
    sketch_fn = make_sketch_fn(cfg, calib["gaussian"], psa)

    rng = np.random.RandomState(0)
    ix = rng.choice(len(test), size=min(512, len(test)), replace=False)
    test_batch = {"x": jnp.asarray(test.x[ix]), "y": jnp.asarray(test.y[ix])}

    @jax.jit
    def grad_fn(p):
        return jax.grad(lambda q: model_lib.loss_fn(q, test_batch, cfg, R))(p)

    pairs = []

    def hook(server, w_client, delta, meta, t):
        g_c, _ = tu.flatten_to_vector(grad_fn(w_client))
        g_s, _ = tu.flatten_to_vector(grad_fn(server.params))
        align = float(cosine(g_c, g_s))
        kappa = float(cosine(meta["sketch"], server.psa.global_sketch))
        pairs.append((kappa, align))

    run_algorithm("fedpsa", cfg, params, clients, test, common.sim_config(),
                  psa_cfg=psa, calib_batch=calib["gaussian"],
                  receive_hook=hook)

    k = np.array([p[0] for p in pairs])
    a = np.array([p[1] for p in pairs])
    pearson = float(np.corrcoef(k, a)[0, 1])

    def spearman(x, y):
        rx = np.argsort(np.argsort(x)).astype(float)
        ry = np.argsort(np.argsort(y)).astype(float)
        return float(np.corrcoef(rx, ry)[0, 1])

    sp = spearman(k, a)

    # binned means (bin width 0.1 as in the paper)
    bins = np.arange(-1.0, 1.01, 0.1)
    which = np.digitize(k, bins)
    centers, means, counts = [], [], []
    for b in np.unique(which):
        mask = which == b
        if mask.sum() >= 3:
            centers.append(float(bins[min(b, len(bins) - 1)] - 0.05))
            means.append(float(a[mask].mean()))
            counts.append(int(mask.sum()))
    b_pearson = float(np.corrcoef(centers, means)[0, 1]) if len(centers) > 2 else float("nan")
    b_spearman = spearman(np.array(centers), np.array(means)) if len(centers) > 2 else float("nan")

    rows = {
        "n_pairs": len(pairs),
        "pearson_samplewise": pearson,
        "spearman_samplewise": sp,
        "pearson_binned": b_pearson,
        "spearman_binned": b_spearman,
        "bins": {"centers": centers, "mean_align": means, "counts": counts},
    }
    for key in ("n_pairs", "pearson_samplewise", "spearman_samplewise",
                "pearson_binned", "spearman_binned"):
        print(f"f6,{key},{rows[key]}")
    common.save("f6_kappa_alignment", rows)
    print(f"f6,claim_binned_correlation_stronger,"
          f"{not np.isnan(b_pearson) and b_pearson > pearson}")
    return rows


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
