"""Client partitioning: Dirichlet label-skew (the paper's protocol), IID,
and a document-level split of token streams for the federated LM scenario."""
from __future__ import annotations

from typing import List

import numpy as np

from repro.data.synthetic import SyntheticClassification


def dirichlet_partition(ds: SyntheticClassification, num_clients: int,
                        alpha: float, seed: int = 0,
                        min_size: int = 2) -> List[np.ndarray]:
    """Standard Dirichlet(alpha) label-skew split: for each class, sample a
    client proportion vector ~ Dir(alpha) and scatter that class's samples.
    Smaller alpha => more heterogeneous. Retries until every client has at
    least ``min_size`` samples (as in common FL benchmarks)."""
    rng = np.random.RandomState(seed)
    n = len(ds)
    for _attempt in range(100):
        idx_by_client = [[] for _ in range(num_clients)]
        for c in range(ds.num_classes):
            idx_c = np.where(ds.y == c)[0]
            rng.shuffle(idx_c)
            p = rng.dirichlet(np.full(num_clients, alpha))
            cuts = (np.cumsum(p) * len(idx_c)).astype(int)[:-1]
            for client, part in enumerate(np.split(idx_c, cuts)):
                idx_by_client[client].extend(part.tolist())
        sizes = [len(ix) for ix in idx_by_client]
        if min(sizes) >= min_size:
            return [np.asarray(sorted(ix)) for ix in idx_by_client]
    raise RuntimeError("dirichlet_partition failed to satisfy min_size")


def skewed_client_sizes(num_clients: int, *, mean: int = 64,
                        spread: float = 0.6, lo: int = 16, hi: int = 512,
                        seed: int = 0) -> np.ndarray:
    """Per-client dataset sizes for a lazy population: log-normal around
    ``mean`` (clipped to [lo, hi]) so a minority of clients hold most of the
    data — the size analogue of the Dirichlet label-skew protocol. One
    vectorized draw, O(C) at C=10^6; deterministic in (args, seed)."""
    if not (0 < lo <= mean <= hi):
        raise ValueError(f"need 0 < lo <= mean <= hi, got {lo}/{mean}/{hi}")
    rng = np.random.RandomState(seed)
    raw = np.exp(rng.normal(np.log(float(mean)), spread, size=num_clients))
    return np.clip(np.round(raw), lo, hi).astype(np.int64)


def iid_partition(ds: SyntheticClassification, num_clients: int,
                  seed: int = 0) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    idx = rng.permutation(len(ds))
    return [np.asarray(sorted(part)) for part in np.array_split(idx, num_clients)]


def document_partition(tokens: np.ndarray, num_clients: int, seq_len: int, *,
                       doc_len: int = 0, alpha: float = 0.0,
                       seed: int = 0) -> List[np.ndarray]:
    """Document-level split of a token stream for federated LM fine-tuning.

    The stream is chopped into contiguous *documents* of ``doc_len`` tokens
    (default ``4 * seq_len``); whole documents are dealt to clients —
    near-uniformly when ``alpha <= 0``, with Dirichlet(alpha)-drawn
    proportions otherwise (small alpha => heavily skewed shard sizes, the
    LM analogue of the label-skew protocol; every client keeps >= 1
    document). Each client's documents are then windowed into
    non-overlapping ``seq_len`` sequences — windows never straddle a
    document boundary, so no client trains across another client's text.

    Returns one ``(n_i, seq_len)`` int32 array per client.
    """
    tokens = np.asarray(tokens)
    doc_len = doc_len or 4 * seq_len
    assert doc_len % seq_len == 0, (doc_len, seq_len)
    n_docs = len(tokens) // doc_len
    assert n_docs >= num_clients, \
        f"need >= {num_clients} documents of {doc_len} tokens, have {n_docs}"
    docs = tokens[:n_docs * doc_len].astype(np.int32).reshape(n_docs, doc_len)
    rng = np.random.RandomState(seed)
    order = rng.permutation(n_docs)
    counts = np.ones(num_clients, np.int64)       # min one document each
    rem = n_docs - num_clients
    if rem > 0:
        if alpha > 0:
            p = rng.dirichlet(np.full(num_clients, alpha))
            counts += rng.multinomial(rem, p)
        else:
            counts += np.diff(np.linspace(0, rem, num_clients + 1).astype(int))
    cuts = np.cumsum(counts)[:-1]
    return [part.reshape(-1, seq_len)
            for part in np.split(docs[order], cuts)]
