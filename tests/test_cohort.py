"""Cohort client engine: parity with the legacy per-client loop, and the
batched-arrival simulator against the sequential oracle.

The engine's contract is *exactness*, not approximation: it must visit the
same batches in the same order with the same arithmetic as
``client.local_update``, and the batched drain must reproduce the sequential
event loop's receive order, RNG streams, and per-dispatch lr/seed
assignment. CPU-only, QUICK-world sized.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import tree as tu
from repro.configs import get_config
from repro.core import PSAConfig
from repro.data import (ClientDataset, StackedClients, dirichlet_partition,
                        iid_partition, make_calibration_batch,
                        make_classification, train_test_split)
from repro.federated import SimConfig, run_algorithm
from repro.federated import client as client_lib
from repro.federated.cohort import CohortEngine
from repro.models import model as M


@pytest.fixture(scope="module")
def world():
    cfg = get_config("paper-synthetic-mlp")
    full = make_classification(4_000, 10, 32, seed=0, class_sep=0.7)
    train, test = train_test_split(full, 0.1)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, train, test, params


def _engine_for(cfg, params, datasets, **kw):
    spec = tu.FlatSpec(params)
    stacked = StackedClients.from_datasets(datasets)
    return spec, CohortEngine(cfg, stacked, spec, params, **kw)


def _assert_parity(cfg, params, datasets, *, epochs, batch_size, tol=1e-5,
                   **variant):
    spec, eng = _engine_for(cfg, params, datasets, local_epochs=epochs,
                            batch_size=batch_size, **variant)
    flat = jnp.array(spec.flatten(params), copy=True)
    cids = [0, len(datasets) // 2, len(datasets) - 1, 0]
    lrs = [0.01, 0.008, 0.012, 0.01]
    seeds = [11, 22, 33, 44]
    deltas, w = eng.cohort_update(jnp.stack([flat] * len(cids)), cids, lrs,
                                  seeds)
    for i, (c, lr, s) in enumerate(zip(cids, lrs, seeds)):
        ref, w_ref = client_lib.local_update(
            params, cfg, datasets[c], epochs=epochs, batch_size=batch_size,
            lr=lr, seed=s, **variant)
        err = float(jnp.max(jnp.abs(deltas[i] - spec.flatten(ref))))
        assert err <= tol, (c, err)
        err_w = float(jnp.max(jnp.abs(w[i] - spec.flatten(w_ref))))
        assert err_w <= tol, (c, err_w)


def test_parity_uniform_sizes(world):
    cfg, train, _, params = world
    parts = iid_partition(train, 8, seed=0)       # equal-size shards
    datasets = [ClientDataset(train.subset(ix)) for ix in parts]
    _assert_parity(cfg, params, datasets, epochs=5, batch_size=64)


def test_parity_ragged_sizes(world):
    cfg, train, _, params = world
    parts = dirichlet_partition(train, 8, alpha=0.1, seed=0)  # ragged shards
    datasets = [ClientDataset(train.subset(ix)) for ix in parts]
    sizes = sorted(len(d) for d in datasets)
    assert sizes[0] != sizes[-1], "world not ragged enough to test padding"
    _assert_parity(cfg, params, datasets, epochs=3, batch_size=64)


def test_parity_prox_and_align_variants(world):
    cfg, train, _, params = world
    parts = dirichlet_partition(train, 6, alpha=0.3, seed=1)
    datasets = [ClientDataset(train.subset(ix)) for ix in parts]
    _assert_parity(cfg, params, datasets, epochs=2, batch_size=32, prox=0.5)
    _assert_parity(cfg, params, datasets, epochs=2, batch_size=32, align=0.1)


def test_cohort_padding_rows_are_noops(world):
    """Bucketed padding must not leak into real members' results."""
    cfg, train, _, params = world
    parts = iid_partition(train, 8, seed=0)
    datasets = [ClientDataset(train.subset(ix)) for ix in parts]
    spec, eng = _engine_for(cfg, params, datasets, local_epochs=2,
                            batch_size=64)
    flat = jnp.array(spec.flatten(params), copy=True)
    # B=3 pads to 4; B=3 alone vs as a prefix of B=4 must agree exactly
    d3, _ = eng.cohort_update(jnp.stack([flat] * 3), [0, 1, 2],
                              [0.01] * 3, [5, 6, 7])
    d4, _ = eng.cohort_update(jnp.stack([flat] * 4), [0, 1, 2, 3],
                              [0.01] * 4, [5, 6, 7, 8])
    np.testing.assert_array_equal(np.asarray(d3), np.asarray(d4[:3]))


QUICK = dict(num_clients=16, horizon=10_000, eval_every=5_000, seed=0)


@pytest.fixture(scope="module")
def sim_world():
    cfg = get_config("paper-synthetic-mlp")
    full = make_classification(6_000, 10, 32, seed=0, class_sep=0.7)
    train, test = train_test_split(full, 0.1)
    parts = dirichlet_partition(train, 16, alpha=0.1, seed=0)
    clients = [ClientDataset(train.subset(ix)) for ix in parts]
    calib = make_calibration_batch(train, 64, "gaussian")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, clients, test, calib, params


def _orders(res):
    return [(e["t"], e["client"], e["tau"]) for e in res.receive_log]


@pytest.mark.parametrize("alg", ["fedasync", "fedbuff", "fedpsa", "ca2fl"])
def test_batched_drain_matches_sequential(sim_world, alg):
    """Same receive order, same version count, same final accuracy."""
    cfg, clients, test, calib, params = sim_world
    kw = dict(psa_cfg=PSAConfig(queue_len=10), calib_batch=calib) \
        if alg == "fedpsa" else {}
    seq = run_algorithm(alg, cfg, params, clients, test,
                        SimConfig(engine="sequential", **QUICK), **kw)
    coh = run_algorithm(alg, cfg, params, clients, test,
                        SimConfig(engine="cohort", **QUICK), **kw)
    assert _orders(seq) == _orders(coh)
    assert seq.versions == coh.versions
    assert seq.dispatches == coh.dispatches
    assert seq.times == coh.times
    np.testing.assert_allclose(coh.final_accuracy, seq.final_accuracy,
                               atol=1e-4)
    np.testing.assert_allclose(coh.accuracies, seq.accuracies, atol=1e-4)


def test_batched_drain_deterministic(sim_world):
    cfg, clients, test, calib, params = sim_world
    sim = SimConfig(engine="cohort", **QUICK)
    r1 = run_algorithm("fedbuff", cfg, params, clients, test, sim)
    r2 = run_algorithm("fedbuff", cfg, params, clients, test, sim)
    assert r1.final_accuracy == r2.final_accuracy
    assert _orders(r1) == _orders(r2)
    assert r1.times == r2.times


def test_fedavg_cohort_matches_sequential(sim_world):
    cfg, clients, test, calib, params = sim_world
    seq = run_algorithm("fedavg", cfg, params, clients, test,
                        SimConfig(engine="sequential", **QUICK))
    coh = run_algorithm("fedavg", cfg, params, clients, test,
                        SimConfig(engine="cohort", **QUICK))
    assert seq.versions == coh.versions and seq.dispatches == coh.dispatches
    np.testing.assert_allclose(coh.final_accuracy, seq.final_accuracy,
                               atol=1e-4)


def test_dropout_scenarios(sim_world):
    """Availability dropouts: identical across engines, and the slots keep
    cycling (dropped dispatches re-dispatch instead of starving)."""
    cfg, clients, test, calib, params = sim_world
    base = dict(availability_kind="hetero", dropout_rate=0.3, **QUICK)
    seq = run_algorithm("fedbuff", cfg, params, clients, test,
                        SimConfig(engine="sequential", **base))
    coh = run_algorithm("fedbuff", cfg, params, clients, test,
                        SimConfig(engine="cohort", **base))
    assert seq.dropped == coh.dropped > 0
    assert _orders(seq) == _orders(coh)
    np.testing.assert_allclose(coh.final_accuracy, seq.final_accuracy,
                               atol=1e-4)
    assert coh.dispatches > 0

    nodrop = run_algorithm("fedbuff", cfg, params, clients, test,
                           SimConfig(engine="cohort", **QUICK))
    assert nodrop.dropped == 0
    # dropping work can only reduce how many updates land by the horizon
    assert coh.dispatches <= nodrop.dispatches


def test_slow_fragile_availability(sim_world):
    cfg, clients, test, calib, params = sim_world
    sim = SimConfig(engine="cohort", availability_kind="slow-fragile",
                    dropout_rate=0.25, **QUICK)
    r = run_algorithm("fedasync", cfg, params, clients, test, sim)
    assert r.dropped > 0 and np.isfinite(r.final_accuracy)


def test_policy_without_raw_step_still_runs_batched(sim_world, monkeypatch):
    """A policy registered docs-style without ``raw_step`` (pre-batching
    convention) must still work under the cohort engine — receive_many
    degrades to per-event ingest instead of crashing."""
    import dataclasses as dc
    from repro.federated import policies as pol

    orig = pol.make_policy

    def no_raw(name, spec, **kw):
        return dc.replace(orig(name, spec, **kw), raw_step=None)

    monkeypatch.setattr(pol, "make_policy", no_raw)
    pol._POLICY_CACHE.clear()
    cfg, clients, test, calib, params = sim_world
    coh = run_algorithm("fedasync", cfg, params, clients, test,
                        SimConfig(engine="cohort", **QUICK))
    monkeypatch.undo()
    pol._POLICY_CACHE.clear()
    seq = run_algorithm("fedasync", cfg, params, clients, test,
                        SimConfig(engine="sequential", **QUICK))
    assert _orders(coh) == _orders(seq)
    np.testing.assert_allclose(coh.final_accuracy, seq.final_accuracy,
                               atol=1e-4)


def test_aulc_uses_actual_horizon():
    from repro.federated.simulator import SimResult
    r_day = SimResult(times=[0.0, 43_200.0, 86_400.0],
                      accuracies=[0.0, 0.5, 0.5])
    r_short = SimResult(times=[0.0, 5_000.0, 10_000.0],
                        accuracies=[0.0, 0.5, 0.5])
    # same curve shape => same normalized AULC regardless of horizon
    np.testing.assert_allclose(r_day.aulc, r_short.aulc)
    assert 0.0 < r_short.aulc < 1.0
