"""The functional policy core vs the legacy class-based servers.

Every jit-compiled ``policy.step`` must reproduce its legacy server's
trajectory: identical arrival stream -> identical sequence of global-update
events and global parameters within 1e-5. The legacy oracles live in
``repro.federated.legacy``; the production path is the ``PolicyServer`` shim
over ``repro.federated.policies``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import tree as tu
from repro.core import PSAConfig
from repro.core import sketch as sketch_lib
from repro.federated import legacy, policies, servers


def _params(seed=0):
    rng = np.random.RandomState(seed)
    return {
        "w1": jnp.asarray(rng.randn(6, 4) * 0.3, jnp.float32),
        "b1": jnp.asarray(rng.randn(4) * 0.1, jnp.float32),
        "w2": jnp.asarray(rng.randn(4, 3) * 0.3, jnp.float32),
    }


def _arrival_stream(params, n, seed=1, num_clients=5, k=None):
    """Deterministic (delta, client_params, meta) triples; deltas shrink the
    way SGD updates do so the trajectories stay well-conditioned."""
    rng = np.random.RandomState(seed)
    out = []
    for i in range(n):
        delta = jax.tree_util.tree_map(
            lambda p: jnp.asarray(rng.randn(*p.shape) * 0.05, jnp.float32),
            params)
        client = tu.tree_add(params, delta)
        meta = {"tau": int(rng.randint(0, 4)),
                "client_id": int(rng.randint(num_clients)),
                "data_size": float(rng.randint(5, 50))}
        if k is not None:
            meta["sketch"] = jnp.asarray(rng.randn(k), jnp.float32)
        out.append((delta, client, meta))
    return out


def _max_param_diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y))) for x, y in
               zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


@pytest.mark.parametrize("name,kwargs", [
    ("fedasync", {"alpha": 0.6, "a": 0.5}),
    ("fedbuff", {"buffer_size": 4, "server_lr": 0.9}),
    ("ca2fl", {"buffer_size": 3, "server_lr": 0.8}),
    ("fedfa", {"queue_len": 4, "beta": 0.5}),
    ("fedpac", {"buffer_size": 3}),
])
def test_policy_matches_legacy_trajectory(name, kwargs):
    params = _params()
    kw = dict(kwargs)
    if name == "ca2fl":
        kw["num_clients"] = 5
    srv_legacy = legacy.make_legacy_server(name, params, **kw)
    srv_policy = servers.make_server(name, params, **kw)
    for delta, client, meta in _arrival_stream(params, 25):
        u_legacy = srv_legacy.receive(delta, client, meta)
        u_policy = srv_policy.receive(delta, client, meta)
        assert u_legacy == u_policy
        assert _max_param_diff(srv_legacy.params, srv_policy.params) < 1e-5
    assert srv_legacy.version == srv_policy.version
    assert srv_legacy.version > 0


@pytest.mark.parametrize("name,kwargs,L", [
    # L=1: every push is also a flush (ring degenerates to a single slot)
    ("fedbuff", {"buffer_size": 1}, 1),
    ("ca2fl", {"buffer_size": 1}, 1),
    ("fedfa", {"queue_len": 1}, 1),
    ("fedpac", {"buffer_size": 1}, 1),
    # wrap-around: stream long enough for > 2L pushes through the ring
    ("fedbuff", {"buffer_size": 3}, 3),
    ("ca2fl", {"buffer_size": 4}, 4),
    ("fedfa", {"queue_len": 3}, 3),
])
def test_ring_buffer_edge_cases(name, kwargs, L):
    """Stacked-ring edge cases vs the legacy deque/list oracles: a stream
    whose length is an exact multiple of L (buffer exactly full at the final
    flush) and longer than 2L (slot indices wrap at least twice)."""
    params = _params()
    kw = dict(kwargs)
    if name == "ca2fl":
        kw["num_clients"] = 5
    srv_legacy = legacy.make_legacy_server(name, params, **kw)
    srv_policy = servers.make_server(name, params, **kw)
    n = max(3 * L, 2 * L + 2)
    n -= n % L    # exact multiple: the last arrival lands on a flush
    flushes = 0
    for delta, client, meta in _arrival_stream(params, n):
        u_legacy = srv_legacy.receive(delta, client, meta)
        u_policy = srv_policy.receive(delta, client, meta)
        assert u_legacy == u_policy
        flushes += int(u_policy)
        assert _max_param_diff(srv_legacy.params, srv_policy.params) < 1e-5
    assert srv_legacy.version == srv_policy.version
    if name == "fedfa":
        assert flushes == n          # refreshes on every arrival
    else:
        assert flushes == n // L     # flushes exactly when the ring fills


def test_fedpsa_policy_matches_legacy_trajectory():
    params = _params()
    cfg = PSAConfig(buffer_size=3, queue_len=5, sketch_k=8)
    # raw-parameter sketch: cheap, model-free, shared by both paths
    sketch_fn = jax.jit(
        lambda p: sketch_lib.sketch_tree(p, cfg.sketch_seed, cfg.sketch_k))
    srv_legacy = legacy.make_legacy_server("fedpsa", params, psa_cfg=cfg,
                                           sketch_fn=sketch_fn)
    srv_policy = servers.make_server("fedpsa", params, psa_cfg=cfg,
                                     sketch_fn=sketch_fn)
    for delta, client, meta in _arrival_stream(params, 24, k=cfg.sketch_k):
        u_legacy = srv_legacy.receive(delta, client, meta)
        u_policy = srv_policy.receive(delta, client, meta)
        assert u_legacy == u_policy
        assert _max_param_diff(srv_legacy.params, srv_policy.params) < 1e-5
    assert srv_legacy.version == srv_policy.version > 0
    # logs agree: same uniform->softmax phase switch, same weights
    assert len(srv_legacy.log) == len(srv_policy.log)
    for e_l, e_p in zip(srv_legacy.log, srv_policy.log):
        assert (e_l["temp"] is None) == (e_p["temp"] is None)
        np.testing.assert_allclose(e_l["weights"], e_p["weights"], atol=1e-5)
        np.testing.assert_allclose(e_l["kappas"], e_p["kappas"], atol=1e-5)


def test_fedpsa_ablations_match_legacy():
    params = _params()
    sketch_fn = jax.jit(lambda p: sketch_lib.sketch_tree(p, 7, 8))
    for cfg in (PSAConfig(buffer_size=2, queue_len=3, sketch_k=8,
                          use_thermometer=False),
                PSAConfig(buffer_size=2, queue_len=3, sketch_k=8,
                          server_lr=0.7)):
        srv_legacy = legacy.make_legacy_server("fedpsa", params, psa_cfg=cfg,
                                               sketch_fn=sketch_fn)
        srv_policy = servers.make_server("fedpsa", params, psa_cfg=cfg,
                                         sketch_fn=sketch_fn)
        for delta, client, meta in _arrival_stream(params, 10, k=8):
            srv_legacy.receive(delta, client, meta)
            srv_policy.receive(delta, client, meta)
            assert _max_param_diff(srv_legacy.params, srv_policy.params) < 1e-5


def test_one_device_call_per_arrival():
    """The whole arrival path (ingest + conditional aggregate) is ONE
    compiled step: no per-arrival retracing after the first two shapes."""
    params = _params()
    srv = servers.make_server("fedbuff", params, buffer_size=3)
    stream = _arrival_stream(params, 9)
    for delta, client, meta in stream[:2]:
        srv.receive(delta, client, meta)
    cache_size = getattr(srv.policy.step, "_cache_size", None)
    if cache_size is None:  # private jax API; skip rather than false-fail
        pytest.skip("jit _cache_size unavailable on this jax version")
    stats0 = cache_size()
    for delta, client, meta in stream[2:]:
        srv.receive(delta, client, meta)
    assert cache_size() == stats0  # no retrace, 1 call/arrival


def test_asyncfeded_distance_policy():
    """The pluggability proof: Euclidean-distance staleness damps drifted
    clients and the policy runs through the standard server interface."""
    params = _params()
    srv = servers.make_server("asyncfeded", params, alpha=0.5)
    delta, client, meta = _arrival_stream(params, 1)[0]

    # fresh client: client == params + delta -> full alpha
    srv.receive(delta, client, meta)
    assert srv.version == 1
    assert abs(srv.log[-1]["weight"] - 0.5) < 1e-5

    # drifted client: same delta but a base model far from the global
    far_client = tu.tree_add(client, tu.tree_scale(params, 5.0))
    srv.receive(delta, far_client, meta)
    assert srv.log[-1]["weight"] < 0.5 * 0.5
    assert bool(jnp.all(tu.tree_all_finite(srv.params)))


def test_asyncfeded_distance_metric_family():
    """cosine/sketch variants of the distance family: every metric gives a
    fresh client the full alpha; drifted clients are damped; the sketch
    metric's JL estimate tracks the exact l2 rule."""
    params = _params()
    delta, client, meta = _arrival_stream(params, 1)[0]
    far_client = tu.tree_add(client, tu.tree_scale(params, 5.0))

    weights = {}
    for metric in ("l2", "cosine", "sketch"):
        srv = servers.make_server("asyncfeded", params, alpha=0.5,
                                  metric=metric)
        srv.receive(delta, client, meta)          # fresh: full alpha
        assert abs(srv.log[-1]["weight"] - 0.5) < 1e-5, metric
        srv.receive(delta, far_client, meta)      # drifted: damped
        weights[metric] = srv.log[-1]["weight"]
        assert weights[metric] < 0.5, metric
        assert bool(jnp.all(tu.tree_all_finite(srv.params)))
    # sketch approximates the exact l2 ratio (k=16 JL estimate: loose but
    # same order of magnitude)
    assert weights["sketch"] == pytest.approx(weights["l2"], rel=1.0)

    with pytest.raises(ValueError, match="unknown distance metric"):
        policies.asyncfeded_policy(tu.FlatSpec(params), metric="manhattan")


def test_asyncfeded_l2_unchanged_by_family_refactor():
    """The default metric must reproduce the original AsyncFedED arithmetic
    exactly (golden streams pin it): compare against the closed form."""
    params = _params()
    spec = tu.FlatSpec(params)
    delta, client, meta = _arrival_stream(params, 1, seed=9)[0]
    far_client = tu.tree_add(client, tu.tree_scale(params, 3.0))
    srv = servers.make_server("asyncfeded", params, alpha=0.6)
    g0 = srv.flat_params
    srv.receive(delta, far_client, meta)
    dw = spec.flatten(delta)
    dist = float(jnp.linalg.norm(spec.flatten(far_client) - g0))
    norm = float(jnp.linalg.norm(dw))
    s = 0.6 * min(1.0, norm / (dist + 1e-8))
    assert srv.log[-1]["weight"] == pytest.approx(s, rel=1e-6)


def test_dist_mode_is_a_lane_hyperparameter():
    """l2 and cosine share one compiled step with the metric as a traced
    lane value: a 2-lane server with per-lane dist_mode must reproduce the
    two single-metric servers."""
    params = _params()
    spec = tu.FlatSpec(params)
    delta, client, meta = _arrival_stream(params, 1)[0]
    far_client = tu.tree_add(client, tu.tree_scale(params, 5.0))
    lane_srv = servers.make_lane_server(
        "asyncfeded", [params, params],
        [dict(dist_mode="l2"), dict(dist_mode="cosine")], num_clients=5)
    dws = jnp.broadcast_to(spec.flatten(delta), (2, 1, spec.size))
    wis = jnp.broadcast_to(spec.flatten(far_client), (2, 1, spec.size))
    lane_srv.receive_many(dws, wis, [meta["client_id"]],
                          [meta["data_size"]], [0])
    lanes = np.asarray(lane_srv.flat_params)

    for k, metric in enumerate(("l2", "cosine")):
        srv = servers.make_server("asyncfeded", params, metric=metric)
        srv.receive(delta, far_client, meta)
        np.testing.assert_allclose(lanes[k], np.asarray(srv.flat_params),
                                   rtol=1e-5, atol=1e-6)
    # the two metrics genuinely disagree on a drifted client
    assert float(np.max(np.abs(lanes[0] - lanes[1]))) > 1e-6


def test_make_hyper_dist_mode_coercion():
    from repro.core import psa as psa_lib
    assert float(policies.make_hyper(dist_mode="l2").dist_mode) == \
        psa_lib.DIST_MODE_L2
    assert float(policies.make_hyper(dist_mode="cosine").dist_mode) == \
        psa_lib.DIST_MODE_COSINE
    with pytest.raises(ValueError, match="sketch"):
        policies.make_hyper(dist_mode="sketch")


def test_asyncfeded_runs_in_simulator():
    from repro.configs import get_config
    from repro.data import (ClientDataset, dirichlet_partition,
                            make_classification, train_test_split)
    from repro.federated import SimConfig, run_algorithm
    from repro.models import model as M

    cfg = get_config("paper-synthetic-mlp")
    full = make_classification(2000, 10, 32, seed=0, class_sep=0.7)
    train, test = train_test_split(full, 0.1)
    parts = dirichlet_partition(train, 8, alpha=0.3, seed=0)
    clients = [ClientDataset(train.subset(ix)) for ix in parts]
    mp = M.init_params(jax.random.PRNGKey(0), cfg)
    sim = SimConfig(num_clients=8, horizon=6_000, eval_every=3_000, seed=0)
    r = run_algorithm("asyncfeded", cfg, mp, clients, test, sim)
    assert r.dispatches > 0
    assert r.versions == r.dispatches  # immediate-mix: update per receipt
    assert np.isfinite(r.final_accuracy)


def test_flat_spec_roundtrip():
    params = _params()
    spec = tu.FlatSpec(params)
    vec = spec.flatten(params)
    assert vec.shape == (spec.size,) and vec.dtype == jnp.float32
    back = spec.unflatten(vec)
    assert _max_param_diff(params, back) == 0.0
    # layout matches the legacy one-shot flattener
    vec2, _ = tu.flatten_to_vector(params)
    np.testing.assert_allclose(np.asarray(vec), np.asarray(vec2))


def test_single_leaf_params_survive_donation():
    """flatten of a single f32 leaf can alias the caller's buffer; the
    donating step must not invalidate it (init copies)."""
    params = {"w": jnp.ones((4,), jnp.float32)}
    srv = servers.make_server("fedasync", params)
    srv.receive({"w": jnp.full((4,), 0.1)}, {"w": jnp.full((4,), 1.1)},
                {"tau": 0})
    assert float(params["w"][0]) == 1.0  # caller's array still alive


def test_fedpsa_requires_sketch_in_meta():
    cfg = PSAConfig(buffer_size=2, sketch_k=8)
    sketch_fn = jax.jit(lambda p: sketch_lib.sketch_tree(p, 0, 8))
    srv = servers.make_server("fedpsa", _params(), psa_cfg=cfg,
                              sketch_fn=sketch_fn)
    delta, client, meta = _arrival_stream(_params(), 1)[0]
    with pytest.raises(KeyError, match="sketch"):
        srv.receive(delta, client, meta)  # meta has no 'sketch'


def test_ca2fl_rejects_out_of_range_client_id():
    srv = servers.make_server("ca2fl", _params(), num_clients=2)
    delta, client, meta = _arrival_stream(_params(), 1)[0]
    meta["client_id"] = 5
    with pytest.raises(ValueError, match="client_id"):
        srv.receive(delta, client, meta)


def test_policy_registry_covers_all_async_algorithms():
    from repro.federated.simulator import ALGORITHMS
    for name in ALGORITHMS:
        if name == "fedavg":  # synchronous, runs round-based
            continue
        assert name in policies.POLICY_NAMES
