"""Event-driven virtual-time AFL simulator (FLGO-style: 86,400 units/day).

Asynchronous runners keep ``concurrency`` clients training at all times: a
heap of completion events; on completion the server ingests the update, a
new client is sampled and dispatched with the *current* global model, and
the learning curve is sampled on a fixed virtual-time grid. The synchronous
FedAvg runner advances rounds at the pace of each round's slowest client —
exactly the straggler behaviour the paper contrasts against.

Two client engines drive the same event semantics:

``cohort`` (default)  completions drain in device batches. Every event's
    training depends only on its dispatch snapshot, so all events due before
    the earliest possible completion of any re-dispatch (``t_first +
    latency_lo``) form a *wave* that trains as ONE compiled call
    (``federated.cohort.CohortEngine`` — vmap over clients, scan over local
    steps, flat parameter layout end to end: dispatch snapshots are the
    server's flat (d,) vector, never a pytree). Receives then apply strictly
    in completion order, so the receive order, per-dispatch lr/seed
    assignment, and RNG streams are identical to the sequential engine.

``sequential``  the legacy reference loop: one ``client.local_update``
    (python loop of per-batch jit calls) per completion. Kept as the
    numerical oracle the batched engine is pinned against.

The paper's defaults (§6.1): 50 clients, 20% concurrency/sampling, 5 local
epochs, batch 64, SGD lr 0.01 with x0.999 decay per (dispatch) round,
latency ~ U(10, 500). Client availability (FLGo-style intermittent
dropouts) is modelled per dispatch: a failed dispatch holds its concurrency
slot for the full response time, then re-dispatches without a receive.
"""
from __future__ import annotations

import heapq
import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import tree as tu
from repro.core import psa as psa_lib
from repro.data.loader import ClientDataset, StackedClients
from repro.federated import client as client_lib
from repro.federated import servers as servers_lib
from repro.federated.cohort import CohortEngine
from repro.federated.latency import per_client_availability, per_client_latency
from repro.models import model as model_lib
from repro.models import registry
from repro.models.config import ModelConfig

ENGINES = ("cohort", "sequential")

_FALLBACK_WARNED = set()


def _resolve_engine(sim: "SimConfig", cfg: ModelConfig) -> str:
    """Validate ``sim.engine`` and pick the engine that can train ``cfg``.

    The cohort engine compiles any family in the model-family registry
    (``models.registry``); unregistered families fall back to the sequential
    per-client loop (the generic ``client.local_update``) rather than
    crashing on the default ``engine="cohort"`` — with a one-time warning,
    because silently comparing a cohort run against a sequential fallback
    would corrupt benchmarks. The engine actually used is recorded on
    ``SimResult.engine``.
    """
    if sim.engine not in ENGINES:
        raise ValueError(f"unknown engine {sim.engine!r}; known: {ENGINES}")
    if sim.engine == "cohort" and not registry.is_registered(cfg.family):
        if cfg.family not in _FALLBACK_WARNED:
            _FALLBACK_WARNED.add(cfg.family)
            warnings.warn(
                f"model family {cfg.family!r} is not in the model-family "
                f"registry (registered: {registry.registered_families()}); "
                f"engine='cohort' falls back to the sequential loop for it. "
                f"Register the family (models/registry.py) to compile it.",
                RuntimeWarning, stacklevel=3)
        return "sequential"
    return sim.engine


@dataclass
class SimConfig:
    num_clients: int = 50
    concurrency: float = 0.2          # fraction of clients training at once
    local_epochs: int = 5
    batch_size: int = 64
    lr: float = 0.01
    lr_decay: float = 0.999
    horizon: float = 86_400.0         # virtual time units (1 day default)
    eval_every: float = 2_000.0
    latency_kind: str = "uniform"
    latency_lo: float = 10.0
    latency_hi: float = 500.0
    availability_kind: str = "always"  # see latency.per_client_availability
    dropout_rate: float = 0.0          # per-dispatch failure rate when enabled
    seed: int = 0
    eval_batches: int = 8
    eval_batch_size: int = 512
    engine: str = "cohort"             # "cohort" (batched) | "sequential"
    max_cohort: int = 256              # cap on one wave's device batch
    # Layout: with a mesh, the policy server shards ServerState over the
    # mesh's flat-parameter axis (servers.ShardedPolicyServer) and the
    # cohort engine trains waves data-parallel over the client axis; rules
    # (default common.sharding.FEDERATED_RULES) map the logical
    # param_shard/cohort axes onto mesh axes. None = single-device layout.
    mesh: Optional[object] = None      # jax.sharding.Mesh
    rules: Optional[object] = None     # common.sharding.LogicalRules
    # Record a per-receive (||w||, probe·w) digest stream of the global
    # model — the golden-trajectory fingerprint (tests/test_golden.py).
    record_trajectory: bool = False


@dataclass
class SimResult:
    times: List[float] = field(default_factory=list)
    accuracies: List[float] = field(default_factory=list)
    final_accuracy: float = 0.0
    versions: int = 0
    dispatches: int = 0
    launched: int = 0                 # total dispatch calls (incl. in flight)
    dropped: int = 0                  # dispatches lost to client unavailability
    cohorts: int = 0                  # device batches the cohort engine ran
    engine: str = ""                  # engine actually used ("cohort" may
                                      # have resolved to "sequential")
    server_log: List[dict] = field(default_factory=list)
    receive_log: List[dict] = field(default_factory=list)
    digests: List[List[float]] = field(default_factory=list)

    @property
    def aulc(self) -> float:
        """Area under the learning curve normalized by the run's actual
        time span, so the unit (mean accuracy over the run) is comparable
        across horizons — matching the paper's Table 3 convention."""
        if len(self.times) < 2:
            return 0.0
        t = np.asarray(self.times)
        a = np.asarray(self.accuracies)
        span = float(t[-1] - t[0])
        if span <= 0.0:
            return 0.0
        return float(np.trapezoid(a, t) / span)


# Cross-run jit reuse: evaluation and sketch closures are deterministic in
# (model, dataset object, config), so cache them instead of re-jitting per
# run. The anchor object is part of the key by id() and is also stored in
# the value: the strong reference keeps the id valid for the cache's
# lifetime, and the identity check guards against id reuse.
_EVAL_CACHE: Dict[tuple, tuple] = {}
_SKETCH_FN_CACHE: Dict[tuple, tuple] = {}
_SKETCH_FLAT_CACHE: Dict[tuple, tuple] = {}


def _memo_identity(cache: Dict[tuple, tuple], key: tuple, anchor, build):
    hit = cache.get(key + (id(anchor),))
    if hit is not None and hit[0] is anchor:
        return hit[1]
    fn = build()
    cache[key + (id(anchor),)] = (anchor, fn)
    return fn


def _make_eval(cfg: ModelConfig, test_ds, sim: SimConfig):
    # the registry entry (None for unregistered families) is part of the
    # key so register_family(..., override=True) invalidates the closure
    fam = (registry.get_family(cfg)
           if registry.is_registered(cfg.family) else None)
    return _memo_identity(
        _EVAL_CACHE, (cfg, sim.eval_batches, sim.eval_batch_size, fam),
        test_ds, lambda: _build_eval(cfg, test_ds, sim))


def _build_eval(cfg: ModelConfig, test_ds, sim: SimConfig):
    from repro.common.sharding import SINGLE_DEVICE_RULES as R

    rng = np.random.RandomState(1234)
    n = len(test_ds)
    bs = min(sim.eval_batch_size, n)
    idxs = [rng.choice(n, size=bs, replace=False) for _ in range(sim.eval_batches)]
    if registry.is_registered(cfg.family):
        fam = registry.get_family(cfg)
        batches = [fam.batch_fn(test_ds.x[ix], test_ds.y[ix]) for ix in idxs]

        @jax.jit
        def acc1(params, batch):
            return fam.eval_accuracy(params, batch, cfg, R)
    else:
        # unregistered family on the sequential fallback: the legacy argmax
        # eval (model_lib.predict raises a clear error for families it
        # cannot score — register the family to plug in a metric)
        batches = [{"x": jnp.asarray(test_ds.x[ix]),
                    "y": jnp.asarray(test_ds.y[ix])} for ix in idxs]

        @jax.jit
        def acc1(params, batch):
            return jnp.mean((model_lib.predict(params, batch["x"], cfg)
                             == batch["y"]).astype(jnp.float32))

    def evaluate(params) -> float:
        return float(np.mean([float(acc1(params, b)) for b in batches]))

    return evaluate


def make_sketch_fn(cfg: ModelConfig, calib_batch: dict, psa_cfg: psa_lib.PSAConfig):
    return _memo_identity(
        _SKETCH_FN_CACHE, (cfg, psa_cfg), calib_batch,
        lambda: _build_sketch_fn(cfg, calib_batch, psa_cfg))


def _build_sketch_fn(cfg: ModelConfig, calib_batch: dict, psa_cfg: psa_lib.PSAConfig):
    calib = {k: jnp.asarray(v) for k, v in calib_batch.items()}
    from repro.common.sharding import SINGLE_DEVICE_RULES as R

    def loss(params, batch):
        return model_lib.loss_fn(params, batch, cfg, R)

    @jax.jit
    def fn(params):
        return psa_lib.client_sketch(loss, params, calib, psa_cfg)

    return fn


def make_sketch_fn_flat(cfg: ModelConfig, calib_batch: dict,
                        psa_cfg: psa_lib.PSAConfig, spec: tu.FlatSpec):
    return _memo_identity(
        _SKETCH_FLAT_CACHE, (cfg, psa_cfg, spec), calib_batch,
        lambda: _build_sketch_fn_flat(cfg, calib_batch, psa_cfg, spec))


def _build_sketch_fn_flat(cfg: ModelConfig, calib_batch: dict,
                          psa_cfg: psa_lib.PSAConfig, spec: tu.FlatSpec):
    """Batched sketch over flat client models: (B, d) -> (B, k), one jitted
    vmap call per wave (row counts bucketed like the engine)."""
    calib = {k: jnp.asarray(v) for k, v in calib_batch.items()}
    from repro.common.sharding import SINGLE_DEVICE_RULES as R

    def loss(params, batch):
        return model_lib.loss_fn(params, batch, cfg, R)

    batched = jax.jit(jax.vmap(
        lambda vec: psa_lib.client_sketch(loss, spec.unflatten(vec), calib,
                                          psa_cfg)))
    from repro.federated.cohort import bucket_size
    data_kind = registry.get_family(cfg).data_kind

    def fn(w_stack: jnp.ndarray) -> jnp.ndarray:
        B = int(w_stack.shape[0])
        # same family-dependent bucket grid as the engine
        Bp = bucket_size(B, data_kind)
        if Bp > B:
            w_stack = jnp.concatenate(
                [w_stack, jnp.zeros((Bp - B, w_stack.shape[1]), w_stack.dtype)])
        return batched(w_stack)[:B]

    return fn


# Trajectory digest: one (||w||_2, probe·w) pair per applied receive — a
# 2-float fingerprint of the full (d,) global vector that any execution path
# (sequential, cohort, sharded) can be compared on within float tolerance.
_DIGEST_SEED = 0xD16E57
_DIGEST_FN_CACHE: Dict[int, Callable] = {}


def make_digest_fn(d: int) -> Callable:
    """(B, d) -> (B, 2) numpy digest with the fixed probe vector for d.
    Host-side on purpose: the rows are transferred for recording anyway,
    and a jitted variant would recompile for every distinct wave size."""
    fn = _DIGEST_FN_CACHE.get(d)
    if fn is None:
        probe = np.random.RandomState(_DIGEST_SEED).randn(d).astype(np.float32)

        def fn(rows):
            rows = np.asarray(rows, np.float32)
            return np.stack([np.sqrt(np.sum(rows * rows, axis=-1)),
                             rows @ probe], axis=-1)

        _DIGEST_FN_CACHE[d] = fn
    return fn


class _Event(NamedTuple):
    """One in-flight dispatch. ``snapshot`` is the global model captured at
    dispatch time — a flat (d,) vector or a ``(source, row)`` reference into
    a batched-ingest snapshot sequence (cohort engine), or the params pytree
    (sequential engine); ``ok`` is the availability draw — False means the
    client never reports back and the slot re-dispatches at ``t_done``."""
    t_done: float
    seq: int
    cid: int
    snapshot: object
    version: int
    ok: bool


def _gather_snapshots(snaps) -> jnp.ndarray:
    """Stack dispatch snapshots into (B, d) with one gather per distinct
    source instead of one device slice per event. Entries are plain (d,)
    vectors (grouped by identity — e.g. the initial dispatches all share the
    version-0 vector) or ``(source (n, d), row)`` references into a previous
    flush's post-receive sequence."""
    groups: dict = {}
    order = []
    for pos, s in enumerate(snaps):
        src, row = s if isinstance(s, tuple) else (s, None)
        g = groups.get(id(src))
        if g is None:
            g = (src, [], [])
            groups[id(src)] = g
            order.append(g)
        g[1].append(row)
        g[2].append(pos)
    parts, positions = [], []
    for src, rows, poss in order:
        if rows[0] is None:
            parts.append(jnp.broadcast_to(src, (len(poss),) + src.shape))
        elif len(rows) == 1:
            parts.append(src[rows[0]][None])
        else:
            parts.append(src[jnp.asarray(np.asarray(rows, np.int32))])
        positions.extend(poss)
    out = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    if positions != list(range(len(snaps))):
        inv = np.empty(len(snaps), np.int32)
        inv[np.asarray(positions)] = np.arange(len(snaps), dtype=np.int32)
        out = out[jnp.asarray(inv)]
    return out


def run_async(server_name: str, cfg: ModelConfig, init_params,
              client_datasets: List[ClientDataset], test_ds,
              sim: SimConfig, *, psa_cfg: Optional[psa_lib.PSAConfig] = None,
              calib_batch: Optional[dict] = None,
              server_kwargs: Optional[dict] = None,
              receive_hook: Optional[Callable] = None) -> SimResult:
    """Run one asynchronous algorithm to the virtual-time horizon."""
    engine = _resolve_engine(sim, cfg)
    batched = engine == "cohort"
    rng = np.random.RandomState(sim.seed)
    latency, lat_means = per_client_latency(
        sim.latency_kind, sim.latency_lo, sim.latency_hi, sim.num_clients,
        sim.seed)
    avail = per_client_availability(sim.availability_kind, sim.dropout_rate,
                                    sim.num_clients, sim.seed,
                                    latency_means=lat_means)
    use_avail = sim.availability_kind != "always" and sim.dropout_rate > 0.0
    sketch_fn = None
    if server_name == "fedpsa":
        psa_cfg = psa_cfg or psa_lib.PSAConfig()
        assert calib_batch is not None
        sketch_fn = make_sketch_fn(cfg, calib_batch, psa_cfg)
    server = servers_lib.make_server(
        server_name, init_params, num_clients=sim.num_clients,
        psa_cfg=psa_cfg, sketch_fn=sketch_fn, mesh=sim.mesh, rules=sim.rules,
        **(server_kwargs or {}))
    align = getattr(server, "client_align", 0.0)
    digest_fn = (make_digest_fn(server.policy.spec.size)
                 if sim.record_trajectory else None)

    evaluate = _make_eval(cfg, test_ds, sim)
    result = SimResult(engine=engine)
    concurrency = max(1, int(round(sim.concurrency * sim.num_clients)))
    heap: List[_Event] = []
    seq = 0
    data_sizes = np.array([len(d) for d in client_datasets], np.float64)

    def dispatch(t: float, snap=None, version=None):
        nonlocal seq
        cid = int(rng.randint(sim.num_clients))
        t_done = t + latency(cid)
        ok = bool(rng.rand() < avail[cid]) if use_avail else True
        if snap is None:
            snap = server.flat_params if batched else server.params
        if version is None:
            version = server.version
        heapq.heappush(heap, _Event(t_done, seq, cid, snap, version, ok))
        seq += 1
        result.launched += 1

    for _ in range(concurrency):
        dispatch(0.0)

    if batched:
        t = _drain_cohort(server, cfg, init_params, client_datasets, sim,
                          dispatch, heap, evaluate, result, data_sizes,
                          align, psa_cfg, calib_batch, receive_hook,
                          digest_fn)
    else:
        t = _drain_sequential(server, cfg, client_datasets, sim, dispatch,
                              heap, evaluate, result, data_sizes, align,
                              sketch_fn, receive_hook, digest_fn)

    result.final_accuracy = evaluate(server.params)
    result.times.append(min(t, sim.horizon))
    result.accuracies.append(result.final_accuracy)
    result.versions = server.version
    result.server_log = server.log
    return result


def _drain_sequential(server, cfg, client_datasets, sim: SimConfig, dispatch,
                      heap, evaluate, result: SimResult, data_sizes, align,
                      sketch_fn, receive_hook, digest_fn=None) -> float:
    """Legacy reference loop: one local_update per completion (oracle)."""
    next_eval = 0.0
    t = 0.0
    while heap and t < sim.horizon:
        ev = heapq.heappop(heap)
        t = ev.t_done
        if t > sim.horizon:
            break
        while next_eval <= t:
            acc = evaluate(server.params)
            result.times.append(next_eval)
            result.accuracies.append(acc)
            next_eval += sim.eval_every
        if not ev.ok:
            result.dropped += 1
            dispatch(t)
            continue
        lr = sim.lr * (sim.lr_decay ** result.dispatches)
        delta, w_client = client_lib.local_update(
            ev.snapshot, cfg, client_datasets[ev.cid],
            epochs=sim.local_epochs, batch_size=sim.batch_size, lr=lr,
            seed=sim.seed * 100003 + result.dispatches, align=align)
        meta = {
            "tau": server.version - ev.version,
            "client_id": ev.cid,
            "data_size": float(data_sizes[ev.cid]),
        }
        if server.needs_sketch:
            meta["sketch"] = sketch_fn(w_client)
        if receive_hook is not None:
            receive_hook(server, w_client, delta, meta, t)
        server.receive(delta, w_client, meta)
        if digest_fn is not None:
            result.digests.append(
                digest_fn(server.flat_params[None, :])[0].tolist())
        result.dispatches += 1
        result.receive_log.append({"t": t, "tau": meta["tau"], "client": ev.cid})
        dispatch(t)
    return t


def _drain_cohort(server, cfg, init_params, client_datasets, sim: SimConfig,
                  dispatch, heap, evaluate, result: SimResult, data_sizes,
                  align, psa_cfg, calib_batch, receive_hook,
                  digest_fn=None) -> float:
    """Batched drain: train completion waves as single device calls.

    A wave is the maximal heap prefix with ``t_done < t_first + latency_lo``
    (capped at ``sim.max_cohort``). Any dispatch issued while the wave is
    being received completes no earlier than ``t_first + latency_lo`` — and
    at an equal timestamp sorts after the wave by ``seq`` — so training the
    wave up front observes exactly the snapshots, learning rates, and seeds
    the sequential engine would have used.
    """
    spec = server.policy.spec
    stacked = StackedClients.from_datasets(client_datasets)
    engine = CohortEngine(cfg, stacked, spec, init_params,
                          local_epochs=sim.local_epochs,
                          batch_size=sim.batch_size, align=align,
                          mesh=sim.mesh, rules=sim.rules)
    sketch_flat = None
    if server.needs_sketch:
        sketch_flat = make_sketch_fn_flat(cfg, calib_batch, psa_cfg, spec)
    unflatten = tu.jit_unflatten(spec) if receive_hook is not None else None

    next_eval = 0.0
    t = 0.0
    while heap and t < sim.horizon:
        first = heapq.heappop(heap)
        if first.t_done > sim.horizon:
            t = first.t_done       # mirror the sequential pop-then-break
            break
        bound = first.t_done + sim.latency_lo
        wave: List[_Event] = [first]
        t_over = None
        while heap and heap[0].t_done < bound and len(wave) < sim.max_cohort:
            ev = heapq.heappop(heap)
            if ev.t_done > sim.horizon:
                t_over = ev.t_done  # discarded, like the sequential break
                break
            wave.append(ev)

        ok_events = [ev for ev in wave if ev.ok]
        deltas = w_stack = sketches = None
        if ok_events:
            d0 = result.dispatches
            snapshots = _gather_snapshots([ev.snapshot for ev in ok_events])
            cids = [ev.cid for ev in ok_events]
            lrs = [sim.lr * (sim.lr_decay ** (d0 + r))
                   for r in range(len(ok_events))]
            seeds = [sim.seed * 100003 + (d0 + r)
                     for r in range(len(ok_events))]
            deltas, w_stack = engine.cohort_update(snapshots, cids, lrs, seeds)
            if sketch_flat is not None:
                sketches = sketch_flat(w_stack)
            result.cohorts += 1

        # Receives are deferred into ``pending`` and flushed as ONE batched
        # ingest (``receive_many``) — flushing early only when an eval
        # boundary needs the intermediate global model, or per-event when a
        # receive_hook must observe pre-receive server state. Replacement
        # dispatches happen inside the flush, each snapshotting the global
        # vector as of *its* event (``snaps`` rows), so RNG order and
        # snapshot contents match the sequential engine exactly.
        pending: List[_Event] = []
        next_row = 0

        def flush():
            nonlocal next_row
            if not pending:
                return
            ok = [ev for ev in pending if ev.ok]
            r0, r1 = next_row, next_row + len(ok)
            cur = server.flat_params   # pre-flush vector, for leading dropouts
            snaps = None
            upd = np.zeros((0,), bool)
            if ok:
                if receive_hook is not None:
                    assert len(pending) == 1
                    ev = ok[0]
                    meta = {"tau": server.version - ev.version,
                            "client_id": ev.cid,
                            "data_size": float(data_sizes[ev.cid])}
                    if sketches is not None:
                        meta["sketch"] = sketches[r0]
                    receive_hook(server, unflatten(w_stack[r0]),
                                 unflatten(deltas[r0]), meta, ev.t_done)
                upd, taus, snaps = server.receive_many(
                    deltas[r0:r1], w_stack[r0:r1],
                    [ev.cid for ev in ok],
                    [float(data_sizes[ev.cid]) for ev in ok],
                    [ev.version for ev in ok],
                    None if sketches is None else sketches[r0:r1])
                if digest_fn is not None:
                    result.digests.extend(digest_fn(snaps).tolist())
                for ev, tau in zip(ok, taus):
                    result.receive_log.append(
                        {"t": ev.t_done, "tau": tau, "client": ev.cid})
                result.dispatches += len(ok)
                next_row = r1
            vcur = server.version - int(np.sum(upd))  # version pre-flush
            oi = 0
            for ev in pending:
                if ev.ok:
                    cur = (snaps, oi)   # row reference, gathered lazily
                    vcur += int(upd[oi])
                    oi += 1
                else:
                    result.dropped += 1
                dispatch(ev.t_done, snap=cur, version=vcur)
            pending.clear()

        for ev in wave:
            t = ev.t_done
            if next_eval <= t:
                flush()
                while next_eval <= t:
                    acc = evaluate(server.params)
                    result.times.append(next_eval)
                    result.accuracies.append(acc)
                    next_eval += sim.eval_every
            pending.append(ev)
            if receive_hook is not None:
                flush()
        flush()
        if t_over is not None:
            t = t_over
            break
    return t


def run_fedavg(cfg: ModelConfig, init_params, client_datasets: List[ClientDataset],
               test_ds, sim: SimConfig, *, prox: float = 0.0) -> SimResult:
    """Synchronous FedAvg: per round sample 20% of clients, wait for the
    slowest, aggregate weighted by client data size. With the cohort engine
    the whole round trains as one device call and the global model stays a
    flat (d,) vector between rounds."""
    rng = np.random.RandomState(sim.seed)
    latency, lat_means = per_client_latency(
        sim.latency_kind, sim.latency_lo, sim.latency_hi, sim.num_clients,
        sim.seed)
    avail = per_client_availability(sim.availability_kind, sim.dropout_rate,
                                    sim.num_clients, sim.seed,
                                    latency_means=lat_means)
    use_avail = sim.availability_kind != "always" and sim.dropout_rate > 0.0
    evaluate = _make_eval(cfg, test_ds, sim)
    engine = _resolve_engine(sim, cfg)
    batched = engine == "cohort"
    result = SimResult(engine=engine)
    m = max(1, int(round(sim.concurrency * sim.num_clients)))
    if batched:
        spec = tu.FlatSpec(init_params)
        stacked = StackedClients.from_datasets(client_datasets)
        engine = CohortEngine(cfg, stacked, spec, init_params,
                              local_epochs=sim.local_epochs,
                              batch_size=sim.batch_size, prox=prox,
                              mesh=sim.mesh, rules=sim.rules)
        flat = jnp.array(spec.flatten(init_params), copy=True)
        params = None
    else:
        params = init_params
    t = 0.0
    next_eval = 0.0
    rnd = 0
    while t < sim.horizon:
        while next_eval <= t:
            acc = evaluate(spec.unflatten(flat) if batched else params)
            result.times.append(next_eval)
            result.accuracies.append(acc)
            next_eval += sim.eval_every
        chosen = rng.choice(sim.num_clients, size=m, replace=False)
        result.launched += len(chosen)
        round_time = max(latency(int(c)) for c in chosen)
        if use_avail:
            ok = [bool(rng.rand() < avail[int(c)]) for c in chosen]
            result.dropped += sum(1 for o in ok if not o)
            active = [int(c) for c, o in zip(chosen, ok) if o]
        else:
            active = [int(c) for c in chosen]
        lr = sim.lr * (sim.lr_decay ** rnd)
        if active:
            sizes = np.asarray([len(client_datasets[c]) for c in active],
                               np.float32)
            w = jnp.asarray(sizes / np.sum(sizes))
            seeds = [sim.seed * 100003 + rnd * 51 + c for c in active]
            if batched:
                snapshots = jnp.broadcast_to(flat, (len(active), flat.shape[0]))
                deltas, _ = engine.cohort_update(snapshots, active,
                                                 [lr] * len(active), seeds)
                flat = flat + jnp.einsum("b,bd->d", w, deltas)
                result.cohorts += 1
            else:
                deltas = []
                for c, s in zip(active, seeds):
                    d, _ = client_lib.local_update(
                        params, cfg, client_datasets[c],
                        epochs=sim.local_epochs, batch_size=sim.batch_size,
                        lr=lr, seed=s, prox=prox)
                    deltas.append(d)
                params = tu.tree_add(params, tu.tree_weighted_sum(deltas, w))
        t += round_time
        rnd += 1
        result.dispatches += len(active)
    final_params = spec.unflatten(flat) if batched else params
    result.final_accuracy = evaluate(final_params)
    result.times.append(min(t, sim.horizon))
    result.accuracies.append(result.final_accuracy)
    result.versions = rnd
    return result


ALGORITHMS = ("fedavg", "fedasync", "fedbuff", "fedpsa", "ca2fl", "fedfa",
              "fedpac", "asyncfeded")


def run_algorithm(name: str, cfg: ModelConfig, init_params, client_datasets,
                  test_ds, sim: SimConfig, **kw) -> SimResult:
    if name == "fedavg":
        kw.pop("psa_cfg", None)
        kw.pop("calib_batch", None)
        return run_fedavg(cfg, init_params, client_datasets, test_ds, sim, **kw)
    return run_async(name, cfg, init_params, client_datasets, test_ds, sim, **kw)
