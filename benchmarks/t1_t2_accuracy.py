"""Paper Tables 1-2: final accuracy, all algorithms x Dirichlet alpha.

Offline stand-in: the synthetic Gaussian-mixture task replaces
MNIST/FMNIST/CIFAR (DESIGN.md §6); the claim validated is the ORDERING
(FedPSA >= FedBuff and the async baselines, largest gap at alpha=0.1).
Learning curves are stored for t3_aulc.

Multi-seed protocol: every async cell runs its SEEDS as lanes of ONE
``run_sweep`` call — per-lane model-init and batch-shuffle seeds over a
shared event timeline — so the table's mean±std costs one batched
simulation per cell instead of |SEEDS| python re-runs. The synchronous
fedavg baseline has no lane machinery and loops (its seeds also reshuffle
the round timeline; its std is correspondingly wider). Reported accuracy
per cell is the seed mean; per-seed values ride along under "per_seed".
"""
from __future__ import annotations

import sys

import jax
import numpy as np

from repro.federated import SimConfig, SweepConfig, run_algorithm
from repro.models import model as model_lib
from benchmarks import common

ALGS = ("fedbuff", "fedavg", "fedasync", "ca2fl", "fedfa", "fedpac", "fedpsa")
ALPHAS = (0.1, 0.5, 1.0)
SEEDS = (0, 1, 2)


def _fedavg_cell(alpha: float):
    """Synchronous baseline: python loop over seeds (round-based runner)."""
    cfg, clients, test, calib, _params = common.world(alpha)
    out = []
    for s in SEEDS:
        params = model_lib.init_params(jax.random.PRNGKey(s), cfg)
        res = run_algorithm("fedavg", cfg, params, clients, test,
                            common.sim_config(seed=s))
        out.append(res)
    return out


def main(argv=None):
    rows = {}
    curves = {}
    for alpha in ALPHAS:
        for alg in ALGS:
            if alg == "fedavg":
                lanes = _fedavg_cell(alpha)
                accs = [r.final_accuracy for r in lanes]
                # fedavg seeds reshuffle the round timeline, so the per-seed
                # eval grids differ; interpolate every curve onto lane 0's
                # grid before averaging (async cells share one grid)
                times = lanes[0].times
                lane_curves = [
                    np.interp(times, r.times, r.accuracies).tolist()
                    for r in lanes]
                aulcs = [r.aulc for r in lanes]
            else:
                sweep = SweepConfig(model_seeds=list(SEEDS),
                                    data_seeds=list(SEEDS))
                res = common.sweep_cell(alg, alpha, sweep)
                accs = list(res.final_accuracy)
                times = res.times
                lane_curves = res.lane_accuracies
                aulcs = res.aulc
            mean, std = float(np.mean(accs)), float(np.std(accs))
            rows[f"{alg}@a{alpha}"] = mean
            rows[f"{alg}@a{alpha}_std"] = std
            # mean curve under the legacy keys (t3_aulc integrates these);
            # per-seed curves ride along
            n = min(len(c) for c in lane_curves)
            mean_curve = np.mean([c[:n] for c in lane_curves],
                                 axis=0).tolist()
            curves[f"{alg}@a{alpha}"] = {
                "times": list(times)[:n], "accuracies": mean_curve,
                "aulc": common.aulc_json(np.mean(aulcs)),
                "per_seed": {"seeds": list(SEEDS), "final": accs,
                             "aulc": [common.aulc_json(a) for a in aulcs]},
            }
            print(f"t1_t2,{alg},alpha={alpha},{mean:.4f}±{std:.4f}")
    common.save("t1_t2_accuracy", rows)
    common.save("t3_curves", curves)
    # qualitative claim check (paper Table 2 ordering at alpha=0.1)
    claim = rows["fedpsa@a0.1"] > rows["fedasync@a0.1"] and \
        rows["fedpsa@a0.1"] > rows["fedfa@a0.1"]
    print(f"t1_t2,claim_fedpsa_beats_async_baselines_a0.1,{claim}")
    return rows


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
