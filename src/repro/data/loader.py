"""Client-side data loading: epoch iterators + device-resident stacking.

``ClientDataset`` is the per-client host view (shuffled epoch batches).
``StackedClients`` is the cohort engine's device view: every client's data
padded into one ``(C, n_max, ...)`` slab with sizes and validity masks, so
local training for a whole cohort is a single gather + vmapped scan instead
of C python loops.

Both views are layout-polymorphic over the registry's two data kinds:
*image* shards hold ``x (n, ...) float32`` features and ``y (n,) int``
labels and batch as ``{"x", "y"}``; *token* shards (federated LM
fine-tuning) hold ``x = y = (n, seq) int32`` token sequences and batch as
``{"tokens", "labels"}`` — the keys ``models.registry``'s token
``client_loss`` (i.e. ``model_lib.loss_fn``) speaks. The kind is inferred
from the feature dtype (integer => tokens), so the cohort slab becomes a
``(C, n_max, seq)`` int32 token/label pair with the SAME sizes/mask/shuffle
machinery as the image slab.

Both views draw batch order from ``epoch_batch_indices`` — the one shuffle
routine — so the vectorized engine visits exactly the batches the legacy
per-client loop would (same ``np.random.RandomState`` stream, same
drop-last rule), which is what makes the 1e-5 parity tests meaningful.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Iterator, List, Sequence

import numpy as np

from repro.data.synthetic import SyntheticClassification


def epoch_batch_indices(n: int, num_epochs: int, batch_size: int,
                        seed: int) -> np.ndarray:
    """Batch schedule for one client: ``(steps, bs)`` int32 indices into its
    ``n`` samples, ``bs = min(batch_size, n)``, drop-last, one fresh
    permutation per epoch from ``RandomState(seed)``."""
    rng = np.random.RandomState(seed)
    bs = min(batch_size, n)
    m = n // bs                       # drop-last batch count per epoch
    out = np.empty((num_epochs * m, bs), np.int32)
    for e in range(num_epochs):
        out[e * m:(e + 1) * m] = rng.permutation(n)[:m * bs].reshape(m, bs)
    return out


def data_kind_of(x: np.ndarray) -> str:
    """The registry data kind a feature array implies: integer dtypes are
    token-id sequences, everything else image/feature rows."""
    return "tokens" if np.issubdtype(np.asarray(x).dtype, np.integer) \
        else "image"


@dataclass
class ClientDataset:
    data: SyntheticClassification

    def __len__(self):
        return len(self.data)

    @property
    def kind(self) -> str:
        return data_kind_of(self.data.x)

    def epochs(self, num_epochs: int, batch_size: int, seed: int) -> Iterator[dict]:
        tokens = self.kind == "tokens"
        for idx in epoch_batch_indices(len(self.data), num_epochs,
                                       batch_size, seed):
            if tokens:
                yield {"tokens": self.data.x[idx].astype(np.int32),
                       "labels": self.data.y[idx].astype(np.int32)}
            else:
                yield {"x": self.data.x[idx].astype(np.float32),
                       "y": self.data.y[idx].astype(np.int32)}


@dataclass
class StackedClients:
    """All clients' data as one padded slab (the cohort engine's layout).

    ``x[c, :sizes[c]]`` are client ``c``'s real samples; rows beyond that are
    zero padding with ``mask`` False. Padding never reaches a loss term: the
    batch schedules index only real rows, and ragged batch tails are masked
    inside the engine's loss (for token shards, by turning the padded rows'
    labels into the ``-1`` no-target sentinel).

    ``kind == "image"``: x (C, n_max, ...) float32, y (C, n_max) int32.
    ``kind == "tokens"``: x and y both (C, n_max, seq) int32.
    """
    x: np.ndarray        # (C, n_max, ...) float32 features | int32 tokens
    y: np.ndarray        # (C, n_max[, seq]) int32 labels
    sizes: np.ndarray    # (C,) int32 true per-client sample counts
    mask: np.ndarray     # (C, n_max) bool — True on real rows
    num_classes: int
    kind: str = "image"

    def __len__(self):
        return self.x.shape[0]

    @property
    def n_max(self) -> int:
        return self.x.shape[1]

    @classmethod
    def from_datasets(cls, datasets: Sequence[ClientDataset]) -> "StackedClients":
        sizes = np.asarray([len(d) for d in datasets], np.int32)
        n_max = int(sizes.max())
        d0 = datasets[0].data
        kind = data_kind_of(d0.x)
        feat = d0.x.shape[1:]
        lab = d0.y.shape[1:]
        C = len(datasets)
        x = np.zeros((C, n_max) + feat,
                     np.int32 if kind == "tokens" else np.float32)
        y = np.zeros((C, n_max) + lab, np.int32)
        mask = np.zeros((C, n_max), bool)
        for c, d in enumerate(datasets):
            n = sizes[c]
            x[c, :n] = d.data.x.astype(x.dtype)
            y[c, :n] = d.data.y.astype(np.int32)
            mask[c, :n] = True
        return cls(x=x, y=y, sizes=sizes, mask=mask,
                   num_classes=d0.num_classes, kind=kind)


class _ListSource:
    """Row source over a materialized client-dataset list — the small-C
    adapter that lets the streaming slab path run on exactly the data the
    monolithic ``StackedClients`` slab would hold (digest-parity tests)."""

    def __init__(self, datasets: Sequence[ClientDataset]):
        self._datasets = list(datasets)
        self.sizes = np.asarray([len(d) for d in self._datasets], np.int64)
        self.n_max = int(self.sizes.max())
        d0 = self._datasets[0].data
        self.kind = data_kind_of(d0.x)
        self.num_classes = d0.num_classes
        self._xdtype = np.int32 if self.kind == "tokens" else np.float32
        self._feat = d0.x.shape[1:]
        self._lab = d0.y.shape[1:]

    def member_rows(self, cids):
        cids = np.asarray(cids, np.int64)
        B = cids.shape[0]
        x = np.zeros((B, self.n_max) + self._feat, self._xdtype)
        y = np.zeros((B, self.n_max) + self._lab, np.int32)
        for i, c in enumerate(cids):
            d = self._datasets[int(c)]
            n = int(self.sizes[c])
            x[i, :n] = d.data.x.astype(self._xdtype)
            y[i, :n] = d.data.y.astype(np.int32)
        return x, y


class ClientSlabStore:
    """Chunked/streaming ``StackedClients``: fixed-size client shards with
    lazy device upload behind a bounded LRU.

    The monolithic slab holds all C clients on device at once —
    O(C * n_max) memory, the population-scale blocker. This store keys
    device residency by the *wave's member set* instead: ``gather(cids)``
    returns the members' ``(B, n_max, ...)`` rows, serving each member
    either from a cached device shard (clients ``[s*shard_size, (s+1) *
    shard_size)`` as one array) or, for shards the wave barely touches,
    from a direct host materialization of just those members ("row path" —
    uploaded with the wave, never cached). A shard is materialized and
    cached only when a wave wants >= ``promote`` of its clients, and at
    most ``cache_shards`` shards stay resident (LRU), so host+device data
    memory is O(cache_shards * shard_size * n_max) — set by the shard
    geometry, not by C.

    Rows come from a deterministic source (``member_rows`` is a pure
    function of client id), so evictions can never change results — only
    which path serves a member. ``stats`` counts both paths for the tests
    and the population benchmark.

    ``prefetch(cids)`` overlaps the NEXT wave's host materialization +
    H2D upload with the current wave's device compute: a single background
    worker runs the same ``member_rows``/``jnp.asarray`` pipeline and the
    results are integrated into the same LRU (shards) or handed to the next
    gather (the row-path block) on the main thread — the worker never
    mutates the cache or the counters, so no locking is needed and the
    serving semantics (and therefore results, rows being pure in cid) are
    byte-identical with prefetch on or off.
    """

    def __init__(self, source, *, shard_size: int, cache_shards: int = 32,
                 promote: int = 8):
        self.source = source
        self.sizes = np.asarray(source.sizes, np.int64)
        self.num_clients = int(self.sizes.shape[0])
        self.shard_size = int(shard_size)
        assert self.shard_size >= 1
        self.num_shards = -(-self.num_clients // self.shard_size)
        self.cache_shards = max(1, int(cache_shards))
        self.promote = max(1, int(promote))
        self._cache: OrderedDict = OrderedDict()   # sid -> (x_dev, y_dev)
        self.hits = 0            # members served from cached shards
        self.row_fetches = 0     # members served via the row path
        self.shard_loads = 0     # full-shard materializations
        self.evictions = 0
        # -- async prefetch (single worker; results land on the main thread)
        self._pool = None                  # lazy ThreadPoolExecutor
        self._pending: dict = {}           # sid -> Future[(x_dev, y_dev)]
        self._pending_rows = None          # (cid-tuple, Future) row block
        self._prefetched_fresh: set = set()   # installed, not yet served
        self.prefetch_issued = 0   # members covered by issued prefetches
        self.prefetch_hits = 0     # members served from prefetched data
        self.prefetch_wasted = 0   # prefetched row-blocks never consumed

    @classmethod
    def build(cls, client_datasets, *, shard_size: int = 0,
              cache_shards: int = 32, promote: int = 8) -> "ClientSlabStore":
        """Wrap either a lazy population (anything with ``member_rows``) or
        a plain client-dataset list; ``shard_size=0`` picks a default."""
        source = (client_datasets
                  if hasattr(client_datasets, "member_rows")
                  else _ListSource(client_datasets))
        if shard_size <= 0:
            shard_size = min(1024, int(np.asarray(source.sizes).shape[0]))
        return cls(source, shard_size=shard_size, cache_shards=cache_shards,
                   promote=promote)

    @property
    def n_max(self) -> int:
        return self.source.n_max

    @property
    def kind(self) -> str:
        return self.source.kind

    @property
    def num_classes(self) -> int:
        return self.source.num_classes

    @property
    def stats(self) -> dict:
        served = self.hits + self.row_fetches
        return {"hits": self.hits, "row_fetches": self.row_fetches,
                "shard_loads": self.shard_loads, "evictions": self.evictions,
                "resident_shards": len(self._cache),
                "prefetch_issued": self.prefetch_issued,
                "prefetch_hits": self.prefetch_hits,
                "prefetch_wasted": self.prefetch_wasted,
                "hit_rate": self.hits / served if served else 0.0,
                "row_fetch_rate": (self.row_fetches / served
                                   if served else 0.0)}

    # -- materialization (pure; safe on the worker thread) ------------------

    def _materialize_shard(self, sid: int):
        import jax.numpy as jnp
        lo = sid * self.shard_size
        hi = min(lo + self.shard_size, self.num_clients)
        x, y = self.source.member_rows(np.arange(lo, hi))
        return jnp.asarray(x), jnp.asarray(y)

    def _materialize_rows(self, cids: np.ndarray):
        import jax.numpy as jnp
        x, y = self.source.member_rows(cids)
        return jnp.asarray(x), jnp.asarray(y)

    # -- cache integration (main thread only) -------------------------------

    def _install_shard(self, sid: int, entry) -> None:
        self._cache[sid] = entry
        self.shard_loads += 1
        while len(self._cache) > self.cache_shards:
            evicted, _ = self._cache.popitem(last=False)
            if evicted in self._prefetched_fresh:
                self._prefetched_fresh.discard(evicted)
                self.prefetch_wasted += 1
            self.evictions += 1

    def _load_shard(self, sid: int):
        entry = self._materialize_shard(sid)
        self._install_shard(sid, entry)
        return entry

    @staticmethod
    def _plan(cids: np.ndarray, shard_size: int):
        """Vectorized shard bucketing: yields ``(sid, positions)`` groups in
        ascending shard order, positions in input order within each group
        (replaces the per-member Python loop — O(B log B) in numpy)."""
        sids = (cids // shard_size).astype(np.int64)
        order = np.argsort(sids, kind="stable")
        uniq, starts = np.unique(sids[order], return_index=True)
        bounds = np.append(starts, cids.shape[0])
        return [(int(uniq[i]), order[bounds[i]:bounds[i + 1]])
                for i in range(uniq.shape[0])]

    def _ensure_pool(self):
        if self._pool is None:
            from concurrent.futures import ThreadPoolExecutor
            self._pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="slab-prefetch")
        return self._pool

    def prefetch(self, cids) -> None:
        """Hint that the next ``gather`` will want these members: schedule
        the shards the promote rule would load (not yet resident, not
        already in flight) and the residual row-path block on the worker.
        A hint can only move work off the gather path — a wrong or stale
        prediction degrades to the synchronous behavior (the mismatched
        row block is dropped and counted in ``prefetch_wasted``)."""
        cids = np.asarray(cids, np.int64)
        if cids.size == 0:
            return
        pool = self._ensure_pool()
        miss = []
        for sid, poss in self._plan(cids, self.shard_size):
            if sid in self._cache:
                continue
            if len(poss) >= self.promote:
                if sid not in self._pending:
                    self._pending[sid] = pool.submit(
                        self._materialize_shard, sid)
                    self.prefetch_issued += len(poss)
            else:
                miss.extend(poss.tolist())
        if miss:
            row_cids = cids[miss]
            key = tuple(int(c) for c in row_cids)
            if self._pending_rows is not None:
                if self._pending_rows[0] == key:
                    return
                self.prefetch_wasted += 1
            self._pending_rows = (key, pool.submit(
                self._materialize_rows, row_cids))
            self.prefetch_issued += len(miss)

    def _drain_prefetch(self) -> None:
        """Integrate completed shard prefetches into the LRU (main thread:
        the worker never touches ``_cache``)."""
        if not self._pending:
            return
        done = [sid for sid, f in self._pending.items() if f.done()]
        for sid in done:
            f = self._pending.pop(sid)
            if sid not in self._cache:
                self._install_shard(sid, f.result())
                self._prefetched_fresh.add(sid)

    def gather(self, cids):
        """Members' rows as device ``(B, n_max, ...)`` arrays, one gather
        per touched cached shard plus at most one row-path upload, restored
        to input order (mirrors ``simulator._gather_snapshots``)."""
        import jax.numpy as jnp
        cids = np.asarray(cids, np.int64)
        B = cids.shape[0]
        self._drain_prefetch()
        parts_x, parts_y, positions, miss = [], [], [], []
        for sid, poss in self._plan(cids, self.shard_size):
            poss = poss.tolist()
            entry = self._cache.get(sid)
            if entry is None and sid in self._pending:
                # in-flight prefetch for a shard this wave needs: wait for
                # the worker instead of re-materializing
                entry = self._pending.pop(sid).result()
                self._install_shard(sid, entry)
                self._prefetched_fresh.add(sid)
            if entry is None and len(poss) >= self.promote:
                entry = self._load_shard(sid)
            if entry is None:
                miss.extend(poss)
                self.row_fetches += len(poss)
                continue
            self._cache.move_to_end(sid)
            if sid in self._prefetched_fresh:
                self._prefetched_fresh.discard(sid)
                self.prefetch_hits += len(poss)
            self.hits += len(poss)
            rows = cids[poss] - sid * self.shard_size
            rows_j = jnp.asarray(rows.astype(np.int32))
            parts_x.append(entry[0][rows_j])
            parts_y.append(entry[1][rows_j])
            positions.extend(poss)
        if miss:
            pr, self._pending_rows = self._pending_rows, None
            if pr is not None and pr[0] == tuple(int(c) for c in cids[miss]):
                x_h, y_h = pr[1].result()
                self.prefetch_hits += len(miss)
            else:
                if pr is not None:
                    self.prefetch_wasted += 1
                x_h, y_h = self._materialize_rows(cids[miss])
            parts_x.append(x_h)
            parts_y.append(y_h)
            positions.extend(miss)
        x = parts_x[0] if len(parts_x) == 1 else jnp.concatenate(parts_x)
        y = parts_y[0] if len(parts_y) == 1 else jnp.concatenate(parts_y)
        if positions != list(range(B)):
            inv = np.empty(B, np.int32)
            inv[np.asarray(positions)] = np.arange(B, dtype=np.int32)
            inv_j = jnp.asarray(inv)
            x, y = x[inv_j], y[inv_j]
        return x, y


def batch_iterator(ds: SyntheticClassification, batch_size: int,
                   seed: int = 0) -> Iterator[dict]:
    """Endless shuffled batches (evaluation/training streams).

    Contract (pinned by ``tests/test_data.py``): every yielded batch has
    exactly ``batch_size`` rows — the tail partial batch of each epoch is
    SILENTLY DROPPED, so one epoch yields ``n // batch_size`` batches and
    the last ``n % batch_size`` rows of each permutation are skipped (a
    different subset every epoch, so no row is starved across epochs).
    Corollary: ``batch_size > n`` yields nothing and an unguarded ``next``
    would spin forever — callers must size batches within the dataset.
    Changing either behavior (e.g. emitting the ragged tail) must be a
    deliberate contract change, not a drive-by fix.
    """
    rng = np.random.RandomState(seed)
    n = len(ds)
    while True:
        order = rng.permutation(n)
        for start in range(0, n - batch_size + 1, batch_size):
            idx = order[start:start + batch_size]
            yield {"x": ds.x[idx].astype(np.float32),
                   "y": ds.y[idx].astype(np.int32)}
