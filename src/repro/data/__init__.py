from repro.data.synthetic import (
    SyntheticClassification,
    SyntheticPopulation,
    make_classification,
    make_lm_corpus,
    train_test_split,
)
from repro.data.partition import (dirichlet_partition, document_partition,
                                  iid_partition, skewed_client_sizes)
from repro.data.calibration import make_calibration_batch
from repro.data.loader import (ClientDataset, ClientSlabStore,
                               StackedClients, batch_iterator, data_kind_of,
                               epoch_batch_indices)
