"""Population-scale streaming stack: lazy clients + chunked slab store.

Three layers, pinned bottom-up:

* ``SyntheticPopulation`` — per-client rows are a pure function of
  (population seed, client id), so materialization order, batching, and
  shard-cache evictions can never change the data a client trains on.
* ``ClientSlabStore`` — the chunked/streaming ``StackedClients``: gathers
  must equal the source rows regardless of which path (cached shard vs
  direct row fetch) serves each member, with LRU residency bounded by
  ``cache_shards``.
* The simulator — a population dispatched through the streaming cohort
  engine reproduces the sequential oracle's digest stream, composes with
  ``run_sweep`` and synchronous FedAvg, and checkpoint/resume round-trips
  across shard-cache eviction boundaries.
"""
import os

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import (ClientSlabStore, StackedClients, SyntheticPopulation,
                        skewed_client_sizes)
from repro.federated import (SimConfig, SweepConfig, run_algorithm,
                             run_sweep)
from repro.models import model as M

C = 20
POP = dict(num_clients=C, num_classes=10, dim=32, seed=3,
           size_mean=24, size_spread=0.4, size_lo=8, size_hi=40)
SIM = dict(num_clients=C, horizon=2_500.0, eval_every=1_250.0, seed=0)
# engine-parity band, matching the golden suite's tolerance
RTOL, ATOL = 1e-4, 1e-3


@pytest.fixture(scope="module")
def pop():
    return SyntheticPopulation(**POP)


@pytest.fixture(scope="module")
def pop_world(pop):
    cfg = get_config("paper-synthetic-mlp")
    test = pop.test_dataset(512)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, pop, test, params


# ---------------------------------------------------------------------------
# SyntheticPopulation: determinism + structure
# ---------------------------------------------------------------------------

def test_population_rows_pure_in_client_id(pop):
    """member_rows is deterministic and order-free: re-materializing (in any
    batch grouping) yields identical rows — the property that makes shard
    eviction safe."""
    cids = np.asarray([0, 7, 13, 19])
    x1, y1 = pop.member_rows(cids)
    x2, y2 = pop.member_rows(cids)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    xp, yp = pop.member_rows(cids[::-1])
    np.testing.assert_array_equal(xp[::-1], x1)
    np.testing.assert_array_equal(yp[::-1], y1)
    for i, c in enumerate(cids):                    # singleton == batched
        xs, ys = pop.member_rows([c])
        np.testing.assert_array_equal(xs[0], x1[i])
        np.testing.assert_array_equal(ys[0], y1[i])


def test_population_getitem_matches_member_rows(pop):
    """The sequential oracle's ClientDataset view holds exactly the slab's
    valid rows (same data reaches both engines)."""
    for c in (0, 5, C - 1):
        ds = pop[c]
        n = int(pop.sizes[c])
        assert len(ds) == n
        x, y = pop.member_rows([c])
        np.testing.assert_array_equal(ds.data.x, x[0, :n])
        np.testing.assert_array_equal(ds.data.y, y[0, :n])
        # padding rows past the client's size are zeroed
        assert not np.any(x[0, n:])
        assert not np.any(y[0, n:])


def test_population_shape_and_skew(pop):
    assert len(pop) == C
    assert pop.sizes.shape == (C,)
    assert pop.sizes.min() >= POP["size_lo"]
    assert pop.sizes.max() <= POP["size_hi"]
    assert pop.n_max == int(pop.sizes.max())
    # label skew: the two dominant classes carry well over uniform mass
    x, y = pop.member_rows(np.arange(C))
    valid = np.arange(pop.n_max)[None, :] < pop.sizes[:, None]
    top2 = 0
    for c in range(C):
        counts = np.bincount(y[c][valid[c]], minlength=10)
        top2 += np.sort(counts)[-2:].sum() / counts.sum()
    assert top2 / C > 0.45            # vs 0.2 under uniform labels
    # held-out set is near-uniform and shares the mixture geometry
    test = pop.test_dataset(2048)
    frac = np.bincount(test.y, minlength=10) / len(test)
    assert frac.max() < 0.2
    assert test.x.shape == (2048, POP["dim"])


def test_skewed_client_sizes_validation():
    s = skewed_client_sizes(1000, mean=64, spread=0.6, lo=16, hi=512, seed=0)
    assert s.shape == (1000,) and s.min() >= 16 and s.max() <= 512
    assert np.median(s) < s.mean()    # log-normal right skew
    with pytest.raises(ValueError):
        skewed_client_sizes(10, mean=8, lo=16, hi=512)


# ---------------------------------------------------------------------------
# ClientSlabStore: gather correctness + LRU residency
# ---------------------------------------------------------------------------

def test_slab_store_gather_matches_source(pop):
    """Every service path — cached shard, fresh shard load, row path, and
    any mix — returns exactly the source's rows in input order."""
    store = ClientSlabStore(pop, shard_size=5, cache_shards=2, promote=2)
    assert store.num_shards == 4
    for cids in ([0, 1, 17, 6],       # shard0 cached, shards 1/3 row path
                 [5, 6, 7],           # shard1 promoted
                 [10, 11, 12, 3, 19],  # shard2 promoted -> evicts shard0
                 [0, 18]):             # shard0 gone: row path again
        want_x, want_y = pop.member_rows(cids)
        got_x, got_y = store.gather(cids)
        np.testing.assert_array_equal(np.asarray(got_x), want_x)
        np.testing.assert_array_equal(np.asarray(got_y), want_y)
    st = store.stats
    assert st["shard_loads"] == 3 and st["evictions"] == 1
    assert st["row_fetches"] > 0 and st["hits"] > 0
    assert st["resident_shards"] <= 2


def test_slab_store_lru_keeps_recently_used(pop):
    store = ClientSlabStore(pop, shard_size=5, cache_shards=2, promote=2)
    store.gather([0, 1])              # load shard 0
    store.gather([5, 6])              # load shard 1
    store.gather([0, 1])              # touch shard 0 (most recent)
    store.gather([10, 11])            # load shard 2 -> evicts shard 1
    loads = store.stats["shard_loads"]
    hits = store.stats["hits"]
    store.gather([0, 2])              # shard 0 must still be resident
    assert store.stats["shard_loads"] == loads
    assert store.stats["hits"] == hits + 2
    store.gather([5, 6])              # shard 1 was evicted: reload
    assert store.stats["shard_loads"] == loads + 1


def test_slab_store_prefetch_paths(pop):
    """prefetch() is a pure hint: correct predictions serve the next gather
    from the worker's shards/row-block (counted as prefetch hits), wrong
    predictions degrade to the synchronous paths with identical rows."""
    store = ClientSlabStore(pop, shard_size=5, cache_shards=2, promote=2)
    # shard 0 crosses promote (prefetch-loads), client 17 rides the row path
    store.prefetch([0, 1, 17])
    want_x, want_y = pop.member_rows([0, 1, 17])
    got_x, got_y = store.gather([0, 1, 17])
    np.testing.assert_array_equal(np.asarray(got_x), want_x)
    np.testing.assert_array_equal(np.asarray(got_y), want_y)
    st = store.stats
    assert st["prefetch_issued"] == 3
    assert st["prefetch_hits"] == 3       # 2 via the shard, 1 via the block
    assert st["shard_loads"] == 1 and st["hits"] == 2
    assert st["row_fetches"] == 1 and st["prefetch_wasted"] == 0
    # a stale row-block prediction is dropped, not served
    store.prefetch([6, 18])               # both sub-promote: one row block
    want_x, want_y = pop.member_rows([6, 19])
    got_x, got_y = store.gather([6, 19])  # actual wave differs
    np.testing.assert_array_equal(np.asarray(got_x), want_x)
    np.testing.assert_array_equal(np.asarray(got_y), want_y)
    st = store.stats
    assert st["prefetch_wasted"] == 1
    assert st["prefetch_hits"] == 3       # unchanged
    # already-cached shards are never re-issued
    issued = st["prefetch_issued"]
    store.prefetch([0, 1, 2])
    assert store.stats["prefetch_issued"] == issued
    # derived rates surface in stats for the bench artifact
    assert 0.0 < st["hit_rate"] < 1.0
    assert abs(st["hit_rate"] + st["row_fetch_rate"] - 1.0) < 1e-12


def test_slab_store_prefetch_inflight_shard_awaited(pop):
    """A gather that needs a shard whose prefetch is still in flight waits
    for the worker instead of re-materializing (one shard_load total)."""
    store = ClientSlabStore(pop, shard_size=5, cache_shards=2, promote=2)
    store.prefetch([5, 6, 7])
    # consume immediately: whether or not the future resolved yet, the
    # gather must integrate exactly one materialization of shard 1
    x, y = store.gather([5, 6, 7])
    want_x, want_y = pop.member_rows([5, 6, 7])
    np.testing.assert_array_equal(np.asarray(x), want_x)
    np.testing.assert_array_equal(np.asarray(y), want_y)
    st = store.stats
    assert st["shard_loads"] == 1 and st["prefetch_hits"] == 3


def test_slab_store_wraps_dataset_lists(pop):
    """build() on a plain client-dataset list streams the exact rows the
    monolithic StackedClients slab would hold."""
    clients = [pop[c] for c in range(8)]
    slab = StackedClients.from_datasets(clients)
    store = ClientSlabStore.build(clients, shard_size=3, cache_shards=2,
                                  promote=1)
    cids = [7, 0, 4, 2]
    x, y = store.gather(cids)
    np.testing.assert_array_equal(np.asarray(x)[:, :slab.n_max],
                                  slab.x[cids])
    np.testing.assert_array_equal(np.asarray(y)[:, :slab.n_max],
                                  slab.y[cids])
    auto = ClientSlabStore.build(clients)            # default geometry
    assert auto.shard_size == len(clients)


# ---------------------------------------------------------------------------
# Simulator composition: engines, sweep, fedavg, checkpoint/resume
# ---------------------------------------------------------------------------

def test_population_engines_agree(pop_world):
    """A population dispatched through the streaming cohort engine (forced
    multi-shard, small cache) reproduces the sequential oracle's per-receive
    digest stream."""
    cfg, pop, test, params = pop_world
    seq = run_algorithm("fedasync", cfg, params, pop, test,
                        SimConfig(engine="sequential",
                                  record_trajectory=True, **SIM))
    coh = run_algorithm("fedasync", cfg, params, pop, test,
                        SimConfig(engine="cohort", record_trajectory=True,
                                  shard_size=4, shard_cache=2,
                                  shard_promote=1, **SIM))
    assert seq.engine == "sequential" and coh.engine == "cohort"
    assert coh.cohorts > 0
    assert coh.dispatches == seq.dispatches
    np.testing.assert_allclose(np.asarray(coh.digests),
                               np.asarray(seq.digests), rtol=RTOL, atol=ATOL)


def test_population_auto_streaming(pop_world):
    """Passing a lazy population with shard_size=0 still routes through the
    streaming engine (a population cannot be monolithically stacked)."""
    cfg, pop, test, params = pop_world
    built = []
    orig = ClientSlabStore.build.__func__

    def spy(cls, datasets, **kw):
        s = orig(cls, datasets, **kw)
        built.append(s)
        return s

    ClientSlabStore.build = classmethod(spy)
    try:
        res = run_algorithm("fedasync", cfg, params, pop, test,
                            SimConfig(engine="cohort", **SIM))
    finally:
        ClientSlabStore.build = classmethod(orig)
    assert built and built[0].source is pop
    assert res.cohorts > 0 and np.isfinite(res.final_accuracy)


def test_population_run_sweep(pop_world):
    """Sweep lanes ride the streaming engine: lane 0 (default data seed)
    equals the standalone run, a reseeded lane diverges."""
    cfg, pop, test, params = pop_world
    sim = SimConfig(engine="cohort", record_trajectory=True, shard_size=4,
                    shard_cache=2, shard_promote=1, **SIM)
    res = run_sweep("fedasync", cfg, params, pop, test, sim,
                    SweepConfig(data_seeds=[SIM["seed"], 7]))
    solo = run_algorithm("fedasync", cfg, params, pop, test, sim)
    np.testing.assert_allclose(np.asarray(res.digests[0]),
                               np.asarray(solo.digests),
                               rtol=RTOL, atol=ATOL)
    # the reseeded lane reshuffles client batches: were the data seed dead,
    # both lanes would run the identical vmapped program bit-for-bit
    assert not np.array_equal(np.asarray(res.digests[1]),
                              np.asarray(res.digests[0]))


def test_population_fedavg(pop_world):
    """The synchronous runner consumes populations too (sizes come from the
    O(C) metadata array, rows stream per round)."""
    cfg, pop, test, params = pop_world
    res = run_algorithm("fedavg", cfg, params, pop, test,
                        SimConfig(engine="cohort", shard_size=8,
                                  num_clients=C, horizon=1_500.0,
                                  eval_every=750.0, seed=0))
    assert res.versions > 0 and np.isfinite(res.final_accuracy)


def _prune_to_mid_run(ckdir, total_dispatches):
    import shutil
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckdir))
    mid = [s for s in steps if 0 < s < total_dispatches]
    assert mid, steps
    for s in steps:
        if s > mid[-1]:
            shutil.rmtree(os.path.join(ckdir, f"step_{s:08d}"))


def test_timeline_peek_wave_matches_drain_rule():
    """peek_wave_cids replicates the cohort drain's wave selection — bound
    = t_first + latency_lo (strict), max_cohort cap, horizon truncation,
    ok-filter — without consuming a single event."""
    from repro.federated.timeline import Timeline

    tl = Timeline()
    t = np.array([10.0, 12.0, 19.9, 20.0, 25.0])
    ok = np.array([True, False, True, True, True])
    tl.extend_arrays(t, np.arange(5), np.array([3, 4, 5, 6, 7]),
                     np.zeros(5, np.int64), ok, [None] * 5)
    # bound = 10 + 10 = 20: events at 10, 12, 19.9 belong (20.0 excluded by
    # the strict head_t() < bound rule); cid 4 dropped by the ok filter
    np.testing.assert_array_equal(
        tl.peek_wave_cids(10.0, 256, 1e9), [3, 5])
    assert len(tl) == 5                      # nothing consumed
    # the cap counts ALL wave events (ok or not), like len(wave)
    np.testing.assert_array_equal(tl.peek_wave_cids(10.0, 2, 1e9), [3])
    # horizon: a first event past it trains nothing; a later one truncates
    assert tl.peek_wave_cids(10.0, 256, 5.0).size == 0
    np.testing.assert_array_equal(tl.peek_wave_cids(10.0, 256, 11.0), [3])
    # pops still see every event in order after all the peeking
    assert [tl.pop().cid for _ in range(5)] == [3, 4, 5, 6, 7]


def test_population_prefetch_digest_parity_across_eviction(pop_world):
    """SimConfig.prefetch is a pure overlap hint: a streaming run whose
    one-shard cache provably cycles through evictions produces a digest
    stream BIT-IDENTICAL to the same run without prefetch, while actually
    exercising the worker (prefetch issued and consumed)."""
    cfg, pop, test, params = pop_world
    kw = dict(SIM, record_trajectory=True, engine="cohort", shard_size=4,
              shard_cache=1, shard_promote=1)
    stores = []
    orig = ClientSlabStore.build.__func__

    def spy(cls, datasets, **kwargs):
        s = orig(cls, datasets, **kwargs)
        stores.append(s)
        return s

    ClientSlabStore.build = classmethod(spy)
    try:
        base = run_algorithm("fedasync", cfg, params, pop, test,
                             SimConfig(**kw))
        pre = run_algorithm("fedasync", cfg, params, pop, test,
                            SimConfig(prefetch=True, **kw))
    finally:
        ClientSlabStore.build = classmethod(orig)
    st_base, st_pre = stores[0].stats, stores[1].stats
    assert st_pre["evictions"] > 0                  # eviction-crossing run
    assert st_pre["prefetch_issued"] > 0            # the worker really ran
    assert st_pre["prefetch_hits"] > 0
    np.testing.assert_array_equal(np.asarray(pre.digests),
                                  np.asarray(base.digests))
    assert pre.dispatches == base.dispatches
    assert pre.cohorts == base.cohorts


def test_population_checkpoint_resume_across_eviction(pop_world, tmp_path,
                                                      monkeypatch):
    """Checkpoint/resume round-trips a streaming-population run whose shard
    cache (one resident shard, five shards touched) provably cycles through
    evictions: some shard is re-materialized after being dropped, and the
    resumed run still reproduces the uninterrupted digest stream."""
    cfg, pop, test, params = pop_world
    kw = dict(SIM, record_trajectory=True, engine="cohort", shard_size=4,
              shard_cache=1, shard_promote=1)
    loads = []
    orig = ClientSlabStore._load_shard
    monkeypatch.setattr(ClientSlabStore, "_load_shard",
                        lambda self, sid: loads.append(sid) or orig(self, sid))
    base = run_algorithm("fedasync", cfg, params, pop, test, SimConfig(**kw))
    # the eviction boundary was genuinely crossed: a shard loaded twice
    assert len(loads) > len(set(loads)), loads
    ckdir = str(tmp_path / "pop")
    ck = run_algorithm("fedasync", cfg, params, pop, test,
                       SimConfig(checkpoint_dir=ckdir,
                                 checkpoint_every=800.0, **kw))
    np.testing.assert_array_equal(np.asarray(ck.digests),
                                  np.asarray(base.digests))
    _prune_to_mid_run(ckdir, base.dispatches)
    res = run_algorithm("fedasync", cfg, params, pop, test,
                        SimConfig(checkpoint_dir=ckdir,
                                  checkpoint_every=800.0, resume=True, **kw))
    np.testing.assert_array_equal(np.asarray(res.digests),
                                  np.asarray(base.digests))
    assert res.dispatches == base.dispatches
    assert res.launched == base.launched
