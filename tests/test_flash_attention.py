"""Flash-attention Pallas kernel vs the chunked-attention / naive oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref
from repro.models.layers import chunked_attention
from tests.test_attention import naive_attention


@pytest.mark.parametrize("B,Sq,Sk,H,Hkv,hd,causal", [
    (2, 64, 64, 4, 2, 16, True),
    (1, 128, 128, 8, 8, 32, True),     # MHA
    (2, 64, 64, 4, 1, 16, False),      # MQA, bidirectional
    (1, 100, 100, 2, 2, 8, True),      # non-block-multiple seq
    (1, 33, 33, 4, 2, 64, False),
])
def test_flash_vs_naive(B, Sq, Sk, H, Hkv, hd, causal):
    key = jax.random.PRNGKey(Sq * H)
    q = jax.random.normal(key, (B, Sq, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Sk, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Sk, Hkv, hd))
    out = flash_attention(q, k, v, causal=causal, block_q=32, block_k=16)
    want = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_dtypes(dtype):
    key = jax.random.PRNGKey(7)
    dt = jnp.dtype(dtype)
    q = jax.random.normal(key, (1, 64, 4, 32), dt)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 64, 2, 32), dt)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 64, 2, 32), dt)
    out = flash_attention(q, k, v, causal=True)
    assert out.dtype == dt
    want = chunked_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32), causal=True,
                             q_chunk=16, kv_chunk=16)
    tol = 3e-2 if dtype == "bfloat16" else 3e-4
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,S,H,Hkv,hd,causal", [
    (2, 64, 4, 2, 16, True),     # GQA
    (1, 100, 2, 2, 8, False),    # MHA, bidirectional, ragged seq
    (1, 48, 4, 1, 32, True),     # MQA
])
def test_flash_vs_ref_oracle(B, S, H, Hkv, hd, causal):
    """Kernel vs its kernels/ref.py oracle (the kernel-contract pairing:
    every Pallas kernel ships a pure-jnp reference in ref.py)."""
    key = jax.random.PRNGKey(S + H)
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, hd))
    out = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_block_shape_invariance():
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (1, 96, 4, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 96, 4, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 96, 4, 16))
    outs = [flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
            for bq, bk in [(96, 96), (32, 32), (16, 48), (48, 8)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-4, atol=1e-4)


def test_flash_matches_model_attention_path():
    """Drop-in equivalence with the jax-native chunked loop used in models."""
    key = jax.random.PRNGKey(11)
    q = jax.random.normal(key, (2, 80, 8, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 80, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 80, 2, 16))
    a = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    b = chunked_attention(q, k, v, causal=True, q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)
