"""Federated runtime behaviour: determinism, staleness, algorithm orderings.

Two regimes: a QUICK world (20 clients, short horizon) for mechanical
invariants, and the PAPER world (50 clients, 20% concurrency, Dirichlet 0.1,
~half a virtual day) where learning-quality orderings are measurable.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import PSAConfig
from repro.data import (ClientDataset, dirichlet_partition,
                        make_calibration_batch, make_classification,
                        train_test_split)
from repro.federated import SimConfig, run_algorithm
from repro.models import model as M


def _world(num_clients, alpha, seed=0):
    cfg = get_config("paper-synthetic-mlp")
    full = make_classification(10_000, 10, 32, seed=seed, class_sep=0.7)
    train, test = train_test_split(full, 0.1)
    parts = dirichlet_partition(train, num_clients, alpha=alpha, seed=seed)
    clients = [ClientDataset(train.subset(ix)) for ix in parts]
    calib = make_calibration_batch(train, 64, "gaussian")
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    return cfg, clients, test, calib, params


@pytest.fixture(scope="module")
def quick():
    return _world(20, 0.1) + (SimConfig(num_clients=20, horizon=12_000,
                                        eval_every=6_000, seed=0),)


@pytest.fixture(scope="module")
def paper_world():
    return _world(50, 0.1) + (SimConfig(num_clients=50, horizon=40_000,
                                        eval_every=10_000, seed=0),)


def test_determinism(quick):
    cfg, clients, test, calib, params, sim = quick
    r1 = run_algorithm("fedbuff", cfg, params, clients, test, sim)
    r2 = run_algorithm("fedbuff", cfg, params, clients, test, sim)
    assert r1.final_accuracy == r2.final_accuracy
    assert r1.dispatches == r2.dispatches
    assert r1.times == r2.times


def test_staleness_is_positive_under_asynchrony(quick):
    cfg, clients, test, calib, params, sim = quick
    r = run_algorithm("fedasync", cfg, params, clients, test, sim)
    taus = [e["tau"] for e in r.receive_log]
    assert max(taus) > 0, "async run must observe stale updates"
    assert r.versions == r.dispatches  # fedasync updates on every receipt


def test_fedbuff_update_frequency(quick):
    cfg, clients, test, calib, params, sim = quick
    r = run_algorithm("fedbuff", cfg, params, clients, test, sim,
                      server_kwargs={"buffer_size": 5})
    assert r.versions == r.dispatches // 5


def test_fedpsa_logs_algorithm1_internals(quick):
    cfg, clients, test, calib, params, sim = quick
    r = run_algorithm("fedpsa", cfg, params, clients, test, sim,
                      psa_cfg=PSAConfig(queue_len=10), calib_batch=calib)
    assert len(r.server_log) == r.versions
    early = r.server_log[0]
    np.testing.assert_allclose(early["weights"], 0.2, atol=1e-6)  # uniform
    assert early["temp"] is None
    late = r.server_log[-1]
    assert late["temp"] is not None and late["temp"] > 0
    assert abs(np.sum(late["weights"]) - 1) < 1e-4
    assert np.all(np.asarray(late["kappas"]) <= 1.0 + 1e-5)


def test_longtail_latency_supported(quick):
    cfg, clients, test, calib, params, _ = quick
    sim = SimConfig(num_clients=20, horizon=8_000, eval_every=4_000, seed=0,
                    latency_kind="longtail", latency_lo=10, latency_hi=500)
    r = run_algorithm("fedbuff", cfg, params, clients, test, sim)
    assert r.dispatches > 0 and np.isfinite(r.final_accuracy)


@pytest.mark.slow
def test_all_algorithms_learn(paper_world):
    cfg, clients, test, calib, params, sim = paper_world
    for alg in ("fedpsa", "fedbuff", "fedasync", "fedavg", "ca2fl", "fedfa", "fedpac"):
        r = run_algorithm(alg, cfg, params, clients, test, sim,
                          psa_cfg=PSAConfig(), calib_batch=calib)
        assert r.final_accuracy > 0.18, (alg, r.final_accuracy)


@pytest.mark.slow
def test_fedpsa_beats_fedasync_noniid(paper_world):
    """The paper's central qualitative claim at alpha=0.1 (Table 2)."""
    cfg, clients, test, calib, params, sim = paper_world
    r_psa = run_algorithm("fedpsa", cfg, params, clients, test, sim,
                          psa_cfg=PSAConfig(), calib_batch=calib)
    r_async = run_algorithm("fedasync", cfg, params, clients, test, sim)
    assert r_psa.final_accuracy > r_async.final_accuracy
    r_buff = run_algorithm("fedbuff", cfg, params, clients, test, sim)
    assert r_psa.final_accuracy > r_buff.final_accuracy


def test_aulc_monotone_in_curve():
    from repro.federated.simulator import SimResult
    r = SimResult(times=[0, 43200, 86400], accuracies=[0.0, 0.5, 0.5])
    assert 0 < r.aulc < 1
    r2 = SimResult(times=[0, 43200, 86400], accuracies=[0.5, 0.75, 0.75])
    assert r2.aulc > r.aulc
