"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape) on the single-pod mesh:
    compute term    = flops_per_device / PEAK_FLOPS          [s]
    memory term     = bytes_per_device / HBM_BW              [s]
    collective term = ici_bytes_per_device / ICI_BW          [s]

Hardware constants (TPU v5e class, per the assignment): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI (we charge the ring estimate against one
link — a conservative single-axis view).

MODEL_FLOPS uses the mode-appropriate analytic formula over ACTIVE params:
train 6*N*T, prefill 2*N*T, decode 2*N*B; the ratio MODEL_FLOPS/HLO_FLOPs
exposes remat & redundancy overhead.
"""
from __future__ import annotations

import glob
import json
import os
import sys

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

ART_DIR = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def model_flops(rec: dict) -> float:
    n = rec["params_active"]
    s, b = rec["seq_len"], rec["global_batch"]
    mode = rec["mode"]
    if mode == "train":
        return 6.0 * n * s * b
    if mode in ("prefill", "encode"):
        return 2.0 * n * s * b
    return 2.0 * n * b  # decode: one token per sequence


def load(mesh: str = "pod"):
    recs = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_row(rec: dict) -> dict:
    w = rec["world"]
    t_c = rec["flops_per_device"] / PEAK_FLOPS
    t_m = rec["bytes_per_device"] / HBM_BW
    t_i = rec["collective_ici_bytes"] / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_i, "collective"))[1]
    mf = model_flops(rec)
    ratio = mf / max(rec["flops_per_device"] * w, 1.0)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mode": rec["mode"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_i,
        "dominant": dom, "model_flops": mf,
        "useful_ratio": ratio,
        "roofline_frac": max(t_c, t_m, t_i) and t_c / max(t_c, t_m, t_i),
    }


IMPROVEMENT_NOTE = {
    ("memory", "ssm"): "chunkwise-parallel recurrence keeps state in VMEM across a chunk instead of round-tripping HBM per token",
    ("memory", "hybrid"): "chunkwise mamba scan + wider fused steps cut per-token state traffic",
    ("memory", "dense"): "less remat (policy=dots) trades HBM re-reads for activation residency",
    ("memory", "moe"): "larger expert blocks amortize dispatch buffer traffic",
    ("memory", "audio"): "less remat (policy=dots) trades HBM re-reads for activation residency",
    ("memory", "vlm"): "less remat + fused patch projector",
    ("compute", "dense"): "already MXU-bound: raise per-chip utilization via larger q_chunk tiles",
    ("compute", "moe"): "dropless grouped-matmul kernels remove capacity-padding flops",
    ("collective", "dense"): "overlap all-gathers with layer compute (collective matmul); shard KV heads instead of replicating",
    ("collective", "moe"): "hierarchical all-to-all over (pod, model) reduces cross-pod expert traffic",
    ("collective", "ssm"): "batch-shard the recurrent state to remove per-step psums",
    ("collective", "hybrid"): "batch-shard mamba state; window attention collectives are minor",
}


def note_for(row, family):
    return IMPROVEMENT_NOTE.get((row["dominant"], family),
                                "rebalance data/model axes for this shape")


def main():
    from repro.configs import get_config
    recs = [r for r in load("pod") if r.get("status") == "ok"]
    if not recs:
        print("no dry-run artifacts found; run repro.launch.dryrun first",
              file=sys.stderr)
        return 1
    print(f"{'arch':18s} {'shape':12s} {'mode':8s} "
          f"{'compute_s':>10s} {'memory_s':>10s} {'collect_s':>10s} "
          f"{'dominant':>10s} {'useful':>7s}")
    rows = []
    for rec in recs:
        row = roofline_row(rec)
        rows.append(row)
        print(f"{row['arch']:18s} {row['shape']:12s} {row['mode']:8s} "
              f"{row['compute_s']:10.4f} {row['memory_s']:10.4f} "
              f"{row['collective_s']:10.4f} {row['dominant']:>10s} "
              f"{row['useful_ratio']:7.3f}")
    # machine-readable dump for EXPERIMENTS.md
    out = os.path.join(ART_DIR, "..", "roofline_pod.json")
    for row in rows:
        fam = get_config(row["arch"]).family
        row["note"] = note_for(row, fam)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\n[roofline] {len(rows)} rows -> {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
