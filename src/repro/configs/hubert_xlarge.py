"""hubert-xlarge [audio] — encoder-only, w2v2 arch [arXiv:2106.07447].

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (masked-cluster prediction
classes). Encoder-only: bidirectional attention, NO decode step (decode_32k
and long_500k are skipped — see DESIGN.md §Arch-applicability). The conv
feature extractor is a stub: ``input_specs`` provides precomputed frame
embeddings (B, S, d_model).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    block_pattern=("attn",),
    ffn_pattern=("dense",),
    ffn_act="gelu",
    frontend="audio",
    long_context_window=None,
    # §Perf opt: pure data parallelism (binding term 8.1s -> 5.5s)
    pure_data_parallel=True,
)
