"""Client-side data loading: epoch iterators + device-resident stacking.

``ClientDataset`` is the per-client host view (shuffled epoch batches).
``StackedClients`` is the cohort engine's device view: every client's data
padded into one ``(C, n_max, ...)`` slab with sizes and validity masks, so
local training for a whole cohort is a single gather + vmapped scan instead
of C python loops.

Both views are layout-polymorphic over the registry's two data kinds:
*image* shards hold ``x (n, ...) float32`` features and ``y (n,) int``
labels and batch as ``{"x", "y"}``; *token* shards (federated LM
fine-tuning) hold ``x = y = (n, seq) int32`` token sequences and batch as
``{"tokens", "labels"}`` — the keys ``models.registry``'s token
``client_loss`` (i.e. ``model_lib.loss_fn``) speaks. The kind is inferred
from the feature dtype (integer => tokens), so the cohort slab becomes a
``(C, n_max, seq)`` int32 token/label pair with the SAME sizes/mask/shuffle
machinery as the image slab.

Both views draw batch order from ``epoch_batch_indices`` — the one shuffle
routine — so the vectorized engine visits exactly the batches the legacy
per-client loop would (same ``np.random.RandomState`` stream, same
drop-last rule), which is what makes the 1e-5 parity tests meaningful.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Sequence

import numpy as np

from repro.data.synthetic import SyntheticClassification


def epoch_batch_indices(n: int, num_epochs: int, batch_size: int,
                        seed: int) -> np.ndarray:
    """Batch schedule for one client: ``(steps, bs)`` int32 indices into its
    ``n`` samples, ``bs = min(batch_size, n)``, drop-last, one fresh
    permutation per epoch from ``RandomState(seed)``."""
    rng = np.random.RandomState(seed)
    bs = min(batch_size, n)
    m = n // bs                       # drop-last batch count per epoch
    out = np.empty((num_epochs * m, bs), np.int32)
    for e in range(num_epochs):
        out[e * m:(e + 1) * m] = rng.permutation(n)[:m * bs].reshape(m, bs)
    return out


def data_kind_of(x: np.ndarray) -> str:
    """The registry data kind a feature array implies: integer dtypes are
    token-id sequences, everything else image/feature rows."""
    return "tokens" if np.issubdtype(np.asarray(x).dtype, np.integer) \
        else "image"


@dataclass
class ClientDataset:
    data: SyntheticClassification

    def __len__(self):
        return len(self.data)

    @property
    def kind(self) -> str:
        return data_kind_of(self.data.x)

    def epochs(self, num_epochs: int, batch_size: int, seed: int) -> Iterator[dict]:
        tokens = self.kind == "tokens"
        for idx in epoch_batch_indices(len(self.data), num_epochs,
                                       batch_size, seed):
            if tokens:
                yield {"tokens": self.data.x[idx].astype(np.int32),
                       "labels": self.data.y[idx].astype(np.int32)}
            else:
                yield {"x": self.data.x[idx].astype(np.float32),
                       "y": self.data.y[idx].astype(np.int32)}


@dataclass
class StackedClients:
    """All clients' data as one padded slab (the cohort engine's layout).

    ``x[c, :sizes[c]]`` are client ``c``'s real samples; rows beyond that are
    zero padding with ``mask`` False. Padding never reaches a loss term: the
    batch schedules index only real rows, and ragged batch tails are masked
    inside the engine's loss (for token shards, by turning the padded rows'
    labels into the ``-1`` no-target sentinel).

    ``kind == "image"``: x (C, n_max, ...) float32, y (C, n_max) int32.
    ``kind == "tokens"``: x and y both (C, n_max, seq) int32.
    """
    x: np.ndarray        # (C, n_max, ...) float32 features | int32 tokens
    y: np.ndarray        # (C, n_max[, seq]) int32 labels
    sizes: np.ndarray    # (C,) int32 true per-client sample counts
    mask: np.ndarray     # (C, n_max) bool — True on real rows
    num_classes: int
    kind: str = "image"

    def __len__(self):
        return self.x.shape[0]

    @property
    def n_max(self) -> int:
        return self.x.shape[1]

    @classmethod
    def from_datasets(cls, datasets: Sequence[ClientDataset]) -> "StackedClients":
        sizes = np.asarray([len(d) for d in datasets], np.int32)
        n_max = int(sizes.max())
        d0 = datasets[0].data
        kind = data_kind_of(d0.x)
        feat = d0.x.shape[1:]
        lab = d0.y.shape[1:]
        C = len(datasets)
        x = np.zeros((C, n_max) + feat,
                     np.int32 if kind == "tokens" else np.float32)
        y = np.zeros((C, n_max) + lab, np.int32)
        mask = np.zeros((C, n_max), bool)
        for c, d in enumerate(datasets):
            n = sizes[c]
            x[c, :n] = d.data.x.astype(x.dtype)
            y[c, :n] = d.data.y.astype(np.int32)
            mask[c, :n] = True
        return cls(x=x, y=y, sizes=sizes, mask=mask,
                   num_classes=d0.num_classes, kind=kind)


def batch_iterator(ds: SyntheticClassification, batch_size: int,
                   seed: int = 0) -> Iterator[dict]:
    """Endless shuffled batches (evaluation/training streams)."""
    rng = np.random.RandomState(seed)
    n = len(ds)
    while True:
        order = rng.permutation(n)
        for start in range(0, n - batch_size + 1, batch_size):
            idx = order[start:start + batch_size]
            yield {"x": ds.x[idx].astype(np.float32),
                   "y": ds.y[idx].astype(np.int32)}
