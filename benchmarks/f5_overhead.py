"""Paper Fig. 5: computation & communication overhead per method.

Measures, for one client upload on the paper model:
* client computation: local-update wall time, and FedPSA's extra
  sensitivity+sketch time,
* communication: bytes of the model update vs bytes of FedPSA's extra
  payload (k floats) -> the compression ratio k/d (Eq. 13).
The claim: FedPSA's additions are a negligible fraction of both budgets.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro.common import tree as tu
from repro.core import PSAConfig
from repro.federated import make_sketch_fn
from repro.federated.client import local_update
from benchmarks import common


def _time(fn, *a, reps=3, **kw):
    fn(*a, **kw)  # warmup / compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*a, **kw)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def main(argv=None):
    cfg, clients, test, calib, params = common.world(0.1)
    d = tu.tree_size(params)
    psa = PSAConfig()

    t_local = _time(lambda: local_update(params, cfg, clients[0], epochs=5,
                                         batch_size=64, lr=0.01, seed=0),
                    reps=2)
    sketch_fn = make_sketch_fn(cfg, calib["gaussian"], psa)
    t_sketch = _time(sketch_fn, params, reps=5)

    update_bytes = d * 4
    sketch_bytes = psa.sketch_k * 4
    rows = {
        "model_params_d": d,
        "local_update_s": t_local,
        "sketch_s": t_sketch,
        "sketch_over_local_pct": 100.0 * t_sketch / t_local,
        "update_bytes": update_bytes,
        "sketch_bytes": sketch_bytes,
        "comm_overhead_pct": 100.0 * sketch_bytes / update_bytes,
        "compression_ratio_k_over_d": psa.sketch_k / d,
    }
    for k, v in rows.items():
        print(f"f5,{k},{v}")
    common.save("f5_overhead", rows)
    # the paper's claim: both overheads are marginal
    print(f"f5,claim_comm_overhead_below_1pct,{rows['comm_overhead_pct'] < 1.0}")
    return rows


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
