"""Property-based invariants (hypothesis). The whole module is skipped on
environments without ``hypothesis`` (``pip install -r requirements-dev.txt``
restores it) — the deterministic variants in ``test_core_psa.py`` keep the
invariants covered on a bare install."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (PSAConfig, buffer_full, cosine, init_state,
                        psa_weights, server_aggregate, server_receive)
from repro.data import dirichlet_partition, make_classification


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_cosine_bounds(seed):
    rng = np.random.RandomState(seed % 100000)
    a = jnp.asarray(rng.randn(16).astype(np.float32))
    b = jnp.asarray(rng.randn(16).astype(np.float32))
    c = float(cosine(a, b))
    assert -1.0001 <= c <= 1.0001
    assert abs(float(cosine(a, a)) - 1.0) < 1e-5


@given(st.lists(st.floats(-1, 1, width=32), min_size=2, max_size=8),
       st.floats(0.125, 20.0, width=32))
@settings(max_examples=50, deadline=None)
def test_psa_weights_simplex(kappas, temp):
    w = np.asarray(psa_weights(jnp.asarray(kappas, jnp.float32), jnp.float32(temp)))
    assert abs(w.sum() - 1.0) < 1e-4
    assert (w >= 0).all()
    # monotone: higher kappa never gets lower weight
    order = np.argsort(kappas)
    assert (np.diff(w[order]) >= -1e-6).all()


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_partition_min_size(seed):
    ds = make_classification(1000, 5, 8, seed=seed % 17)
    parts = dirichlet_partition(ds, 10, alpha=0.1, seed=seed, min_size=2)
    assert min(len(p) for p in parts) >= 2


@given(st.integers(2, 6), st.integers(1, 20), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_psa_ring_buffer_property(buffer_size, n_pushes, seed):
    """Stacked-ring invariant: after any receive/aggregate interleaving, slot
    ``j`` holds the most recent update whose in-cycle index was ``j``, the
    fill count equals receives since the last flush, and the thermometer
    counts every receive."""
    rng = np.random.RandomState(seed % 100000)
    cfg = PSAConfig(buffer_size=buffer_size, queue_len=50)
    d = 5
    state = init_state(cfg, d, jnp.ones(cfg.sketch_k))
    params = jnp.zeros((d,))
    expected = {}  # slot -> latest update written there
    fill = 0
    for i in range(n_pushes):
        u = jnp.asarray(rng.randn(d).astype(np.float32))
        state = server_receive(state, u, jnp.ones(cfg.sketch_k))
        expected[fill % buffer_size] = np.asarray(u)
        fill += 1
        assert int(state.count) == fill
        for slot, want in expected.items():
            np.testing.assert_allclose(np.asarray(state.buffer[slot]), want,
                                       rtol=1e-6)
        assert bool(buffer_full(state)) == (fill >= buffer_size)
        if bool(buffer_full(state)):
            state, params, info = server_aggregate(state, params, cfg)
            fill = 0
            assert int(state.count) == 0
            assert abs(float(np.sum(np.asarray(info.weights))) - 1.0) < 1e-4
    assert int(state.thermo.count) == n_pushes
    assert np.all(np.isfinite(np.asarray(params)))
