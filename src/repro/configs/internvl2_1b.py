"""internvl2-1b [vlm] — InternViT + Qwen2-0.5B LM [arXiv:2404.16821].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655. The vision encoder is
a stub per the assignment carve-out: ``input_specs`` provides 256 precomputed
patch embeddings (B, 256, d_model) which a learned projector maps into the LM
space; the LM backbone here is the deliverable. 14 heads / 151655 vocab do
not divide the 16-way model axis — rules_for() falls back per axis.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    block_pattern=("attn",),
    ffn_pattern=("dense",),
    frontend="vision",
    num_prefix_tokens=256,
    long_context_window=8192,
    # §Perf opt: at 1B params, model parallelism is pure overhead — replicate
    # weights, shard batch over all 256 chips: binding term 31.0s -> 2.2s (14x)
    pure_data_parallel=True,
)
