"""Functional staleness-policy core: every async server is one pure step.

The server side of each algorithm is expressed as a ``Policy`` — an ``init``
building an immutable ``ServerState`` pytree (flat contiguous f32 parameter
vector + fixed-size stacked ring buffers) and a pure, jit-compiled,
buffer-donating

    ``policy.step(state, arrival) -> (state, StepInfo)``

with ``lax.cond`` replacing all host-side branching, so one arrival costs at
most ONE device call (aggregation, when the buffer fills, happens inside the
same fused step; FedPSA's global-sketch refresh is traced into the taken
branch of the cond). Buffered Eq. 20 applies run through the Pallas
``buffer_agg`` kernel over the flat layout.

Timeline-preserving hyperparameters (fedasync's mixing alpha, fedbuff's
staleness exponent, FedPSA's temperature slope/floor, server learning rates,
...) live in ``ServerState.hyper`` — a ``PolicyParams`` pytree of traced
scalars — NOT in python closures. Two consequences: (a) runs that differ
only in such a hyperparameter share ONE compiled step (the step functions
themselves are cached by structural key: flat layout + buffer shapes), and
(b) stacking ``ServerState`` with a leading lane axis and ``jax.vmap``-ing
the step runs a whole hyperparameter grid as one batched simulation (the
sweep engine, ``federated.simulator.run_sweep``). Shape-determining
parameters (``buffer_size``, ``queue_len``, ``sketch_k``) remain static.

Staleness weighting is a design space (AsyncFedED's Euclidean-distance
adaptive weights, the distance-metric ablations of "Revisiting Gradient
Staleness", the paper's behavioral kappa) — adding a policy means writing
one ``step`` function and registering it; see ARCHITECTURE.md for a ~30-line
walkthrough.

Implemented: fedasync, fedbuff, fedpsa, ca2fl, fedfa, fedpac, plus the
distance-based ``asyncfeded`` proving pluggability.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import sharding
from repro.common import tree as tu
from repro.core import aggregation, psa as psa_lib


class PolicyParams(NamedTuple):
    """Timeline-preserving hyperparameters as traced scalars, one uniform
    pytree for every policy (a policy simply ignores the fields it does not
    read — dead leaves cost nothing under jit). Lives in
    ``ServerState.hyper``, so a lane-stacked state carries per-lane values.

    Everything here may vary per sweep lane; anything that changes state
    SHAPES (buffer_size, queue_len, sketch_k, num_clients) or the client
    program (use_sensitivity) must NOT be here — lanes share those.
    """
    alpha: jnp.ndarray = None            # fedasync / asyncfeded mixing
    a: jnp.ndarray = None                # staleness polynomial exponent
    server_lr: jnp.ndarray = None        # buffered-apply learning rate
    beta: jnp.ndarray = None             # fedfa recency decay
    gamma: jnp.ndarray = None            # fedpsa temperature slope
    delta: jnp.ndarray = None            # fedpsa temperature floor
    eps: jnp.ndarray = None              # asyncfeded distance epsilon
    use_thermometer: jnp.ndarray = None  # fedpsa w/o-T ablation switch
    dist_mode: jnp.ndarray = None        # asyncfeded metric (0=l2, 1=cosine)


HYPER_DEFAULTS = dict(alpha=0.6, a=0.5, server_lr=1.0, beta=0.5, gamma=5.0,
                      delta=0.5, eps=1e-8, use_thermometer=True,
                      dist_mode=psa_lib.DIST_MODE_L2)
HYPER_FIELDS = PolicyParams._fields

# Metric-name aliases accepted for ``dist_mode`` (the arithmetic variants);
# "sketch" changes the traced program and is a structural policy choice, not
# a per-lane value — ``asyncfeded_policy(metric="sketch")`` builds it.
_DIST_MODE_CODES = {"l2": psa_lib.DIST_MODE_L2,
                    "cosine": psa_lib.DIST_MODE_COSINE}


def make_hyper(**kw) -> PolicyParams:
    """Concrete ``PolicyParams`` from keyword overrides over the defaults.

    Raises on unknown keys — in particular on shape-determining parameters
    (buffer_size, queue_len, sketch_k), which cannot vary per lane.
    ``dist_mode`` also accepts the metric names "l2"/"cosine".
    """
    bad = sorted(set(kw) - set(HYPER_FIELDS))
    if bad:
        raise ValueError(
            f"unknown policy hyperparameter(s) {bad}; per-lane tunables are "
            f"{sorted(HYPER_FIELDS)} (shape parameters like buffer_size/"
            f"queue_len/sketch_k are static and must be shared)")
    vals = dict(HYPER_DEFAULTS)
    vals.update(kw)
    if isinstance(vals["dist_mode"], str):
        try:
            vals["dist_mode"] = _DIST_MODE_CODES[vals["dist_mode"]]
        except KeyError:
            raise ValueError(
                f"dist_mode {vals['dist_mode']!r} is not a traced metric; "
                f"traced: {sorted(_DIST_MODE_CODES)} ('sketch' alters the "
                f"program — request it via asyncfeded_policy(metric="
                f"'sketch'))") from None
    return PolicyParams(**{
        k: (jnp.asarray(bool(v)) if k == "use_thermometer"
            else jnp.float32(v)) for k, v in vals.items()})


class RingState(NamedTuple):
    """Fixed-size stacked ring buffer over the flat parameter layout."""
    data: jnp.ndarray    # (L, d) f32
    count: jnp.ndarray   # int32 — fill level (flush policies) or total writes

    @property
    def capacity(self) -> int:
        return self.data.shape[0]


class CacheState(NamedTuple):
    """CA2FL per-client cached deltas h_i plus their running sum."""
    data: jnp.ndarray    # (num_clients, d) f32
    valid: jnp.ndarray   # (num_clients,) bool — client seen at least once
    total: jnp.ndarray   # (d,) f32 running sum of cached deltas


class ServerState(NamedTuple):
    """One pytree for every policy; unused sub-states are None (static
    structure, so each policy jit-compiles its own step once). ``hyper``
    holds the policy's traced hyperparameters — per-lane when the state is
    stacked with a leading lane axis (``federated.servers.LanePolicyServer``).
    """
    params: jnp.ndarray                         # (d,) flat f32 global model
    version: jnp.ndarray                        # int32 completed updates
    ring: Optional[RingState]
    psa: Optional[psa_lib.PSAState]
    cache: Optional[CacheState]
    hyper: Optional[PolicyParams] = None


class Arrival(NamedTuple):
    """One client completion as the server sees it. ``update`` and
    ``client_params`` keep the client's pytree layout — flattening happens
    inside the jitted step (one fused device call per arrival)."""
    update: Any              # pytree dw_i
    client_params: Any       # pytree w_i
    tau: jnp.ndarray         # f32 version gap at ingest
    client_id: jnp.ndarray   # int32
    data_size: jnp.ndarray   # f32
    sketch: jnp.ndarray      # (k,) f32 behavioral sketch (zeros if unused)


class StepInfo(NamedTuple):
    """Fixed-shape per-step diagnostics (host converts to logs)."""
    updated: jnp.ndarray     # bool — global params changed this step
    weights: jnp.ndarray     # (L,) aggregation weights (L=0 for mix policies)
    kappas: jnp.ndarray      # (L,) buffer kappas (fedpsa)
    temp: jnp.ndarray        # f32 softmax temperature (fedpsa)
    temp_valid: jnp.ndarray  # bool — temp meaningful (thermometer full)
    mix: jnp.ndarray         # f32 mixing/scale coefficient (mix policies)


@dataclasses.dataclass(frozen=True)
class Policy:
    """The pluggable staleness-policy interface.

    ``init(params, hyper=None)`` builds the state with the factory-call
    hyperparameters unless an explicit ``PolicyParams`` is given (the sweep
    engine inits each lane with its own). ``hyper_defaults`` records the
    factory-call values as a hashable ``(field, value)`` tuple so callers
    (``run_sweep``) can merge per-lane overrides on top of them.
    """
    name: str
    init: Callable[..., ServerState]             # (params[, hyper]) -> state
    step: Callable[[ServerState, Arrival], Tuple[ServerState, StepInfo]]
    spec: tu.FlatSpec                            # flat <-> pytree layout
    # the unjitted step — what batched ingest scans over (wave of arrivals
    # as one device call); ``step`` is jit_step(raw_step). Shared across
    # policies that differ only in hyper values (structural step cache), so
    # keying compiled artifacts on ``raw_step`` maximizes jit reuse.
    raw_step: Optional[Callable[[ServerState, Arrival],
                                Tuple[ServerState, StepInfo]]] = None
    sketch_k: int = 0
    needs_sketch: bool = False
    client_align: float = 0.0
    # (StepInfo, meta) -> host log dict for an applied update, or None.
    # Owned by the policy so new policies get logging without shim edits.
    log_fn: Optional[Callable[[StepInfo, dict], Optional[dict]]] = None
    hyper_defaults: tuple = ()                   # ((field, value), ...)


def _log_mix(info: StepInfo, meta: dict) -> dict:
    return {"tau": meta.get("tau", 0), "weight": float(info.mix)}


def _log_psa(info: StepInfo, meta: dict) -> dict:
    return {
        "weights": np.asarray(info.weights),
        "kappas": np.asarray(info.kappas),
        "temp": float(info.temp) if bool(info.temp_valid) else None,
    }


# Donating the state buffers lets XLA update the (L, d) ring and the flat
# params in place instead of copying them every arrival.
def jit_step(fn):
    return jax.jit(fn, donate_argnums=(0,))


# Step functions cached by STRUCTURAL key (policy name + flat layout + buffer
# shapes + sketch-refresh identity) — hyper values live in the traced state,
# so every hyperparameter setting of a policy shares one (raw_step, step)
# pair and with it one jit cache entry per arrival shape.
_STEP_FN_CACHE: dict = {}


def _shared_steps(key, build):
    hit = _STEP_FN_CACHE.get(key)
    if hit is None:
        raw = build()
        hit = (raw, jit_step(raw))
        _STEP_FN_CACHE[key] = hit
    return hit


def _ring_push(ring: RingState, row: jnp.ndarray) -> RingState:
    data, _ = tu.ring_update(ring.data, row.astype(jnp.float32), ring.count)
    return RingState(data=data, count=ring.count + 1)


def make_info(L: int, *, updated, weights=None, kappas=None, temp=0.0,
              temp_valid=False, mix=0.0) -> StepInfo:
    z = jnp.zeros((L,), jnp.float32)
    return StepInfo(
        updated=jnp.asarray(updated, jnp.bool_),
        weights=z if weights is None else weights.astype(jnp.float32),
        kappas=z if kappas is None else kappas.astype(jnp.float32),
        temp=jnp.asarray(temp, jnp.float32),
        temp_valid=jnp.asarray(temp_valid, jnp.bool_),
        mix=jnp.asarray(mix, jnp.float32),
    )


def base_state(spec: tu.FlatSpec, params,
               hyper: Optional[PolicyParams] = None) -> ServerState:
    # copy: for a single-leaf f32 tree flatten can alias the caller's buffer,
    # which the donating step would invalidate on the first receive; the
    # hyper leaves are copied for the same reason (the policy's default
    # PolicyParams is shared by every server built from the cached Policy)
    vec = jnp.array(spec.flatten(params), copy=True)
    hyper = make_hyper() if hyper is None else hyper
    return ServerState(params=vec, version=jnp.int32(0),
                       ring=None, psa=None, cache=None,
                       hyper=jax.tree_util.tree_map(jnp.copy, hyper))


# ---------------------------------------------------------------------------
# Immediate-mix policies (one global update per arrival)
# ---------------------------------------------------------------------------

def _base_init(spec: tu.FlatSpec, hyper: PolicyParams):
    def init(params, h: Optional[PolicyParams] = None) -> ServerState:
        return base_state(spec, params, hyper if h is None else h)
    return init


def fedasync_policy(spec: tu.FlatSpec, alpha: float = 0.6,
                    a: float = 0.5) -> Policy:
    """FedAsync: w <- (1-s)w + s*w_i with s = alpha*(1+tau)^-a."""

    def build():
        def step(state: ServerState, arr: Arrival):
            h = state.hyper
            s = aggregation.staleness_polynomial(arr.tau, h.alpha, h.a)
            wi = spec.flatten(arr.client_params)
            params = (1.0 - s) * state.params + s * wi
            state = state._replace(params=params, version=state.version + 1)
            return state, make_info(0, updated=True, mix=s)
        return step

    raw, jitted = _shared_steps(("fedasync", spec), build)
    return Policy(name="fedasync",
                  init=_base_init(spec, make_hyper(alpha=alpha, a=a)),
                  step=jitted, raw_step=raw, spec=spec, log_fn=_log_mix,
                  hyper_defaults=(("alpha", alpha), ("a", a)))


def asyncfeded_policy(spec: tu.FlatSpec, alpha: float = 0.6,
                      eps: float = 1e-8, metric: str = "l2",
                      sketch_k: int = 16, sketch_seed: int = 42) -> Policy:
    """AsyncFedED-style distance-metric staleness family: instead of the
    version gap tau, staleness is measured in parameter space between the
    current global model and the returning client model, and the applied
    server step is  w <- w + s * dw.

    ``metric`` selects the member (``core.psa.DISTANCE_METRICS``):

    - "l2" (default, the original AsyncFedED rule — golden streams pin it):
      s = alpha * min(1, ||dw|| / (||w_i - w|| + eps)); a fresh client
      (w_i - w ~ dw) gets the full alpha, a drifted one is damped by its
      relative drift.
    - "cosine": direction-only damping,
      s = alpha * (1 + cos(dw, w_i - w)) / 2.
    - "sketch": the l2 rule on k-dim JL magnitude sketches (the paper's
      compressed-staleness machinery; ``sens_sketch`` kernel single-device,
      k scalar psums sharded).

    l2/cosine share ONE compiled step — the metric is the traced
    ``hyper.dist_mode`` scalar, so it can vary per sweep lane. "sketch"
    adds contractions to the program and keys its own compiled step
    (``sketch_k``/``sketch_seed`` static).
    """
    if metric not in psa_lib.DISTANCE_METRICS:
        raise ValueError(f"unknown distance metric {metric!r}; known: "
                         f"{psa_lib.DISTANCE_METRICS}")

    if metric == "sketch":
        def build():
            def step(state: ServerState, arr: Arrival):
                h = state.hyper
                dw = spec.flatten(arr.update)
                wi = spec.flatten(arr.client_params)
                s = psa_lib.sketch_distance_scale(
                    state.params, wi, dw, alpha=h.alpha, eps=h.eps,
                    k=sketch_k, seed=sketch_seed)
                state = state._replace(params=state.params + s * dw,
                                       version=state.version + 1)
                return state, make_info(0, updated=True, mix=s)
            return step

        raw, jitted = _shared_steps(
            ("asyncfeded", spec, "sketch", sketch_k, sketch_seed), build)
        return Policy(name="asyncfeded",
                      init=_base_init(spec, make_hyper(alpha=alpha, eps=eps)),
                      step=jitted, raw_step=raw, spec=spec, log_fn=_log_mix,
                      hyper_defaults=(("alpha", alpha), ("eps", eps)))

    def build():
        def step(state: ServerState, arr: Arrival):
            h = state.hyper
            dw = spec.flatten(arr.update)
            wi = spec.flatten(arr.client_params)
            # d-contractions inside psum across shards when the step is
            # traced under the sharded server's shard_map
            s = psa_lib.distance_staleness_scale(
                state.params, wi, dw, alpha=h.alpha, eps=h.eps,
                dist_mode=h.dist_mode)
            state = state._replace(params=state.params + s * dw,
                                   version=state.version + 1)
            return state, make_info(0, updated=True, mix=s)
        return step

    raw, jitted = _shared_steps(("asyncfeded", spec), build)
    dist_mode = _DIST_MODE_CODES[metric]
    return Policy(name="asyncfeded",
                  init=_base_init(spec, make_hyper(alpha=alpha, eps=eps,
                                                   dist_mode=dist_mode)),
                  step=jitted, raw_step=raw, spec=spec, log_fn=_log_mix,
                  hyper_defaults=(("alpha", alpha), ("eps", eps),
                                  ("dist_mode", dist_mode)))


# ---------------------------------------------------------------------------
# Buffered policies (flush every L-th arrival)
# ---------------------------------------------------------------------------

def _buffered_policy(name: str, spec: tu.FlatSpec, buffer_size: int,
                     hyper: PolicyParams, defaults: tuple, scale_fn,
                     client_align: float = 0.0):
    """Shared skeleton for FedBuff/FedPAC-lite: ring the (optionally
    staleness-scaled) deltas, apply their uniform mean when full.
    ``scale_fn(arr, hyper)`` reads its knobs from the traced hyper leaves."""
    L = buffer_size

    def init(params, h: Optional[PolicyParams] = None) -> ServerState:
        base = base_state(spec, params, hyper if h is None else h)
        return base._replace(ring=RingState(
            data=jnp.zeros((L, spec.size), jnp.float32), count=jnp.int32(0)))

    def build():
        def step(state: ServerState, arr: Arrival):
            h = state.hyper
            dw = spec.flatten(arr.update)
            ring = _ring_push(state.ring, scale_fn(arr, h) * dw)

            def flush(state, ring):
                w = aggregation.uniform_weights(L)
                params = aggregation.aggregate_flat(state.params, ring.data,
                                                    w, h.server_lr)
                state = state._replace(params=params,
                                       version=state.version + 1,
                                       ring=ring._replace(count=jnp.int32(0)))
                return state, make_info(L, updated=True, weights=w)

            def wait(state, ring):
                return state._replace(ring=ring), make_info(L, updated=False)

            return jax.lax.cond(ring.count >= L, flush, wait, state, ring)
        return step

    raw, jitted = _shared_steps((name, spec, L), build)
    return Policy(name=name, init=init, step=jitted, raw_step=raw, spec=spec,
                  client_align=client_align, hyper_defaults=defaults)


def fedbuff_policy(spec: tu.FlatSpec, buffer_size: int = 5,
                   server_lr: float = 1.0, a: float = 0.5) -> Policy:
    """FedBuff: buffer K staleness-scaled deltas, apply their mean."""
    return _buffered_policy(
        "fedbuff", spec, buffer_size,
        make_hyper(server_lr=server_lr, a=a),
        (("server_lr", server_lr), ("a", a)),
        lambda arr, h: aggregation.staleness_polynomial(arr.tau, 1.0, h.a))


def fedpac_policy(spec: tu.FlatSpec, buffer_size: int = 5,
                  server_lr: float = 1.0) -> Policy:
    """FedPAC-lite: FedBuff-style buffering of raw deltas; clients train with
    an extra classifier-alignment term (client.local_update(align=...))."""
    return _buffered_policy("fedpac", spec, buffer_size,
                            make_hyper(server_lr=server_lr),
                            (("server_lr", server_lr),),
                            lambda arr, h: jnp.float32(1.0),
                            client_align=0.1)


def fedpsa_policy(spec: tu.FlatSpec, cfg: psa_lib.PSAConfig,
                  sketch_refresh: Optional[Callable] = None) -> Policy:
    """FedPSA (Algorithm 1): behavioral-staleness softmax over the buffer.

    ``sketch_refresh(flat_params) -> (k,)`` recomputes the global sketch
    after each aggregation, inside the fused step (cond's taken branch).
    The temperature knobs (gamma/delta), server_lr, and the w/o-T ablation
    switch are traced from ``state.hyper`` (so they may vary per lane);
    buffer_size/queue_len/sketch_k and use_sensitivity stay static."""
    hyper = make_hyper(gamma=cfg.gamma, delta=cfg.delta,
                       server_lr=cfg.server_lr,
                       use_thermometer=cfg.use_thermometer)

    def init(params, h: Optional[PolicyParams] = None) -> ServerState:
        base = base_state(spec, params, hyper if h is None else h)
        gs = None if sketch_refresh is None else sketch_refresh(base.params)
        return base._replace(psa=psa_lib.init_state(cfg, spec.size, gs))

    # The global-sketch refresh consumes the WHOLE flat vector (it unflattens
    # into the model pytree); under the sharded server's shard_map the step
    # sees only a (d_local,) slice, so the refresh gathers first (identity on
    # single-device traces). Its (k,) result is identical on every shard.
    refresh = None if sketch_refresh is None else (
        lambda vec: sketch_refresh(sharding.gather_param_axis(vec, spec.size)))

    def build():
        def step(state: ServerState, arr: Arrival):
            h = state.hyper
            dw = spec.flatten(arr.update)
            psa, params, pi = psa_lib.server_step(
                state.psa, state.params, dw, arr.sketch, cfg, refresh,
                gamma=h.gamma, delta=h.delta, server_lr=h.server_lr,
                thermo_on=h.use_thermometer)
            state = state._replace(
                params=params, psa=psa,
                version=state.version + pi.updated.astype(jnp.int32))
            return state, make_info(cfg.buffer_size, updated=pi.updated,
                                    weights=pi.weights, kappas=pi.kappas,
                                    temp=pi.temp, temp_valid=pi.temp_valid)
        return step

    raw, jitted = _shared_steps(
        ("fedpsa", spec, psa_lib.structural(cfg), sketch_refresh), build)
    return Policy(name="fedpsa", init=init, step=jitted, raw_step=raw,
                  spec=spec, sketch_k=cfg.sketch_k, needs_sketch=True,
                  log_fn=_log_psa,
                  hyper_defaults=(("gamma", cfg.gamma), ("delta", cfg.delta),
                                  ("server_lr", cfg.server_lr),
                                  ("use_thermometer", cfg.use_thermometer)))


def ca2fl_policy(spec: tu.FlatSpec, num_clients: int, buffer_size: int = 5,
                 server_lr: float = 1.0) -> Policy:
    """CA2FL: cached-update calibration. Buffers the residual vs the
    client's previous delta; aggregation adds the cache mean back."""
    L = buffer_size
    hyper = make_hyper(server_lr=server_lr)

    def init(params, h: Optional[PolicyParams] = None) -> ServerState:
        base = base_state(spec, params, hyper if h is None else h)
        return base._replace(
            ring=RingState(data=jnp.zeros((L, spec.size), jnp.float32),
                           count=jnp.int32(0)),
            cache=CacheState(
                data=jnp.zeros((num_clients, spec.size), jnp.float32),
                valid=jnp.zeros((num_clients,), jnp.bool_),
                total=jnp.zeros((spec.size,), jnp.float32)))

    def build():
        def step(state: ServerState, arr: Arrival):
            h = state.hyper
            dw = spec.flatten(arr.update)
            cid = arr.client_id
            prev = state.cache.data[cid]  # zeros until client is first seen
            ring = _ring_push(state.ring, dw - prev)
            cache = CacheState(data=state.cache.data.at[cid].set(dw),
                               valid=state.cache.valid.at[cid].set(True),
                               total=state.cache.total + dw - prev)

            def flush(state, ring, cache):
                w = aggregation.uniform_weights(L)
                n_cached = jnp.maximum(
                    jnp.sum(cache.valid.astype(jnp.float32)), 1.0)
                params = aggregation.aggregate_flat(state.params, ring.data,
                                                    w, h.server_lr)
                params = params + h.server_lr * cache.total / n_cached
                state = state._replace(params=params,
                                       version=state.version + 1,
                                       ring=ring._replace(count=jnp.int32(0)),
                                       cache=cache)
                return state, make_info(L, updated=True, weights=w)

            def wait(state, ring, cache):
                state = state._replace(ring=ring, cache=cache)
                return state, make_info(L, updated=False)

            return jax.lax.cond(ring.count >= L, flush, wait, state, ring,
                                cache)
        return step

    raw, jitted = _shared_steps(("ca2fl", spec, L, num_clients), build)
    return Policy(name="ca2fl", init=init, step=jitted, raw_step=raw,
                  spec=spec, hyper_defaults=(("server_lr", server_lr),))


def fedfa_policy(spec: tu.FlatSpec, queue_len: int = 5,
                 beta: float = 0.5) -> Policy:
    """FedFa: the global model is a recency-weighted average of the ring of
    the last ``queue_len`` client models, refreshed on every arrival. The
    ring count grows monotonically; slot ages are recovered from it (the
    stacked-buffer replacement for the legacy O(n) list.pop(0) queue)."""
    L = queue_len

    def init(params, h: Optional[PolicyParams] = None) -> ServerState:
        base = base_state(spec, params,
                          make_hyper(beta=beta) if h is None else h)
        return base._replace(ring=RingState(
            data=jnp.zeros((L, spec.size), jnp.float32), count=jnp.int32(0)))

    def build():
        def step(state: ServerState, arr: Arrival):
            h = state.hyper
            wi = spec.flatten(arr.client_params)
            ring = _ring_push(state.ring, wi)
            n = jnp.minimum(ring.count, L)
            newest = jnp.mod(ring.count - 1, L)
            age = jnp.mod(newest - jnp.arange(L, dtype=jnp.int32), L)
            w = jnp.where(age < n,
                          jnp.power(h.beta, age.astype(jnp.float32)), 0.0)
            w = w / jnp.sum(w)
            params = aggregation.aggregate_flat(
                jnp.zeros_like(state.params), ring.data, w)
            state = state._replace(params=params, version=state.version + 1,
                                   ring=ring)
            return state, make_info(L, updated=True, weights=w)
        return step

    raw, jitted = _shared_steps(("fedfa", spec, L), build)
    return Policy(name="fedfa", init=init, step=jitted, raw_step=raw,
                  spec=spec, hyper_defaults=(("beta", beta),))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

POLICY_NAMES = ("fedasync", "fedbuff", "fedpsa", "ca2fl", "fedfa", "fedpac",
                "asyncfeded")

# Policies are immutable (state lives in ServerState), so identical requests
# share one Policy — and with it the jit cache of its compiled step(s).
# Without this every run_async would rebuild the step closures and recompile.
# FlatSpec hashes by layout; sketch_refresh participates by identity (the
# simulator caches its sketch closures, so fedpsa hits too).
_POLICY_CACHE = {}


def make_policy(name: str, spec: tu.FlatSpec, *, num_clients: int = 50,
                psa_cfg: Optional[psa_lib.PSAConfig] = None,
                sketch_refresh: Optional[Callable] = None, **kw) -> Policy:
    key = (name, spec, num_clients, psa_cfg, sketch_refresh,
           tuple(sorted(kw.items())))
    try:
        cached = _POLICY_CACHE.get(key)
    except TypeError:        # unhashable kwarg — build uncached
        cached = None
        key = None
    if cached is not None:
        return cached
    policy = _make_policy(name, spec, num_clients=num_clients,
                          psa_cfg=psa_cfg, sketch_refresh=sketch_refresh, **kw)
    if key is not None:
        _POLICY_CACHE[key] = policy
    return policy


def _make_policy(name: str, spec: tu.FlatSpec, *, num_clients: int = 50,
                 psa_cfg: Optional[psa_lib.PSAConfig] = None,
                 sketch_refresh: Optional[Callable] = None, **kw) -> Policy:
    if name == "fedasync":
        return fedasync_policy(spec, **kw)
    if name == "fedbuff":
        return fedbuff_policy(spec, **kw)
    if name == "fedpsa":
        # without a refresh the global sketch stays zeros, every kappa is 0
        # and FedPSA silently degenerates to uniform (FedBuff-like) weighting
        assert psa_cfg is not None and sketch_refresh is not None, \
            "fedpsa needs psa_cfg and sketch_refresh"
        return fedpsa_policy(spec, psa_cfg, sketch_refresh)
    if name == "ca2fl":
        return ca2fl_policy(spec, num_clients=num_clients, **kw)
    if name == "fedfa":
        return fedfa_policy(spec, **kw)
    if name == "fedpac":
        return fedpac_policy(spec, **kw)
    if name == "asyncfeded":
        return asyncfeded_policy(spec, **kw)
    raise ValueError(f"unknown staleness policy {name!r}")
