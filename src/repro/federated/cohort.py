"""Device-resident cohort client engine: vmapped local training.

The legacy client path (``client.local_update``) runs E epochs as a python
loop of per-batch jit calls on pytrees — every simulated dispatch pays
O(epochs * batches) device-call overhead plus a pytree snapshot. This module
replaces it with ONE compiled call per *cohort*: all clients whose
completions drain together train simultaneously via ``vmap`` over the cohort
axis and ``lax.scan`` over their local SGD steps, operating directly on the
flat ``(d,)`` parameter layout from ``common.tree.FlatSpec`` (no pytree
unflatten on the host — ``spec.unflatten`` happens inside the traced loss).

Data lives on device once, as a padded ``(C, n_max, ...)`` slab
(``data.loader.StackedClients`` — float features for image families,
``(C, n_max, seq)`` int32 token/label arrays for LM families); batch
schedules come from the same ``epoch_batch_indices`` stream the legacy
iterator uses, so the engine reproduces the per-client loop's arithmetic to
float tolerance — ragged client sizes are handled by masking batch tails
inside the loss, and padded scan steps / padded cohort rows are exact no-ops.

The member loss is model-agnostic: it comes from the family registry
(``models.registry.get_family(cfg).client_loss`` with the mask folded in by
``masked_batch``), so ANY registered family — the paper's cnn/mlp, the
dense/ssm/moe/hybrid LM families via ``model_lib.loss_fn`` (remat honored
per ``ModelConfig``), or a user-registered one — compiles into the same
vmap x scan program.

FedProx (``prox``) and FedPAC (``align``) fold in as static config: the
proximal/alignment pulls are plain vector arithmetic on the flat layout
(the classifier head becomes a precomputed 0/1 mask over flat offsets).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common import sharding
from repro.common import tree as tu
from repro.common.sharding import SINGLE_DEVICE_RULES
from repro.data.loader import StackedClients, epoch_batch_indices
from repro.federated.client import _head
from repro.models import member_math
from repro.models import registry
from repro.models.config import ModelConfig


_RUN_CACHE = {}


def bucket_size(B: int, data_kind: str = "tokens") -> int:
    """Pad a wave of B members up to the family's bucket grid. Padded rows
    are masked no-ops but still execute their local steps, so the grid
    trades padded compute against compiled-program count:

    ``image`` — multiples of 4 (max_cohort/4 programs, <= 3 wasted rows):
    the cnn/mlp programs compile in milliseconds, so a dense grid is free.

    ``tokens`` — {4, 6, 8, 12, 16, 24, 32, ...} (powers of two and 1.5x
    powers of two; worst-case 1.5x padded compute, O(log max_cohort)
    programs): transformer-family programs compile in *seconds* each, so a
    dense grid would stall mid-run on every fresh wave size."""
    if data_kind == "image":
        return -(-B // 4) * 4
    if B <= 4:
        return 4
    p = 1 << (B - 1).bit_length()          # next power of two >= B
    return 3 * p // 4 if 3 * p // 4 >= B else p


class CohortEngine:
    """One compiled local-training step for a whole cohort.

    Built once per (model, stacked data, epochs, batch_size, prox, align);
    ``cohort_update`` then costs one device call per cohort. Cohort sizes
    are bucketed to the ``bucket_size`` grid and scan length is fixed at
    the global maximum, so the jit cache holds O(log C) programs, not one
    per cohort shape.
    """

    def __init__(self, cfg: ModelConfig, stacked: StackedClients,
                 spec: tu.FlatSpec, template_params, *,
                 local_epochs: int = 5, batch_size: int = 64,
                 prox: float = 0.0, align: float = 0.0,
                 mesh=None, rules: Optional[sharding.LogicalRules] = None,
                 member_kernel: str = "vmap"):
        # any registered family compiles; get_family raises (naming the
        # registered set) for families the registry does not know
        fam = registry.get_family(cfg)
        self._data_kind = fam.data_kind
        self.cfg = cfg
        self.spec = spec
        self.local_epochs = int(local_epochs)
        self.batch_size = int(batch_size)
        self.prox = float(prox)
        self.align = float(align)
        if member_kernel not in member_math.MODES:
            raise ValueError(f"member_kernel must be one of "
                             f"{member_math.MODES}, got {member_kernel!r}")
        self.member_kernel = member_kernel
        self.sizes = np.asarray(stacked.sizes, np.int64)
        self.x = jnp.asarray(stacked.x)
        self.y = jnp.asarray(stacked.y)
        # With a mesh, a wave trains data-parallel: the cohort (client) axis
        # of every per-member input shards over the ``cohort`` logical axis
        # and the data slab replicates; vmap members are independent, so the
        # numerics are identical to the single-device call.
        self.mesh = mesh
        self.cohort_axis = None
        if mesh is not None:
            rules = rules or sharding.FEDERATED_RULES
            ax = rules.mesh_axes(("cohort",))[0]
            if ax is not None and ax in mesh.axis_names:
                self.cohort_axis = ax
                self._axis_n = int(mesh.shape[ax])
            rep = NamedSharding(mesh, P())
            self.x = jax.device_put(self.x, rep)
            self.y = jax.device_put(self.y, rep)
        # Per-client steps/epoch under the drop-last rule; the scan runs the
        # global max and masks the tail (a masked step is an exact no-op).
        bs_c = np.minimum(self.batch_size, self.sizes)
        self.steps_per_client = (self.local_epochs * (self.sizes // bs_c)).astype(int)
        self.num_steps = int(self.steps_per_client.max())
        self.bs_pad = int(bs_c.max())
        # Compiled step shared across engine instances (a fresh engine per
        # run would otherwise retrace; mirrors client._STEP_CACHE). The key
        # pins everything _build closes over: the model (which fixes the
        # flat layout), the static loss variant, and the registry entry —
        # so register_family(..., override=True) invalidates the program.
        key = (cfg, spec, self.prox, self.align, fam, member_kernel)
        if key not in _RUN_CACHE:
            _RUN_CACHE[key] = self._build(cfg, spec, self.prox, self.align,
                                          fam, member_kernel)
        self._run, self._run_lanes = _RUN_CACHE[key]

    # -- compiled core ------------------------------------------------------

    @staticmethod
    def _build(cfg, spec, prox, align, fam, member_kernel="vmap"):
        def member(x_all, y_all, p0_flat, cid, idx, valid, counts, lr_steps):
          # member-math routing is a trace-time switch: "grouped" makes the
          # vmap over members collapse every dense layer into one Pallas
          # grouped-GEMM launch (models.member_math); "vmap" keeps the exact
          # per-member dot_general HLO the golden digests pin.
          with member_math.routing(member_kernel):
            xs = x_all[cid]          # (n_max, ...) this member's data
            ys = y_all[cid]
            # The scan carries the params *pytree*: unflatten/flatten happen
            # once at the boundary, not (with their grad-transpose scatters)
            # inside every local step — the per-step program stays the same
            # op sequence the legacy per-batch jit ran.
            anchor = spec.unflatten(p0_flat)

            def loss(p, xb, yb, vm, cnt):
                base = fam.client_loss(p, fam.masked_batch(xb, yb, vm, cnt),
                                       cfg, SINGLE_DEVICE_RULES)
                if prox > 0.0:
                    base = base + 0.5 * prox * tu.tree_sq_norm(
                        tu.tree_sub(p, anchor))
                if align > 0.0:
                    base = base + 0.5 * align * tu.tree_sq_norm(
                        tu.tree_sub(_head(p), _head(anchor)))
                return base

            grad = jax.grad(loss)

            # vm (f32 tail mask), cnt (= max(sum(vm), 1)) and lr_t (member lr,
            # 0 on padded steps) are host-precomputed so the compiled step
            # carries no mask bookkeeping; a padded step has finite g (safe
            # denominator) and lr_t = 0 — an exact no-op.
            def body(p, sl):
                bi, vm, cnt, lr_t = sl
                g = grad(p, xs[bi], ys[bi], vm, cnt)
                p = jax.tree_util.tree_map(lambda a, b: a - lr_t * b, p, g)
                return p, None

            p, _ = jax.lax.scan(body, anchor, (idx, valid, counts, lr_steps))
            return spec.flatten(p)

        @jax.jit
        def run(x_all, y_all, params_stack, cids, idx, valid, counts,
                lr_steps):
            w = jax.vmap(member, in_axes=(None, None, 0, 0, 0, 0, 0, 0))(
                x_all, y_all, params_stack, cids, idx, valid, counts,
                lr_steps)
            return w - params_stack, w

        # The sweep engine's variant: one more vmap over a leading lane
        # axis. Lanes share the data slab, the member (client) assignment,
        # the validity masks/counts (schedule shapes depend only on client
        # sizes) and the lr schedule — all lane-invariant because the event
        # timeline is shared; the dispatch snapshots and the batch-index
        # permutations are per-lane (per-lane weights / shuffle seeds).
        over_members = jax.vmap(member, in_axes=(None, None, 0, 0, 0, 0, 0, 0))

        @jax.jit
        def run_lanes(x_all, y_all, params_stack, cids, idx, valid, counts,
                      lr_steps):
            w = jax.vmap(over_members,
                         in_axes=(None, None, 0, None, 0, None, None, None))(
                x_all, y_all, params_stack, cids, idx, valid, counts,
                lr_steps)
            return w - params_stack, w

        return run, run_lanes

    # -- host driver --------------------------------------------------------

    def _schedules(self, cids: np.ndarray, seeds: np.ndarray):
        """Batch schedules for a cohort, padded to the engine's fixed
        (num_steps, bs_pad) frame. Same RandomState stream as the legacy
        ``ClientDataset.epochs`` iterator. Returns (idx, valid f32 masks,
        counts = per-step valid totals clamped to >= 1, nvalid per-step raw
        totals for lr gating)."""
        B = len(cids)
        idx = np.zeros((B, self.num_steps, self.bs_pad), np.int32)
        valid = np.zeros((B, self.num_steps, self.bs_pad), np.float32)
        nvalid = np.zeros((B, self.num_steps), np.float32)
        for i, (c, s) in enumerate(zip(cids, seeds)):
            sched = epoch_batch_indices(int(self.sizes[c]), self.local_epochs,
                                        self.batch_size, int(s))
            st, bs = sched.shape
            idx[i, :st, :bs] = sched
            valid[i, :st, :bs] = 1.0
            nvalid[i, :st] = bs
        counts = np.maximum(nvalid, 1.0)
        return idx, valid, counts, nvalid

    def cohort_update(self, params_stack: jnp.ndarray, cids: Sequence[int],
                      lrs: Sequence[float], seeds: Sequence[int]
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Train the cohort; returns (deltas, new_params), both (B, d).

        ``params_stack`` holds each member's dispatch snapshot (its anchor
        for prox/align); ``lrs``/``seeds`` are per-member, matching what the
        legacy loop would have used for that dispatch.
        """
        B = int(params_stack.shape[0])
        assert B >= 1
        cids = np.asarray(cids, np.int32)
        idx, valid, counts, nvalid = self._schedules(cids, np.asarray(seeds))
        # per-(member, step) learning rate: the member's lr on real steps,
        # 0 on padded steps (making them exact no-ops)
        lr_steps = (np.asarray(lrs, np.float64)[:, None]
                    * (nvalid > 0.0)).astype(np.float32)
        Bp = bucket_size(B, self._data_kind)
        if Bp > B:
            pad = Bp - B

            def padded(a):
                return np.concatenate(
                    [a, np.zeros((pad,) + a.shape[1:], a.dtype)])

            params_stack = jnp.concatenate(
                [params_stack, jnp.zeros((pad, params_stack.shape[1]),
                                         params_stack.dtype)])
            cids, idx, valid, lr_steps = map(padded,
                                             (cids, idx, valid, lr_steps))
            counts = np.concatenate(
                [counts, np.ones((pad,) + counts.shape[1:], counts.dtype)])
        args = (params_stack, jnp.asarray(cids), jnp.asarray(idx),
                jnp.asarray(valid), jnp.asarray(counts),
                jnp.asarray(lr_steps))
        if self.mesh is not None:
            # shard the cohort axis when it divides the mesh; otherwise the
            # wave still runs on the mesh, replicated (exact either way)
            ax = (self.cohort_axis
                  if self.cohort_axis and Bp % self._axis_n == 0 else None)
            args = tuple(
                jax.device_put(a, NamedSharding(
                    self.mesh, P(*([ax] + [None] * (a.ndim - 1)))))
                for a in args)
        deltas, w = self._run(self.x, self.y, *args)
        return deltas[:B], w[:B]

    def sweep_update(self, params_stack: jnp.ndarray, cids: Sequence[int],
                     lrs: Sequence[float], seeds_per_lane: np.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Train one wave for all S sweep lanes in ONE compiled call.

        ``params_stack`` is the ``(S, B, d)`` stack of per-lane dispatch
        snapshots; ``cids``/``lrs`` are shared across lanes (the event
        timeline is lane-invariant); ``seeds_per_lane`` is ``(S, B)`` —
        per-lane client-shuffle seeds for the wave's members. Returns
        ``(deltas, new_params)``, both ``(S, B, d)``. Lane ``s`` is
        arithmetically identical to ``cohort_update`` on that lane's
        snapshots/seeds: the member program is the same, vmapped once more
        over the lane axis.
        """
        S, B = int(params_stack.shape[0]), int(params_stack.shape[1])
        assert B >= 1 and S >= 1
        assert self.mesh is None, "sweeps run single-device (no mesh support)"
        cids = np.asarray(cids, np.int32)
        seeds_per_lane = np.asarray(seeds_per_lane)
        # Schedule shapes (valid masks, per-step counts) depend only on
        # client sizes — lane-invariant; only the index permutations are
        # per-lane. Lanes sharing a seed row share one schedule build.
        built = {}
        idx = np.zeros((S, B, self.num_steps, self.bs_pad), np.int32)
        valid = counts = nvalid = None
        for s in range(S):
            key = tuple(int(v) for v in seeds_per_lane[s])
            if key not in built:
                built[key] = self._schedules(cids, seeds_per_lane[s])
            idx[s], valid, counts, nvalid = built[key]
        lr_steps = (np.asarray(lrs, np.float64)[:, None]
                    * (nvalid > 0.0)).astype(np.float32)
        Bp = bucket_size(B, self._data_kind)
        if Bp > B:
            pad = Bp - B

            def padded(a, fill=0):
                ext = np.full((pad,) + a.shape[1:], fill, a.dtype)
                return np.concatenate([a, ext])

            params_stack = jnp.concatenate(
                [params_stack,
                 jnp.zeros((S, pad, params_stack.shape[2]),
                           params_stack.dtype)], axis=1)
            idx = np.concatenate(
                [idx, np.zeros((S, pad) + idx.shape[2:], idx.dtype)], axis=1)
            cids = padded(cids)
            valid, lr_steps = padded(valid), padded(lr_steps)
            counts = np.concatenate(
                [counts, np.ones((pad,) + counts.shape[1:], counts.dtype)])
        deltas, w = self._run_lanes(
            self.x, self.y, params_stack, jnp.asarray(cids),
            jnp.asarray(idx), jnp.asarray(valid), jnp.asarray(counts),
            jnp.asarray(lr_steps))
        return deltas[:, :B], w[:, :B]


class StreamingCohortEngine(CohortEngine):
    """The cohort engine over streamed client slabs (population scale).

    Same compiled member program as ``CohortEngine`` except the data
    arrives per wave: instead of indexing a resident ``(C, n_max, ...)``
    slab by client id inside the jit, each member receives its own
    ``(n_max, ...)`` rows, gathered by a ``data.loader.ClientSlabStore``
    (cached device shards + on-demand row uploads). Members train on
    exactly the rows the monolithic slab holds for them and the batch
    schedules come from the same ``epoch_batch_indices`` stream, so the two
    engines agree to float tolerance — the streaming digest-parity tests
    pin this. Memory is bounded by the store's shard geometry, not by C.

    Single-device by construction (the simulator rejects mesh +
    streaming); the lane variant mirrors ``sweep_update`` with the wave's
    row slab shared across lanes.
    """

    def __init__(self, cfg: ModelConfig, store, spec: tu.FlatSpec,
                 template_params, *, local_epochs: int = 5,
                 batch_size: int = 64, prox: float = 0.0,
                 align: float = 0.0, member_kernel: str = "vmap"):
        fam = registry.get_family(cfg)
        self._data_kind = fam.data_kind
        self.cfg = cfg
        self.spec = spec
        self.local_epochs = int(local_epochs)
        self.batch_size = int(batch_size)
        self.prox = float(prox)
        self.align = float(align)
        if member_kernel not in member_math.MODES:
            raise ValueError(f"member_kernel must be one of "
                             f"{member_math.MODES}, got {member_kernel!r}")
        self.member_kernel = member_kernel
        self.store = store
        self.sizes = np.asarray(store.sizes, np.int64)
        self.mesh = None
        self.cohort_axis = None
        bs_c = np.minimum(self.batch_size, self.sizes)
        self.steps_per_client = (self.local_epochs
                                 * (self.sizes // bs_c)).astype(int)
        self.num_steps = int(self.steps_per_client.max())
        self.bs_pad = int(bs_c.max())
        key = (cfg, spec, self.prox, self.align, fam, member_kernel, "rows")
        if key not in _RUN_CACHE:
            _RUN_CACHE[key] = self._build_rows(cfg, spec, self.prox,
                                               self.align, fam, member_kernel)
        self._run_rows, self._run_rows_lanes = _RUN_CACHE[key]

    @staticmethod
    def _build_rows(cfg, spec, prox, align, fam, member_kernel="vmap"):
        def member(xs, ys, p0_flat, idx, valid, counts, lr_steps):
          with member_math.routing(member_kernel):
            # identical member program to CohortEngine._build, minus the
            # in-jit x_all[cid] gather: xs/ys are this member's rows
            anchor = spec.unflatten(p0_flat)

            def loss(p, xb, yb, vm, cnt):
                base = fam.client_loss(p, fam.masked_batch(xb, yb, vm, cnt),
                                       cfg, SINGLE_DEVICE_RULES)
                if prox > 0.0:
                    base = base + 0.5 * prox * tu.tree_sq_norm(
                        tu.tree_sub(p, anchor))
                if align > 0.0:
                    base = base + 0.5 * align * tu.tree_sq_norm(
                        tu.tree_sub(_head(p), _head(anchor)))
                return base

            grad = jax.grad(loss)

            def body(p, sl):
                bi, vm, cnt, lr_t = sl
                g = grad(p, xs[bi], ys[bi], vm, cnt)
                p = jax.tree_util.tree_map(lambda a, b: a - lr_t * b, p, g)
                return p, None

            p, _ = jax.lax.scan(body, anchor, (idx, valid, counts, lr_steps))
            return spec.flatten(p)

        @jax.jit
        def run(x_rows, y_rows, params_stack, idx, valid, counts, lr_steps):
            w = jax.vmap(member, in_axes=(0, 0, 0, 0, 0, 0, 0))(
                x_rows, y_rows, params_stack, idx, valid, counts, lr_steps)
            return w - params_stack, w

        over_members = jax.vmap(member, in_axes=(0, 0, 0, 0, 0, 0, 0))

        @jax.jit
        def run_lanes(x_rows, y_rows, params_stack, idx, valid, counts,
                      lr_steps):
            # lanes share the wave's row slab, schedules shapes and lr; the
            # snapshots and index permutations are per-lane
            w = jax.vmap(over_members,
                         in_axes=(None, None, 0, 0, None, None, None))(
                x_rows, y_rows, params_stack, idx, valid, counts, lr_steps)
            return w - params_stack, w

        return run, run_lanes

    def _wave_rows(self, cids: np.ndarray, pad: int):
        """The wave's (Bp, n_max, ...) device row slab, zero-padded rows
        for bucket-grid members (their lr is 0 — exact no-ops)."""
        x, y = self.store.gather(cids)
        if pad > 0:
            x = jnp.concatenate(
                [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
            y = jnp.concatenate(
                [y, jnp.zeros((pad,) + y.shape[1:], y.dtype)])
        return x, y

    def cohort_update(self, params_stack: jnp.ndarray, cids: Sequence[int],
                      lrs: Sequence[float], seeds: Sequence[int]
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        B = int(params_stack.shape[0])
        assert B >= 1
        cids = np.asarray(cids, np.int32)
        idx, valid, counts, nvalid = self._schedules(cids, np.asarray(seeds))
        lr_steps = (np.asarray(lrs, np.float64)[:, None]
                    * (nvalid > 0.0)).astype(np.float32)
        Bp = bucket_size(B, self._data_kind)
        pad = Bp - B
        x, y = self._wave_rows(cids, pad)
        if pad > 0:
            def padded(a):
                return np.concatenate(
                    [a, np.zeros((pad,) + a.shape[1:], a.dtype)])

            params_stack = jnp.concatenate(
                [params_stack, jnp.zeros((pad, params_stack.shape[1]),
                                         params_stack.dtype)])
            idx, valid, lr_steps = map(padded, (idx, valid, lr_steps))
            counts = np.concatenate(
                [counts, np.ones((pad,) + counts.shape[1:], counts.dtype)])
        deltas, w = self._run_rows(x, y, params_stack, jnp.asarray(idx),
                                   jnp.asarray(valid), jnp.asarray(counts),
                                   jnp.asarray(lr_steps))
        return deltas[:B], w[:B]

    def sweep_update(self, params_stack: jnp.ndarray, cids: Sequence[int],
                     lrs: Sequence[float], seeds_per_lane: np.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        S, B = int(params_stack.shape[0]), int(params_stack.shape[1])
        assert B >= 1 and S >= 1
        cids = np.asarray(cids, np.int32)
        seeds_per_lane = np.asarray(seeds_per_lane)
        built = {}
        idx = np.zeros((S, B, self.num_steps, self.bs_pad), np.int32)
        valid = counts = nvalid = None
        for s in range(S):
            key = tuple(int(v) for v in seeds_per_lane[s])
            if key not in built:
                built[key] = self._schedules(cids, seeds_per_lane[s])
            idx[s], valid, counts, nvalid = built[key]
        lr_steps = (np.asarray(lrs, np.float64)[:, None]
                    * (nvalid > 0.0)).astype(np.float32)
        Bp = bucket_size(B, self._data_kind)
        pad = Bp - B
        x, y = self._wave_rows(cids, pad)
        if pad > 0:
            def padded(a):
                return np.concatenate(
                    [a, np.zeros((pad,) + a.shape[1:], a.dtype)])

            params_stack = jnp.concatenate(
                [params_stack,
                 jnp.zeros((S, pad, params_stack.shape[2]),
                           params_stack.dtype)], axis=1)
            idx = np.concatenate(
                [idx, np.zeros((S, pad) + idx.shape[2:], idx.dtype)], axis=1)
            valid, lr_steps = padded(valid), padded(lr_steps)
            counts = np.concatenate(
                [counts, np.ones((pad,) + counts.shape[1:], counts.dtype)])
        deltas, w = self._run_rows_lanes(
            x, y, params_stack, jnp.asarray(idx), jnp.asarray(valid),
            jnp.asarray(counts), jnp.asarray(lr_steps))
        return deltas[:, :B], w[:, :B]
