"""Mesh-sharded policy server vs the single-device ``PolicyServer``.

The contract (ISSUE 3): on identical arrival streams,
``ShardedPolicyServer.step`` — the policy's raw step under ``shard_map``
with ``ServerState`` partitioned on the flat parameter axis — stays within
1e-5 of the single-device trajectory for every policy, on both the
per-arrival (``receive``) and the batched (``receive_many``) ingest paths,
for divisible and non-divisible ``d``. All tests are ``multidevice``
(CI forces virtual CPU devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=4``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import sharding, tree as tu
from repro.core import PSAConfig
from repro.core import sketch as sketch_lib
from repro.federated import servers
from repro.launch.mesh import make_fed_mesh

pytestmark = pytest.mark.multidevice

SKETCH_K = 8


def _params(extra_bias: int = 0, seed: int = 0):
    """d = 40 (+ extra_bias): with extra_bias=1, d=41 is indivisible by any
    mesh size, exercising the zero-padded tail shard."""
    rng = np.random.RandomState(seed)
    p = {
        "w1": jnp.asarray(rng.randn(6, 4) * 0.3, jnp.float32),
        "b1": jnp.asarray(rng.randn(4) * 0.1, jnp.float32),
        "w2": jnp.asarray(rng.randn(4, 3) * 0.3, jnp.float32),
    }
    if extra_bias:
        p["b2"] = jnp.asarray(rng.randn(extra_bias) * 0.1, jnp.float32)
    return p


def _stream(params, n, seed=1, num_clients=5, k=None):
    rng = np.random.RandomState(seed)
    out = []
    for _ in range(n):
        delta = jax.tree_util.tree_map(
            lambda p: jnp.asarray(rng.randn(*p.shape) * 0.05, jnp.float32),
            params)
        client = tu.tree_add(params, delta)
        meta = {"tau": int(rng.randint(0, 4)),
                "client_id": int(rng.randint(num_clients)),
                "data_size": float(rng.randint(5, 50))}
        if k is not None:
            meta["sketch"] = jnp.asarray(rng.randn(k), jnp.float32)
        out.append((delta, client, meta))
    return out


def _psa_case():
    cfg = PSAConfig(buffer_size=3, queue_len=5, sketch_k=SKETCH_K)
    sketch_fn = jax.jit(
        lambda p: sketch_lib.sketch_tree(p, cfg.sketch_seed, cfg.sketch_k))
    return {"psa_cfg": cfg, "sketch_fn": sketch_fn}


CASES = [
    ("fedasync", lambda: {}),
    ("asyncfeded", lambda: {}),
    ("fedbuff", lambda: {"buffer_size": 3}),
    ("fedpac", lambda: {"buffer_size": 3}),
    ("ca2fl", lambda: {"buffer_size": 3, "num_clients": 5}),
    ("fedfa", lambda: {"queue_len": 4}),
    ("fedpsa", _psa_case),
]


def _mesh_sizes():
    return [n for n in (2, 4) if n <= jax.device_count()]


@pytest.mark.parametrize("name,mk", CASES, ids=[c[0] for c in CASES])
@pytest.mark.parametrize("extra_bias", [0, 1], ids=["d40", "d41"])
def test_sharded_receive_matches_single_device(name, mk, extra_bias):
    params = _params(extra_bias)
    for ndev in _mesh_sizes():
        mesh = make_fed_mesh(ndev)
        kw = mk()
        base = servers.make_server(name, params, **kw)
        shrd = servers.make_server(name, params, mesh=mesh, **kw)
        assert isinstance(shrd, servers.ShardedPolicyServer)
        k = SKETCH_K if name == "fedpsa" else None
        for delta, client, meta in _stream(params, 13, k=k):
            u_base = base.receive(delta, client, meta)
            u_shrd = shrd.receive(delta, client, meta)
            assert u_base == u_shrd
            err = float(jnp.max(jnp.abs(base.flat_params - shrd.flat_params)))
            assert err < 1e-5, (name, ndev, err)
        assert base.version == shrd.version > 0


@pytest.mark.parametrize("name,mk", CASES, ids=[c[0] for c in CASES])
def test_sharded_receive_many_matches_single_device(name, mk):
    params = _params(extra_bias=1)
    spec = tu.FlatSpec(params)
    rng = np.random.RandomState(7)
    B = 11       # chunks into 8 + 2 + 1: exercises the power-of-two split
    deltas = jnp.asarray(rng.randn(B, spec.size) * 0.05, jnp.float32)
    w_stack = spec.flatten(params)[None, :] + deltas
    cids = rng.randint(0, 5, size=B)
    sizes = rng.randint(5, 50, size=B).astype(float)
    vdisp = np.zeros(B, np.int64)
    sketches = (jnp.asarray(rng.randn(B, SKETCH_K), jnp.float32)
                if name == "fedpsa" else None)
    for ndev in _mesh_sizes():
        kw = mk()
        base = servers.make_server(name, params, **kw)
        shrd = servers.make_server(name, params, mesh=make_fed_mesh(ndev),
                                   **kw)
        u1, t1, s1 = base.receive_many(deltas, w_stack, cids, sizes, vdisp,
                                       sketches)
        u2, t2, s2 = shrd.receive_many(deltas, w_stack, cids, sizes, vdisp,
                                       sketches)
        assert list(u1) == list(u2) and t1 == t2
        assert s2.shape == (B, spec.size)   # padding stripped
        err = float(jnp.max(jnp.abs(jnp.asarray(s1) - jnp.asarray(s2))))
        assert err < 1e-5, (name, ndev, err)
        assert base.version == shrd.version


def test_sharded_state_layout_contract():
    """Exactly the d-trailing tensors shard; scalars/sketches replicate."""
    mesh = make_fed_mesh(2)
    kw = _psa_case()
    shrd = servers.make_server("fedpsa", _params(extra_bias=1), mesh=mesh,
                               **kw)
    d_pad = shrd._d_pad
    assert d_pad % 2 == 0 and d_pad >= shrd._d
    state = shrd.state

    def nshards(x):
        return len({s.device for s in x.addressable_shards})

    # sharded on the parameter axis
    assert state.params.shape == (d_pad,) and nshards(state.params) == 2
    assert state.psa.buffer.shape[-1] == d_pad
    assert nshards(state.psa.buffer) == 2
    # replicated
    assert nshards(state.version) in (1, 2)  # fully replicated or single
    for leaf in jax.tree_util.tree_leaves(
            (state.psa.kappas, state.psa.thermo, state.psa.global_sketch)):
        assert leaf.sharding.is_fully_replicated


def test_sharded_server_rejects_bad_rules():
    mesh = make_fed_mesh(2)
    bad = sharding.LogicalRules({"param_shard": None, "cohort": None})
    with pytest.raises(ValueError, match="param_shard"):
        servers.make_server("fedasync", _params(), mesh=mesh, rules=bad)
