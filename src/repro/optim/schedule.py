"""Learning-rate schedules (callables step -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_lr(lr: float):
    return lambda step: jnp.float32(lr)


def exponential_decay(lr: float, decay: float = 0.999):
    """The paper's per-round decay: lr * decay^round."""
    return lambda step: jnp.float32(lr) * jnp.power(jnp.float32(decay), step)


def cosine_decay(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        p = jnp.clip(step / total_steps, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * p))
        return jnp.float32(lr) * (final_frac + (1 - final_frac) * cos)
    return fn


def warmup_cosine(lr: float, warmup: int, total_steps: int, final_frac: float = 0.1):
    cos = cosine_decay(lr, max(total_steps - warmup, 1), final_frac)
    def fn(step):
        w = jnp.clip(step / jnp.maximum(warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, jnp.float32(lr) * w, cos(step - warmup))
    return fn
