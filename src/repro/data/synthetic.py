"""Synthetic datasets (offline stand-ins for MNIST/FMNIST/CIFAR).

The paper's experiments need labelled classification data with controllable
class structure so that Dirichlet label-skew partitioning produces the same
heterogeneity protocol. We use an anisotropic Gaussian-mixture: one mean per
class on a random simplex, shared covariance, plus per-class rotation, which
gives a task that linear models solve partially and small MLPs/CNNs solve
well — enough dynamic range to reproduce the paper's *orderings*.

``make_lm_corpus`` generates token streams from a sparse random bigram
chain, giving a learnable non-uniform LM task for the pretrain example.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np


@dataclass
class SyntheticClassification:
    x: np.ndarray       # (N, ...) float32
    y: np.ndarray       # (N,) int64
    num_classes: int

    def __len__(self):
        return self.x.shape[0]

    def subset(self, idx) -> "SyntheticClassification":
        return SyntheticClassification(self.x[idx], self.y[idx], self.num_classes)


def make_classification(num_samples: int = 10_000, num_classes: int = 10,
                        dim: int = 32, *, image_hw=None, seed: int = 0,
                        class_sep: float = 1.8,
                        noise: float = 1.0) -> SyntheticClassification:
    """Gaussian mixture. ``image_hw=(H, W, C)`` reshapes features to images
    (for the CNN family); dim is then H*W*C."""
    rng = np.random.RandomState(seed)
    if image_hw is not None:
        dim = int(np.prod(image_hw))
    means = rng.randn(num_classes, dim).astype(np.float32)
    means *= class_sep / np.linalg.norm(means, axis=1, keepdims=True)
    y = rng.randint(0, num_classes, size=num_samples)
    x = means[y] + noise * rng.randn(num_samples, dim).astype(np.float32) / np.sqrt(dim) * np.sqrt(dim) * 0.3
    # mild class-dependent rotation so the task is not purely linear
    w = rng.randn(num_classes, dim, 8).astype(np.float32) / np.sqrt(dim)
    feats = np.einsum("nd,ndk->nk", x, w[y])
    x[:, :8] += 0.5 * np.tanh(feats)
    x = x.astype(np.float32)
    if image_hw is not None:
        x = x.reshape((num_samples,) + tuple(image_hw))
    return SyntheticClassification(x, y.astype(np.int64), num_classes)


def train_test_split(ds: SyntheticClassification, test_frac: float = 0.1,
                     seed: int = 7):
    """Paper protocol: 10% test split, remainder training."""
    rng = np.random.RandomState(seed)
    idx = rng.permutation(len(ds))
    n_test = int(len(ds) * test_frac)
    return ds.subset(idx[n_test:]), ds.subset(idx[:n_test])


# ---------------------------------------------------------------------------
# Lazy population: 10^5-10^6 clients materialized on demand
# ---------------------------------------------------------------------------
#
# ``SyntheticPopulation`` is the population-scale source behind the
# streaming slab store (``data.loader.ClientSlabStore``): per-client rows
# are a pure function of (population seed, client id, row, column), so any
# client can be generated at any time — in wave batches, in whole shards,
# or as a standalone ``ClientDataset`` for the sequential oracle — and
# shard-cache evictions can never change what a re-materialized shard
# holds. Randomness comes from fixed noise/uniform tables indexed by a
# multiplicative hash of (client, row, column, tag): one vectorized gather
# per wave instead of per-client ``RandomState`` construction, which is
# what keeps on-demand materialization off the simulator's critical path.

_TABLE_BITS = 20
_TABLE = 1 << _TABLE_BITS
# distinct odd multipliers keep (client, row, column, tag) strides
# decorrelated modulo the table size
_HC, _HR, _HK, _HT = 0x9E3779B1, 0x85EBCA77, 0xC2B2AE35, 0x27D4EB2F
# tag ids: per-(client,row,col) noise, per-(client,row) label draws,
# per-client dominant classes
_T_NOISE, _T_LABEL, _T_TAIL, _T_DOM1, _T_DOM2, _T_TEST = range(6)


def _table_idx(*parts) -> np.ndarray:
    """Hash broadcastable integer parts into noise-table indices."""
    muls = (_HC, _HR, _HK, _HT)
    acc = 0
    for p, m in zip(parts, muls):
        acc = acc + np.asarray(p, np.int64) * m
    return (acc ^ (acc >> 17)) % _TABLE


class SyntheticPopulation:
    """A lazy ``make_classification``-style population of C clients.

    Shares one class structure (simplex means + class-dependent rotation,
    drawn once from the population seed) across all clients; each client
    holds a label-skewed sample — two hash-chosen dominant classes carry
    ~70% of its mass, the rest is uniform — with log-normal per-client
    sizes (``partition.skewed_client_sizes``). Nothing of size O(C * n_max)
    is ever materialized: the resident state is O(C) size/metadata arrays
    plus the fixed noise tables.

    Duck-types the simulator's population contract: ``sizes``,
    ``num_classes``, ``kind``, ``n_max``, ``member_rows(cids)`` (for the
    slab store) and ``__getitem__ -> ClientDataset`` / ``__len__`` (for the
    sequential oracle and the synchronous runner).
    """

    kind = "image"

    def __init__(self, num_clients: int, num_classes: int = 10,
                 dim: int = 32, *, seed: int = 0, class_sep: float = 1.8,
                 noise: float = 1.0, size_mean: int = 64,
                 size_spread: float = 0.5, size_lo: int = 16,
                 size_hi: int = 128, dominant_mass: float = 0.7):
        from repro.data.partition import skewed_client_sizes
        self.num_clients = int(num_clients)
        self.num_classes = int(num_classes)
        self.dim = int(dim)
        self.seed = int(seed)
        self.noise = float(noise)
        self.dominant_mass = float(dominant_mass)
        rng = np.random.RandomState(seed)
        means = rng.randn(num_classes, dim).astype(np.float32)
        means *= class_sep / np.linalg.norm(means, axis=1, keepdims=True)
        self.means = means
        self.w = rng.randn(num_classes, dim, 8).astype(np.float32) \
            / np.sqrt(dim)
        self._normals = rng.randn(_TABLE).astype(np.float32)
        self._uniforms = rng.rand(_TABLE)
        self.sizes = skewed_client_sizes(
            num_clients, mean=size_mean, spread=size_spread, lo=size_lo,
            hi=size_hi, seed=seed + 1)
        self.n_max = int(self.sizes.max())

    def __len__(self) -> int:
        return self.num_clients

    # -- row generation -----------------------------------------------------

    def _labels(self, cids: np.ndarray, n: int) -> np.ndarray:
        """(B, n) int labels: dominant-class skew, hash-deterministic."""
        K = self.num_classes
        c = cids[:, None]
        rows = np.arange(n)[None, :]
        dom1 = (self._uniforms[_table_idx(cids, 0, 0, _T_DOM1)]
                * K).astype(np.int64)[:, None]
        dom2 = (self._uniforms[_table_idx(cids, 0, 0, _T_DOM2)]
                * K).astype(np.int64)[:, None]
        r = self._uniforms[_table_idx(c, rows, 0, _T_LABEL)]
        tail = (self._uniforms[_table_idx(c, rows, 0, _T_TAIL)]
                * K).astype(np.int64)
        q = self.dominant_mass
        return np.where(r < 0.6 * q, dom1,
                        np.where(r < q, dom2, tail))

    def _features(self, cids: np.ndarray, y: np.ndarray) -> np.ndarray:
        """(B, n, dim) float32 features for the given labels — the same
        mixture + rotation arithmetic as ``make_classification``."""
        B, n = y.shape
        c = cids[:, None, None]
        rows = np.arange(n)[None, :, None]
        cols = np.arange(self.dim)[None, None, :]
        g = self._normals[_table_idx(c, rows, cols, _T_NOISE)]
        x = self.means[y] + self.noise * 0.3 * g
        feats = np.einsum("bnd,bndk->bnk", x, self.w[y])
        x[:, :, :8] += 0.5 * np.tanh(feats)
        return x.astype(np.float32)

    def member_rows(self, cids) -> tuple:
        """Materialize clients as padded ``(B, n_max, dim)`` / ``(B, n_max)``
        host arrays (rows past ``sizes[c]`` zeroed) — the slab-store row
        protocol. One vectorized build, no per-client RNG objects."""
        cids = np.asarray(cids, np.int64)
        y = self._labels(cids, self.n_max)
        x = self._features(cids, y)
        valid = np.arange(self.n_max)[None, :] < self.sizes[cids][:, None]
        x *= valid[:, :, None]
        y = (y * valid).astype(np.int32)
        return x, y

    def __getitem__(self, c: int):
        """Client ``c`` as a standalone ``ClientDataset`` (the sequential
        oracle's view) — identical rows to the streamed slab."""
        from repro.data.loader import ClientDataset
        x, y = self.member_rows([int(c)])
        n = int(self.sizes[int(c)])
        return ClientDataset(SyntheticClassification(
            x[0, :n], y[0, :n].astype(np.int64), self.num_classes))

    def test_dataset(self, n: int = 2048) -> SyntheticClassification:
        """An i.i.d. uniform-label sample from the shared mixture (held-out
        evaluation set; reserved hash lane, no client overlap)."""
        cid = np.asarray([self.num_clients], np.int64)
        rows = np.arange(n)[None, :]
        y = (self._uniforms[_table_idx(cid[:, None], rows, 0, _T_TEST)]
             * self.num_classes).astype(np.int64)
        x = self._features(cid, y)
        return SyntheticClassification(x[0], y[0], self.num_classes)


def make_lm_corpus(num_tokens: int = 2_000_000, vocab: int = 512,
                   seed: int = 0, branching: int = 8) -> np.ndarray:
    """Sparse random bigram chain: each token has ``branching`` likely
    successors — cross-entropy floor ~ log(branching) < log(vocab)."""
    rng = np.random.RandomState(seed)
    succ = rng.randint(0, vocab, size=(vocab, branching))
    probs = rng.dirichlet(np.ones(branching) * 0.5, size=vocab)
    out = np.empty(num_tokens, np.int32)
    t = rng.randint(vocab)
    for i in range(num_tokens):
        out[i] = t
        t = succ[t, rng.choice(branching, p=probs[t])]
    return out
