"""Simulator dispatch throughput: legacy per-client loop vs cohort engine.

The point of the cohort refactor: simulated wall-clock should be bounded by
device math, not per-dispatch python/jit overhead. This benchmark runs the
same async world (fedasync, uniform clients) under both engines and reports
dispatches/second at C in {50, 500, 5000} synthetic clients. Horizons are
scaled so each cell processes a comparable number of dispatches; a warmup
run populates the jit caches so compile time is not billed to either engine.

Writes artifacts/bench/BENCH_sim_throughput.json. Acceptance gate (ISSUE 2):
cohort >= 5x legacy at C=500. Override the client counts with
SIM_BENCH_CLIENTS=50,500 (comma-separated) for a quick smoke run.

``--mesh N`` adds a third engine variant per cell — the cohort engine with
the policy server sharded over an N-device mesh (the wave also trains
data-parallel over the client axis) — so the artifact records sharded vs
replicated dispatch throughput side by side. On a CPU box combine with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (virtual devices:
expect layout overhead, not speedup — the point is the measurement).

``--family [LIST]`` switches to the model-family sweep: one cell per
architecture (default the paper MLP plus the three fed-lm families —
override with a comma list or SIM_BENCH_FAMILIES), cohort vs sequential at
a fixed client count (SIM_BENCH_FAMILY_CLIENTS, default 50), written to
artifacts/bench/BENCH_sim_throughput_family.json. Gate (ISSUE 4): cohort
>= 3x sequential on the fed-lm-smoke scenario.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data import ClientDataset, make_classification
from repro.federated import SimConfig, run_async
from repro.launch.mesh import make_fed_mesh
from repro.launch.train import build_task
from repro.models import model as model_lib
from benchmarks import common

# Paper-protocol local work: E=5 epochs over each client's shard. 192
# samples at batch 16 = 12 batches/epoch -> 60 local SGD steps per dispatch,
# the regime where the legacy loop pays 60 per-batch jit dispatches + host
# batch copies while the cohort engine runs one fused scan.
SAMPLES_PER_CLIENT = 192
BATCH_SIZE = 16
LOCAL_EPOCHS = 5
LATENCY_LO, LATENCY_HI = 100.0, 500.0
TARGET_DISPATCHES = 150  # per timed run, roughly, at every C


def build_world(num_clients: int, seed: int = 0):
    cfg = get_config("paper-synthetic-mlp")
    n = num_clients * SAMPLES_PER_CLIENT
    full = make_classification(n + 1000, cfg.num_classes, dim=cfg.input_hw[0],
                               seed=seed, class_sep=0.7)
    test = full.subset(np.arange(n, n + 1000))
    clients = [
        ClientDataset(full.subset(np.arange(c * SAMPLES_PER_CLIENT,
                                            (c + 1) * SAMPLES_PER_CLIENT)))
        for c in range(num_clients)
    ]
    params = model_lib.init_params(jax.random.PRNGKey(seed), cfg)
    return cfg, clients, test, params


def sim_for(num_clients: int, horizon: float, engine: str,
            mesh=None) -> SimConfig:
    return SimConfig(
        num_clients=num_clients, concurrency=0.2, local_epochs=LOCAL_EPOCHS,
        batch_size=BATCH_SIZE, horizon=horizon, eval_every=horizon,
        latency_kind="uniform", latency_lo=LATENCY_LO, latency_hi=LATENCY_HI,
        seed=0, eval_batches=2, engine=engine, mesh=mesh)


def horizon_for(num_clients: int, target: int) -> float:
    """Horizon putting ~target dispatches through the heap: the steady-state
    completion rate is concurrency / mean_latency per client."""
    mean_lat = 0.5 * (LATENCY_LO + LATENCY_HI)
    rate = 0.2 * num_clients / mean_lat
    return max(target / rate, 2.0 * LATENCY_HI)


def bench_cell(num_clients: int, mesh=None) -> dict:
    cfg, clients, test, params = build_world(num_clients)
    horizon = horizon_for(num_clients, TARGET_DISPATCHES)
    cell = {"num_clients": num_clients, "horizon": horizon}
    variants = [("sequential", "sequential", None), ("cohort", "cohort", None)]
    if mesh is not None:
        variants.append(("cohort_sharded", "cohort", mesh))
    for label, engine, m in variants:
        sim = sim_for(num_clients, horizon, engine, mesh=m)
        # full-length warmup: identical run, so every wave/chunk bucket the
        # timed run hits is already compiled for both engines
        run_async("fedasync", cfg, params, clients, test, sim)
        t0 = time.perf_counter()
        res = run_async("fedasync", cfg, params, clients, test, sim)
        wall = time.perf_counter() - t0
        assert res.engine == engine, (label, res.engine)  # no silent fallback
        cell[label] = {
            "dispatches": res.dispatches,
            "wall_s": wall,
            "dispatches_per_s": res.dispatches / wall,
            "cohorts": res.cohorts,
            "mean_cohort_size": (res.dispatches / res.cohorts
                                 if res.cohorts else 1.0),
            "final_accuracy": res.final_accuracy,
        }
        print(f"sim_throughput,C={num_clients},engine={label},"
              f"dispatches={res.dispatches},wall_s={wall:.2f},"
              f"dps={res.dispatches / wall:.2f}", flush=True)
    cell["speedup"] = (cell["cohort"]["dispatches_per_s"]
                       / cell["sequential"]["dispatches_per_s"])
    if mesh is not None:
        cell["sharded_vs_replicated"] = (
            cell["cohort_sharded"]["dispatches_per_s"]
            / cell["cohort"]["dispatches_per_s"])
    print(f"sim_throughput,C={num_clients},speedup={cell['speedup']:.2f}x",
          flush=True)
    return cell


DEFAULT_FAMILIES = ("paper-synthetic-mlp,fed-lm-smoke,"
                    "fed-lm-ssm-smoke,fed-lm-moe-smoke")
# The family sweep measures the overhead-bound many-small-clients regime
# the simulator targets: 96 sequences / batch 2 x 5 epochs = ~215 local SGD
# steps per dispatch on the tiny fed-lm smokes, 256 clients (wave ~16),
# ~60+ timed dispatches per engine. Transformer local steps are real device
# math even at smoke scale, so the per-family gate (>=3x) only applies at
# the default client count — a reduced SIM_BENCH_FAMILY_CLIENTS smoke run
# (CI) records the cells without gating, like SIM_BENCH_CLIENTS does.
FAMILY_SAMPLES_PER_CLIENT = 96
FAMILY_BATCH_SIZE = 2
FAMILY_NUM_CLIENTS = 256
FAMILY_TARGET_DISPATCHES = 60
SEQ_LEN = 8


def bench_family_cell(arch: str, num_clients: int) -> dict:
    """Cohort vs sequential for one architecture's federated scenario
    (image families get the classification world, token families the
    LM fine-tuning world), equal-size client shards."""
    cfg, clients, test, _calib = build_task(
        arch, num_clients * FAMILY_SAMPLES_PER_CLIENT, alpha=0.0,
        num_clients=num_clients, seed=0, seq_len=SEQ_LEN)
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    horizon = horizon_for(num_clients, FAMILY_TARGET_DISPATCHES)
    cell = {"arch": arch, "family": cfg.family, "num_clients": num_clients,
            "horizon": horizon,
            "mean_shard": float(np.mean([len(c) for c in clients]))}
    def fam_sim(h, engine):
        return SimConfig(
            num_clients=num_clients, concurrency=0.2,
            local_epochs=LOCAL_EPOCHS, batch_size=FAMILY_BATCH_SIZE,
            horizon=h, eval_every=h, latency_kind="uniform",
            latency_lo=LATENCY_LO, latency_hi=LATENCY_HI, seed=0,
            eval_batches=2, engine=engine)

    for engine in ("sequential", "cohort"):
        sim = fam_sim(horizon, engine)
        # full-length warmup, as in bench_cell: every wave bucket the timed
        # run hits is already compiled for both engines
        run_async("fedasync", cfg, params, clients, test, sim)
        t0 = time.perf_counter()
        res = run_async("fedasync", cfg, params, clients, test, sim)
        wall = time.perf_counter() - t0
        assert res.engine == engine, (arch, res.engine)  # no silent fallback
        cell[engine] = {
            "dispatches": res.dispatches,
            "wall_s": wall,
            "dispatches_per_s": res.dispatches / wall,
            "cohorts": res.cohorts,
            "final_accuracy": res.final_accuracy,
        }
        print(f"sim_throughput,arch={arch},engine={engine},"
              f"dispatches={res.dispatches},wall_s={wall:.2f},"
              f"dps={res.dispatches / wall:.2f}", flush=True)
    cell["speedup"] = (cell["cohort"]["dispatches_per_s"]
                       / cell["sequential"]["dispatches_per_s"])
    print(f"sim_throughput,arch={arch},speedup={cell['speedup']:.2f}x",
          flush=True)
    return cell


def run_family_bench(families: str) -> int:
    num_clients = int(os.environ.get("SIM_BENCH_FAMILY_CLIENTS",
                                     str(FAMILY_NUM_CLIENTS)))
    archs = (os.environ.get("SIM_BENCH_FAMILIES", DEFAULT_FAMILIES)
             if families == "all" else families).split(",")
    cells = [bench_family_cell(a.strip(), num_clients) for a in archs if a]
    payload = {
        "backend": jax.default_backend(),
        "num_clients": num_clients,
        "local_epochs": LOCAL_EPOCHS,
        "batch_size": FAMILY_BATCH_SIZE,
        "seq_len": SEQ_LEN,
        "cells": cells,
    }
    path = common.save("BENCH_sim_throughput_family", payload)
    print(f"wrote {path}")
    gate = [c for c in cells if c["arch"] == "fed-lm-smoke"]
    if (gate and num_clients >= FAMILY_NUM_CLIENTS
            and gate[0]["speedup"] < 3.0):
        print(f"WARNING: fed-lm-smoke speedup is "
              f"{gate[0]['speedup']:.2f}x < 3x", file=sys.stderr)
        return 1
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="also run the cohort engine with an N-device "
                         "sharded policy server per cell (0 = off)")
    ap.add_argument("--family", nargs="?", const="all", default=None,
                    metavar="LIST",
                    help="run the per-model-family sweep instead (comma "
                         "list of arch ids; bare flag = the default set)")
    args = ap.parse_args(argv)
    if args.family:
        return run_family_bench(args.family)
    mesh = None
    if args.mesh:
        try:
            mesh = make_fed_mesh(args.mesh)
        except ValueError as e:   # too few devices; the error carries the fix
            print(e, file=sys.stderr)
            return 2
    counts = os.environ.get("SIM_BENCH_CLIENTS", "50,500,5000")
    cells = [bench_cell(int(c), mesh=mesh) for c in counts.split(",")]
    payload = {
        "model": "paper-synthetic-mlp",
        "local_steps_per_dispatch": LOCAL_EPOCHS * (SAMPLES_PER_CLIENT // BATCH_SIZE),
        "backend": jax.default_backend(),
        "mesh_devices": args.mesh or None,
        "cells": cells,
    }
    # mesh runs record to their own artifact so the headline replicated
    # numbers are never clobbered by a layout experiment
    artifact = "BENCH_sim_throughput_mesh" if mesh else "BENCH_sim_throughput"
    path = common.save(artifact, payload)
    print(f"wrote {path}")
    gate = [c for c in cells if c["num_clients"] == 500]
    if gate and gate[0]["speedup"] < 5.0:
        print(f"WARNING: speedup at C=500 is {gate[0]['speedup']:.2f}x < 5x",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
