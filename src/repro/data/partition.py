"""Client partitioning: Dirichlet label-skew (the paper's protocol) and IID."""
from __future__ import annotations

from typing import List

import numpy as np

from repro.data.synthetic import SyntheticClassification


def dirichlet_partition(ds: SyntheticClassification, num_clients: int,
                        alpha: float, seed: int = 0,
                        min_size: int = 2) -> List[np.ndarray]:
    """Standard Dirichlet(alpha) label-skew split: for each class, sample a
    client proportion vector ~ Dir(alpha) and scatter that class's samples.
    Smaller alpha => more heterogeneous. Retries until every client has at
    least ``min_size`` samples (as in common FL benchmarks)."""
    rng = np.random.RandomState(seed)
    n = len(ds)
    for _attempt in range(100):
        idx_by_client = [[] for _ in range(num_clients)]
        for c in range(ds.num_classes):
            idx_c = np.where(ds.y == c)[0]
            rng.shuffle(idx_c)
            p = rng.dirichlet(np.full(num_clients, alpha))
            cuts = (np.cumsum(p) * len(idx_c)).astype(int)[:-1]
            for client, part in enumerate(np.split(idx_c, cuts)):
                idx_by_client[client].extend(part.tolist())
        sizes = [len(ix) for ix in idx_by_client]
        if min(sizes) >= min_size:
            return [np.asarray(sorted(ix)) for ix in idx_by_client]
    raise RuntimeError("dirichlet_partition failed to satisfy min_size")


def iid_partition(ds: SyntheticClassification, num_clients: int,
                  seed: int = 0) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    idx = rng.permutation(len(ds))
    return [np.asarray(sorted(part)) for part in np.array_split(idx, num_clients)]
