"""Sharding-rule resolution: divisibility fallbacks across all 10 archs."""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.common.sharding import LogicalRules, PRODUCTION_RULES
from repro.configs import ASSIGNED, get_config
from repro.configs.shapes import SHAPES, config_for_shape, shape_supported
from repro.launch.mesh import axis_dims, rules_for
from repro.models import model as M


def _fake_mesh(shape, axes):
    return SimpleNamespace(axis_names=axes,
                           devices=SimpleNamespace(shape=shape))


POD = _fake_mesh((16, 16), ("data", "model"))
MULTIPOD = _fake_mesh((2, 16, 16), ("pod", "data", "model"))


def _nshards(mesh, assign):
    if assign is None:
        return 1
    axes = assign if isinstance(assign, (list, tuple)) else (assign,)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(np.prod([sizes[a] for a in axes]))


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("mesh", [POD, MULTIPOD], ids=["pod", "multipod"])
def test_rules_respect_divisibility(arch, mesh):
    cfg = get_config(arch)
    rules = rules_for(cfg, mesh, 256)
    dims = axis_dims(cfg, 256)
    for name, sizes in dims.items():
        assign = rules.rules.get(name)
        ns = _nshards(mesh, assign)
        for d in sizes:
            assert d % ns == 0, (arch, name, d, assign)


def test_head_dim_fallback_for_odd_head_counts():
    # recurrent-only archs: head_dim TP fallback applies
    rules = rules_for(get_config("xlstm-350m"), POD, 256)
    assert rules.rules["heads"] is None
    assert rules.rules["head_dim"] == "model"
    # attention archs with indivisible heads: attention runs replicated over
    # `model` (head_dim TP would all-reduce every f32 score block — §Perf)
    for arch in ("phi4-mini-3.8b", "internvl2-1b", "arctic-480b"):
        rules = rules_for(get_config(arch), POD, 256)
        assert rules.rules["heads"] is None, arch
        assert rules.rules["head_dim"] is None, arch
    for arch in ("llama3-405b", "codeqwen1.5-7b", "minitron-8b"):
        rules = rules_for(get_config(arch), POD, 256)
        assert rules.rules["heads"] == "model", arch
        assert rules.rules["head_dim"] is None, arch


def test_qwen2_moe_expert_tensor_parallel():
    cfg = get_config("qwen2-moe-a2.7b")
    rules = rules_for(cfg, POD, 256)
    assert rules.rules["expert"] is None        # 60 does not divide 16
    assert rules.rules["expert_mlp"] == "model"  # 1408 = 16 * 88
    arctic = rules_for(get_config("arctic-480b"), POD, 256)
    assert arctic.rules["expert"] == "model"     # 128 = 16 * 8


def test_batch_replicated_for_long500k():
    cfg = get_config("jamba-v0.1-52b")
    rules = rules_for(cfg, POD, 1)  # long_500k: global_batch=1
    assert rules.rules["batch"] is None
    rules256 = rules_for(cfg, POD, 256)
    assert rules256.rules["batch"] == "data"


def test_vocab_fallback_for_non_divisible():
    assert rules_for(get_config("internvl2-1b"), POD, 256).rules["vocab"] is None
    assert rules_for(get_config("hubert-xlarge"), POD, 256).rules["vocab"] is None
    assert rules_for(get_config("llama3-405b"), POD, 256).rules["vocab"] == "model"


def test_spec_dedup_first_wins():
    rules = LogicalRules({"a": "model", "b": "model", "c": "data"})
    spec = rules.mesh_axes(("a", "b", "c"))
    assert spec == __import__("jax").sharding.PartitionSpec("model", None, "data")


def test_pod_axis_dropped_on_single_pod_mesh():
    cfg = get_config("llama3-405b")
    rules = rules_for(cfg, POD, 256)
    assert rules.rules["batch"] == "data"
    rules_mp = rules_for(cfg, MULTIPOD, 256)
    assert tuple(rules_mp.rules["batch"]) == ("pod", "data")


def test_assignment_matrix_counts():
    """10 archs x 4 shapes = 40; hubert decode shapes are the only skips."""
    total, skipped = 0, []
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for s in SHAPES:
            total += 1
            ok, why = shape_supported(cfg, s)
            if not ok:
                skipped.append((arch, s))
    assert total == 40
    assert sorted(skipped) == [("hubert-xlarge", "decode_32k"),
                               ("hubert-xlarge", "long_500k")]


@pytest.mark.parametrize("arch", ASSIGNED)
def test_input_specs_wellformed(arch):
    from repro.configs.shapes import input_specs
    import jax
    cfg = get_config(arch)
    for s in SHAPES:
        ok, _ = shape_supported(cfg, s)
        if not ok:
            continue
        mode, specs, axes = input_specs(cfg, s)
        flat_s = jax.tree_util.tree_leaves(specs)
        assert all(isinstance(x, jax.ShapeDtypeStruct) for x in flat_s)
        # axes tree matches specs tree structure
        def is_ax(x):
            return isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)
        flat_a = jax.tree_util.tree_leaves(axes, is_leaf=is_ax)
        assert len(flat_a) == len(flat_s), (arch, s)
        if mode == "train":
            b = specs["batch"]
            leading = jax.tree_util.tree_leaves(b)[0].shape[0]
            assert leading == SHAPES[s].global_batch


def test_long_context_variant_sets_window():
    cfg = get_config("llama3-405b")
    assert config_for_shape(cfg, "long_500k").sliding_window == 8192
    assert config_for_shape(cfg, "train_4k").sliding_window is None
    # ssm archs don't need a window
    x = get_config("xlstm-350m")
    assert config_for_shape(x, "long_500k").sliding_window is None
