"""Client-side local training (paper protocol: E epochs of SGD, batch 64).

The per-batch step is jit'd once per (model config, variant) and cached.
``local_update`` returns the parameter delta dw = w_after - w_before plus
optional extras (FedPSA sensitivity sketch, FedPAC alignment stats).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.common import tree as tu
from repro.data.loader import ClientDataset
from repro.models import model as model_lib
from repro.models import registry
from repro.models.config import ModelConfig

_STEP_CACHE = {}


def _client_loss_fn(cfg: ModelConfig):
    """The registry's client_loss is the one per-family training-loss entry
    point both engines share (for cnn/mlp it is arithmetically identical to
    the legacy model_lib.loss_fn dispatch); unregistered families keep the
    generic loss_fn so the sequential fallback stays able to train them."""
    return (registry.get_family(cfg).client_loss
            if registry.is_registered(cfg.family) else model_lib.loss_fn)


def _loss_for(cfg: ModelConfig, prox: float, align: float, base_fn):
    def loss(params, batch, anchor):
        base = base_fn(params, batch, cfg, _RULES)
        if prox > 0.0:  # FedProx-style proximal pull toward the anchor
            base = base + 0.5 * prox * tu.tree_sq_norm(tu.tree_sub(params, anchor))
        if align > 0.0:  # FedPAC-lite: align the classifier head with global
            head_p = _head(params)
            head_a = _head(anchor)
            base = base + 0.5 * align * tu.tree_sq_norm(tu.tree_sub(head_p, head_a))
        return base
    return loss


def _head(params):
    """Classifier head leaves (last fc layer) of the paper models."""
    fc_keys = sorted(k for k in params if k.startswith("fc"))
    return params[fc_keys[-1]] if fc_keys else params


from repro.common.sharding import SINGLE_DEVICE_RULES as _RULES


def _get_step(cfg: ModelConfig, prox: float, align: float):
    # the resolved loss entry is part of the key so register_family(...,
    # override=True) invalidates the compiled step instead of silently
    # reusing the replaced entry's program
    base_fn = _client_loss_fn(cfg)
    key = (cfg, prox, align, base_fn)
    if key not in _STEP_CACHE:
        loss = _loss_for(cfg, prox, align, base_fn)

        @jax.jit
        def step(params, batch, anchor, lr):
            g = jax.grad(loss)(params, batch, anchor)
            return jax.tree_util.tree_map(
                lambda p, gi: p - lr * gi.astype(p.dtype), params, g)

        _STEP_CACHE[key] = step
    return _STEP_CACHE[key]


def local_update(global_params, cfg: ModelConfig, dataset: ClientDataset, *,
                 epochs: int = 5, batch_size: int = 64, lr: float = 0.01,
                 seed: int = 0, prox: float = 0.0, align: float = 0.0):
    """Run E local epochs of SGD from ``global_params``; returns (delta, w_i)."""
    step = _get_step(cfg, prox, align)
    params = global_params
    lr = jnp.float32(lr)
    for batch in dataset.epochs(epochs, batch_size, seed):
        params = step(params, batch, global_params, lr)
    delta = tu.tree_sub(params, global_params)
    return delta, params
