"""AdamW with f32 moments (used by the LM pretrain example)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "t": jnp.int32(0),
        }

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(m_, v_, p):
            step = m_ / bc1 / (jnp.sqrt(v_ / bc2) + eps)
            return -lr * (step + weight_decay * p.astype(jnp.float32))

        updates = jax.tree_util.tree_map(upd, m, v, params)
        return updates, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)
