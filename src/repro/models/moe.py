"""Mixture-of-Experts feed-forward with token-choice top-k capacity routing.

GShard-style dispatch: each token picks its top-k experts; a cumulative-sum
position assignment gives every (token, expert) choice a slot in a fixed
capacity buffer ``(E, C, D)``; overflowing tokens are dropped (weighted by the
capacity factor). The buffer is expert-sharded over the ``model`` mesh axis
(expert parallelism) unless ``cfg.expert_tensor_parallel`` — used when the
expert count does not divide the axis (qwen2-moe: 60 experts) — in which case
experts are replicated and the per-expert hidden dim is tensor-parallel.

Supports the assigned MoE variants:
* qwen2-moe-a2.7b: 60 routed top-4 + 4 shared experts (always-on dense path)
* jamba-v0.1-52b:  16 routed top-2 (on alternating layers)
* arctic-480b:     128 routed top-2 + dense residual FFN in parallel
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.sharding import LogicalRules, with_logical_constraint
from repro.models.config import ModelConfig
from repro.models import layers
from repro.models.member_math import member_dot


def init_moe(key, cfg: ModelConfig) -> dict:
    pd = layers.param_dtype_of(cfg)
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": layers.dense_init(k1, (D, E), pd, scale=0.02),
        "w_in": layers.dense_init(k2, (E, D, F), pd),
        "w_gate": layers.dense_init(k3, (E, D, F), pd),
        "w_out": layers.dense_init(k4, (E, F, D), pd, scale=1.0 / math.sqrt(F)),
    }
    if cfg.num_shared_experts > 0:
        sf = cfg.shared_d_ff or cfg.num_shared_experts * F
        p["shared"] = layers.init_ffn(k5, cfg, d_ff=sf)
    return p


MOE_AXES = {
    "router": ("embed", None),
    "w_in": ("expert", "embed", "expert_mlp"),
    "w_gate": ("expert", "embed", "expert_mlp"),
    "w_out": ("expert", "expert_mlp", "embed"),
    "shared": layers.FFN_AXES,
}


def moe_capacity(cfg: ModelConfig, num_tokens: int) -> int:
    c = int(math.ceil(cfg.top_k * num_tokens * cfg.capacity_factor / cfg.num_experts))
    return max(c, 1)


def moe_forward(params, x, cfg: ModelConfig, rules: LogicalRules):
    """x: (B, S, D) -> (y, aux_loss).

    Grouped token-choice dispatch: tokens split into ``cfg.dispatch_groups``
    groups (the group dim carries the "batch" sharding, aligning groups with
    data shards); cumsum position assignment, capacity, scatter and combine
    are group-LOCAL, so no global (E, C, D) buffer is ever materialized or
    all-reduced. With dispatch_groups=1 this is the classic single-group
    GShard dispatch.
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * S
    G = max(cfg.dispatch_groups, 1)
    if T % G:
        G = 1
    Tg = T // G
    C = moe_capacity(cfg, Tg)

    g_ax = "batch" if G > 1 else None  # never shard a size-1 group dim
    xt = x.reshape(G, Tg, D)
    xt = with_logical_constraint(xt, rules, (g_ax, "tokens" if G == 1 else None, "embed_act"))

    logits = member_dot(xt, params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (G, Tg, E)
    top_p, top_e = jax.lax.top_k(probs, K)   # (G, Tg, K)
    if cfg.name.startswith("qwen2-moe"):
        # qwen renormalizes the selected probs
        top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # Load-balance auxiliary loss (Switch-style): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=(0, 1))
    one_hot_all = jax.nn.one_hot(top_e, E, dtype=jnp.float32)       # (G, Tg, K, E)
    fe = jnp.mean(jnp.sum(one_hot_all, axis=2), axis=(0, 1))        # fraction routed
    aux = cfg.router_aux_coef * E * jnp.sum(fe * me)

    # Group-local position-in-expert via cumsum over the (Tg*K) choice list.
    choice_e = top_e.reshape(G, Tg * K)
    choice_p = top_p.reshape(G, Tg * K)
    oh = jax.nn.one_hot(choice_e, E, dtype=jnp.int32)               # (G, Tg*K, E)
    pos = jnp.cumsum(oh, axis=1) - 1                                # per-group position
    pos_in_e = jnp.sum(pos * oh, axis=-1)                           # (G, Tg*K)
    keep = (pos_in_e < C)
    slot = jnp.where(keep, pos_in_e, 0)

    tok_idx = jnp.repeat(jnp.arange(Tg), K)                         # shared per group
    w = jnp.where(keep, choice_p, 0.0).astype(jnp.float32)

    def dispatch(xt_g, choice_e_g, slot_g, keep_g):
        buf = jnp.zeros((E, C, D), xt_g.dtype)
        src = xt_g[tok_idx] * keep_g[:, None].astype(xt_g.dtype)
        return buf.at[choice_e_g, slot_g].add(src)

    buf = jax.vmap(dispatch)(xt, choice_e, slot, keep)              # (G, E, C, D)
    buf = with_logical_constraint(
        buf, rules, (g_ax, "expert", "expert_capacity", "embed_act"))

    # Expert computation (SwiGLU), batched over groups and experts. These
    # stay on XLA einsum (not member_dot): the expert axis e is a diagonal
    # batch dim shared by activations and weights, which the grouped member
    # kernel's (group, M, K) x (group, K, N) form cannot express.
    h_in = jnp.einsum("gecd,edf->gecf", buf, params["w_in"].astype(x.dtype))
    h_gate = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"].astype(x.dtype))
    h = jax.nn.silu(h_gate) * h_in
    h = with_logical_constraint(
        h, rules, (g_ax, "expert", "expert_capacity", "expert_mlp"))
    out_buf = jnp.einsum("gecf,efd->gecd", h, params["w_out"].astype(x.dtype))
    out_buf = with_logical_constraint(
        out_buf, rules, (g_ax, "expert", "expert_capacity", "embed_act"))

    def combine(out_g, choice_e_g, slot_g, w_g):
        gathered = out_g[choice_e_g, slot_g].astype(jnp.float32) * w_g[:, None]
        return jnp.zeros((Tg, D), jnp.float32).at[tok_idx].add(gathered)

    y = jax.vmap(combine)(out_buf, choice_e, slot, w).astype(x.dtype)  # (G, Tg, D)

    if "shared" in params:
        y = y + layers.ffn_forward(params["shared"], x, cfg, rules).reshape(G, Tg, D)

    y = with_logical_constraint(y, rules, (g_ax, "tokens" if G == 1 else None, "embed_act"))
    return y.reshape(B, S, D), aux


def moe_forward_dense(params, x, cfg: ModelConfig, rules: LogicalRules):
    """Reference dropless implementation: every expert sees every token.

    O(E) more FLOPs than dispatch — used as the correctness oracle in tests
    and for tiny smoke configs where capacity dropping would add noise.
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    xt = x.reshape(B * S, D)
    logits = member_dot(xt, params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)
    if cfg.name.startswith("qwen2-moe"):
        top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)
    gate = jnp.zeros_like(probs).at[jnp.arange(xt.shape[0])[:, None], top_e].set(top_p)

    h_in = jnp.einsum("td,edf->etf", xt, params["w_in"].astype(x.dtype))
    h_gate = jnp.einsum("td,edf->etf", xt, params["w_gate"].astype(x.dtype))
    h = jax.nn.silu(h_gate) * h_in
    out = jnp.einsum("etf,efd->etd", h, params["w_out"].astype(x.dtype))
    y = jnp.einsum("etd,te->td", out.astype(jnp.float32), gate).astype(x.dtype)

    me = jnp.mean(probs, axis=0)
    fe = jnp.mean(jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=1), axis=0)
    aux = cfg.router_aux_coef * E * jnp.sum(fe * me)

    if "shared" in params:
        y = y + layers.ffn_forward(params["shared"], x, cfg, rules).reshape(-1, D)
    return y.reshape(B, S, D), aux
