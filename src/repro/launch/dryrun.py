"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination
on 512 placeholder host devices, and extract the roofline raw terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Writes one JSON per combination into artifacts/dryrun/: cost_analysis FLOPs
and bytes (per-device: the compiled module is the SPMD per-device program),
memory_analysis, and the collective ops parsed from the partitioned HLO with
a per-op ICI byte estimate (ring cost model, group size from replica_groups).
"""
# The VERY FIRST lines, before ANY other import: jax locks the device count
# on first init. Do NOT set this in conftest/pyproject — only the dry-run
# needs 512 placeholder devices.
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

import argparse
import json
import re
import time
import traceback

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED, get_config
from repro.configs.shapes import (SHAPES, config_for_shape, input_specs,
                                  shape_supported)
from repro.launch import steps as steps_lib
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh, rules_for
from repro.models import model as model_lib

def _named(mesh, rules, axes_tree):
    def leaf(ax):
        return NamedSharding(mesh, rules.mesh_axes(ax))
    return jax.tree_util.tree_map(
        leaf, axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x))


def run_one(arch: str, shape: str, mesh_kind: str, out_dir: str,
            verbose: bool = True, overrides: dict = None, tag: str = "") -> dict:
    cfg0 = get_config(arch)
    ok, why = shape_supported(cfg0, shape)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind}
    if tag:
        rec["tag"] = tag
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        _save(rec, out_dir)
        return rec
    cfg = config_for_shape(cfg0, shape)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
        rec["overrides"] = {k: str(v) for k, v in overrides.items()}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    world = int(np.prod(mesh.devices.shape))
    gb = SHAPES[shape].global_batch
    rules = rules_for(cfg, mesh, gb)
    mode, specs, axes = input_specs(cfg0, shape)

    params_sds = jax.eval_shape(lambda: model_lib.init_params(jax.random.PRNGKey(0), cfg))
    p_axes = model_lib.param_axes(cfg, params_sds)
    params_sh = _named(mesh, rules, p_axes)
    in_sh = [_named(mesh, rules, axes[k]) for k in specs]
    arg_sds = [specs[k] for k in specs]

    step = steps_lib.make_step(mode, cfg, rules)
    t0 = time.time()
    total, active = model_lib.count_params(cfg)
    rec.update({
        "mode": mode, "world": world,
        "params_total": total, "params_active": active,
        "seq_len": SHAPES[shape].seq_len, "global_batch": gb,
        "rules": {k: (list(v) if isinstance(v, (list, tuple)) else v)
                   for k, v in rules.rules.items()},
    })
    try:
        if mode == "train":
            lr_sds = jax.ShapeDtypeStruct((), np.float32)
            jitted = jax.jit(step, in_shardings=(params_sh, in_sh[0], None))
            with mesh:
                lowered = jitted.lower(params_sds, arg_sds[0], lr_sds)
        elif mode in ("prefill", "encode"):
            jitted = jax.jit(step, in_shardings=(params_sh, in_sh[0]))
            with mesh:
                lowered = jitted.lower(params_sds, arg_sds[0])
        else:  # decode: (params, cache, tokens, pos)
            jitted = jax.jit(
                step,
                in_shardings=(params_sh, in_sh[0], in_sh[1], None))
            with mesh:
                lowered = jitted.lower(params_sds, arg_sds[0], arg_sds[1],
                                       jax.ShapeDtypeStruct((), np.int32))
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # jax>=0.4.30: one dict per device
            cost = cost[0] if cost else {}
        try:
            mem = compiled.memory_analysis()
            mem_rec = {a: int(getattr(mem, a)) for a in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes") if hasattr(mem, a)}
        except Exception as e:  # CPU backend may not implement it
            mem_rec = {"error": str(e)}
        text = compiled.as_text()
        t0 = time.time()
        hc = hlo_cost.analyze(text, world)  # trip-count-aware (see hlo_cost.py)
        t_analyze = time.time() - t0
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "analyze_s": round(t_analyze, 2),
            "flops_per_device": hc["flops_per_device"],
            "bytes_per_device": hc["bytes_per_device"],
            "collective_ici_bytes": hc["ici_bytes_per_device"],
            "transcendentals_per_device": hc["transcendentals"],
            "collectives": hc["collectives"],
            "unparsed_loops": hc["unparsed_loops"],
            # XLA's own (loop-body-once) numbers, for reference
            "xla_cost_analysis": {k: float(v) for k, v in cost.items()
                                  if isinstance(v, (int, float)) and not k.startswith("utilization")},
            "memory_analysis": mem_rec,
            "n_collectives": int(sum(s["count"] for s in hc["collectives"].values())),
            "hlo_lines": text.count("\n"),
        })
        if verbose:
            print(f"[dryrun] {arch} x {shape} x {mesh_kind}: OK "
                  f"flops/dev={rec['flops_per_device']:.3e} "
                  f"ici={rec['collective_ici_bytes']:.3e}B "
                  f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[dryrun] {arch} x {shape} x {mesh_kind}: FAIL {rec['error']}")
    _save(rec, out_dir)
    return rec


def _save(rec: dict, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    tag = f"__{rec['tag']}" if rec.get("tag") else ""
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{tag}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1, default=float)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="", help="suffix for artifact filenames")
    ap.add_argument("--scan-groups", type=int, default=None)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--remat", default=None, choices=["none", "full", "dots"])
    ap.add_argument("--dispatch-groups", type=int, default=None)
    ap.add_argument("--pure-dp", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=None)
    args = ap.parse_args()

    overrides = {}
    if args.scan_groups is not None:
        overrides["scan_groups"] = args.scan_groups
    if args.seq_shard:
        overrides["seq_shard"] = True
    if args.remat is not None:
        overrides["remat"] = args.remat
    if args.dispatch_groups is not None:
        overrides["dispatch_groups"] = args.dispatch_groups
    if args.pure_dp:
        overrides["pure_data_parallel"] = True
    if args.grad_accum is not None:
        overrides["grad_accum"] = args.grad_accum

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    archs = ASSIGNED if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]

    results = []
    for m in meshes:
        for a in archs:
            for s in shapes:
                results.append(run_one(a, s, m, args.out,
                                       overrides=overrides or None,
                                       tag=args.tag))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
