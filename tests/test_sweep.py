"""Fleet sweep engine: lane independence, permutation safety, resume.

The lane contract (ARCHITECTURE.md "sweep-lane contract"): an S-lane
``run_sweep`` is S independent simulations sharing one event timeline. These
tests pin that down three ways:

* *Lane parity* — every lane's per-receive digest stream equals the
  standalone ``run_async`` with the same timeline seed, data seed, init
  params and hyperparameters, at 1e-5 (bit-exact for the ring policies on
  CPU, where the vmapped member program is the same op sequence).
* *Permutation* — permuting the lane order permutes the results and nothing
  else: no cross-lane talk through the stacked state or the vmapped calls.
* *Checkpoint resume* — ``SimConfig.checkpoint_dir``/``checkpoint_every``
  snapshots a single run mid-flight; resuming reproduces the remaining
  digest stream of the uninterrupted run exactly.

Deterministic cases always run; with ``hypothesis`` installed the parity
invariant is additionally fuzzed over lane counts, seeds and hyper grids.
"""
import os

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import PSAConfig
from repro.data import (ClientDataset, dirichlet_partition,
                        make_calibration_batch, make_classification,
                        train_test_split)
from repro.federated import (SimConfig, SweepConfig, run_algorithm,
                             run_sweep)
from repro.models import model as M

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

NUM_CLIENTS = 6
QUICK = dict(num_clients=NUM_CLIENTS, horizon=3_500.0, eval_every=1_750.0)

# The lane contract tolerance. Immediate-mix policies (fedasync) come out
# bit-exact on CPU; the ring policies' buffered einsum reassociates under
# the lane vmap at ~5e-7 relative, well inside the 1e-5 contract.
FLOAT_TOL = 1e-5


@pytest.fixture(scope="module")
def world():
    cfg = get_config("paper-synthetic-mlp")
    full = make_classification(800, 10, 32, seed=0, class_sep=0.7)
    train, test = train_test_split(full, 0.1)
    parts = dirichlet_partition(train, NUM_CLIENTS, alpha=0.3, seed=0)
    clients = [ClientDataset(train.subset(ix)) for ix in parts]
    calib = make_calibration_batch(train, 64, "gaussian")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, clients, test, calib, params


def _digest_close(a, b, tol):
    a, b = np.asarray(a), np.asarray(b)
    assert a.shape == b.shape, (a.shape, b.shape)
    if tol == 0.0:
        np.testing.assert_array_equal(a, b)
    else:
        np.testing.assert_allclose(a, b, rtol=tol, atol=tol * 10)


def _run_solo(world, alg, sim_kw, seed, init_seed=None, hyper=None, **kw):
    cfg, clients, test, calib, params = world
    if init_seed is not None:
        params = M.init_params(jax.random.PRNGKey(init_seed), cfg)
    sim = SimConfig(record_trajectory=True, seed=seed, **sim_kw)
    if alg == "fedpsa":
        kw.setdefault("psa_cfg", PSAConfig(queue_len=8))
        kw.setdefault("calib_batch", calib)
    if hyper:
        kw.setdefault("server_kwargs", {}).update(
            {k: v for k, v in hyper.items()})
    return run_algorithm(alg, cfg, params, clients, test, sim, **kw)


# ---------------------------------------------------------------------------
# Lane parity: lane k of a sweep == the standalone run it encodes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alg,hyper", [
    ("fedbuff", {"server_lr": 0.7}),       # ring policy: bit-exact lanes
    ("fedfa", {"beta": 0.8}),              # ring policy: bit-exact lanes
    ("fedasync", {"alpha": 0.35}),
])
def test_lane_matches_standalone(world, alg, hyper):
    """Each lane of a 3-lane sweep (default / hyper-varied / reshuffled)
    reproduces the standalone run with the same timeline seed and that
    lane's data seed + hyper overrides."""
    cfg, clients, test, calib, params = world
    tseed = 0
    lanes = [dict(data_seed=0, hyper=None),
             dict(data_seed=0, hyper=hyper),
             dict(data_seed=11, hyper=None)]
    sweep = SweepConfig(data_seeds=[l["data_seed"] for l in lanes],
                        policy_params=[l["hyper"] for l in lanes])
    res = run_sweep(alg, cfg, params, clients, test,
                    SimConfig(record_trajectory=True, seed=tseed, **QUICK),
                    sweep)
    assert res.num_lanes == 3 and res.dispatches > 0
    for s, lane in enumerate(lanes):
        solo = _run_solo(
            world, alg, dict(QUICK, timeline_seed=tseed),
            seed=lane["data_seed"],
            **({"server_kwargs": dict(lane["hyper"])} if lane["hyper"]
               else {}))
        assert solo.dispatches == res.dispatches      # shared timeline
        assert solo.receive_log == res.receive_log
        _digest_close(res.digests[s], solo.digests, FLOAT_TOL)
        np.testing.assert_allclose(res.final_accuracy[s],
                                   solo.final_accuracy, atol=1e-5)
    # the varied lanes took genuinely different trajectories
    assert not np.allclose(res.digests[0], res.digests[1])
    assert not np.allclose(res.digests[0], res.digests[2])


def test_fedpsa_lane_parity_including_ablation_lane(world):
    """FedPSA lanes: per-lane gamma/delta AND a w/o-T ablation lane (the
    use_thermometer switch is a traced hyper leaf) each match their
    standalone equivalents."""
    cfg, clients, test, calib, params = world
    psa = PSAConfig(queue_len=8)
    sweep = SweepConfig(policy_params=[
        None, {"gamma": 0.5, "delta": 0.1}, {"use_thermometer": False}])
    res = run_sweep("fedpsa", cfg, params, clients, test,
                    SimConfig(record_trajectory=True, seed=0, **QUICK),
                    sweep, psa_cfg=psa, calib_batch=calib)
    solos = [
        _run_solo(world, "fedpsa", QUICK, seed=0, psa_cfg=psa,
                  calib_batch=calib),
        _run_solo(world, "fedpsa", QUICK, seed=0,
                  psa_cfg=PSAConfig(queue_len=8, gamma=0.5, delta=0.1),
                  calib_batch=calib),
        _run_solo(world, "fedpsa", QUICK, seed=0,
                  psa_cfg=PSAConfig(queue_len=8, use_thermometer=False),
                  calib_batch=calib),
    ]
    for s, solo in enumerate(solos):
        _digest_close(res.digests[s], solo.digests, FLOAT_TOL)


def test_model_seed_lanes(world):
    """model_seeds inits each lane's model independently; the lane matches
    the standalone run started from that init."""
    cfg, clients, test, calib, params = world
    sweep = SweepConfig(model_seeds=[0, 3])
    res = run_sweep("fedasync", cfg, params, clients, test,
                    SimConfig(record_trajectory=True, seed=0, **QUICK),
                    sweep)
    for s, init_seed in enumerate((0, 3)):
        solo = _run_solo(world, "fedasync", QUICK, seed=0,
                         init_seed=init_seed)
        _digest_close(res.digests[s], solo.digests, FLOAT_TOL)
    assert not np.allclose(res.digests[0], res.digests[1])


# ---------------------------------------------------------------------------
# Permutation: lane order is irrelevant
# ---------------------------------------------------------------------------

def test_permuting_lanes_permutes_results(world):
    cfg, clients, test, calib, params = world
    seeds = [0, 5, 9]
    hypers = [None, {"alpha": 0.3}, {"alpha": 0.9}]
    perm = [2, 0, 1]
    sim = SimConfig(record_trajectory=True, seed=0, **QUICK)
    base = run_sweep("fedasync", cfg, params, clients, test, sim,
                     SweepConfig(data_seeds=seeds, policy_params=hypers))
    shuf = run_sweep("fedasync", cfg, params, clients, test, sim,
                     SweepConfig(data_seeds=[seeds[p] for p in perm],
                                 policy_params=[hypers[p] for p in perm]))
    assert base.times == shuf.times
    for s, p in enumerate(perm):
        _digest_close(shuf.digests[s], base.digests[p], FLOAT_TOL)
        np.testing.assert_allclose(shuf.final_accuracy[s],
                                   base.final_accuracy[p], atol=1e-6)
        np.testing.assert_allclose(shuf.lane_accuracies[s],
                                   base.lane_accuracies[p], atol=1e-6)


# ---------------------------------------------------------------------------
# Sweep surface: validation + SimResult views
# ---------------------------------------------------------------------------

def test_sweep_config_validation(world):
    cfg, clients, test, calib, params = world
    sim = SimConfig(seed=0, **QUICK)
    with pytest.raises(ValueError, match="lane counts"):
        SweepConfig(data_seeds=[0, 1], policy_params=[None]).resolve(0)
    with pytest.raises(ValueError, match="fedavg"):
        run_sweep("fedavg", cfg, params, clients, test, sim, SweepConfig())
    with pytest.raises(ValueError, match="buffer_size"):
        run_sweep("fedbuff", cfg, params, clients, test, sim,
                  SweepConfig(policy_params=[{"buffer_size": 9}]))
    with pytest.raises(ValueError, match="cohort"):
        run_sweep("fedasync", cfg, params, clients, test,
                  SimConfig(seed=0, engine="sequential", **QUICK),
                  SweepConfig())


def test_lane_view_is_a_sim_result(world):
    cfg, clients, test, calib, params = world
    res = run_sweep("fedbuff", cfg, params, clients, test,
                    SimConfig(record_trajectory=True, seed=0, **QUICK),
                    SweepConfig(data_seeds=[0, 4]))
    lane = res.lane(1)
    assert lane.final_accuracy == res.final_accuracy[1]
    assert lane.times == res.times
    assert lane.dispatches == res.dispatches
    assert 0.0 <= lane.aulc <= 1.0
    mean, std = res.accuracy_mean_std()
    np.testing.assert_allclose(mean, np.mean(res.final_accuracy))


# ---------------------------------------------------------------------------
# Checkpoint / resume (SimConfig.checkpoint_dir wiring)
# ---------------------------------------------------------------------------

def _prune_to_mid_run(ckdir, total_dispatches):
    """Drop snapshots at/after the run's end so ``resume=True`` (which picks
    the latest) restarts from a genuinely mid-run state."""
    import shutil
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckdir))
    mid = [s for s in steps if 0 < s < total_dispatches]
    assert mid, steps
    for s in steps:
        if s > mid[-1]:
            shutil.rmtree(os.path.join(ckdir, f"step_{s:08d}"))
    return mid

@pytest.mark.parametrize("engine", ("cohort", "sequential"))
def test_checkpoint_resume_reproduces_digest_stream(world, engine, tmp_path):
    """A run checkpointed mid-flight, then restarted with ``resume=True``
    from its latest snapshot, reproduces the uninterrupted run's remaining
    digest stream and final metrics exactly."""
    cfg, clients, test, calib, params = world
    kw = dict(QUICK, record_trajectory=True, seed=0, engine=engine)
    base = run_algorithm("fedbuff", cfg, params, clients, test,
                         SimConfig(**kw))
    ckdir = str(tmp_path / engine)
    # checkpointing must not perturb the run it snapshots
    ck = run_algorithm("fedbuff", cfg, params, clients, test,
                       SimConfig(checkpoint_dir=ckdir,
                                 checkpoint_every=1_000.0, **kw))
    np.testing.assert_array_equal(np.asarray(ck.digests),
                                  np.asarray(base.digests))
    from repro.checkpoint import store
    steps = _prune_to_mid_run(ckdir, base.dispatches)
    assert len(steps) >= 2, steps
    assert 0 < store.latest_step(ckdir) < base.dispatches  # genuinely mid-run
    res = run_algorithm("fedbuff", cfg, params, clients, test,
                        SimConfig(checkpoint_dir=ckdir,
                                  checkpoint_every=1_000.0, resume=True,
                                  **kw))
    np.testing.assert_array_equal(np.asarray(res.digests),
                                  np.asarray(base.digests))
    assert res.dispatches == base.dispatches
    assert res.launched == base.launched
    assert res.times == base.times
    assert res.receive_log == base.receive_log   # incl. pre-resume entries
    np.testing.assert_allclose(res.accuracies, base.accuracies, atol=1e-6)
    np.testing.assert_allclose(res.final_accuracy, base.final_accuracy,
                               atol=1e-6)


def test_checkpoint_resume_fedpsa_state(world, tmp_path):
    """FedPSA's full sub-state (ring buffer, kappas, thermometer queue,
    global sketch) survives the round-trip: the resumed trajectory equals
    the uninterrupted one."""
    cfg, clients, test, calib, params = world
    psa = PSAConfig(queue_len=8)
    kw = dict(QUICK, record_trajectory=True, seed=0)
    base = run_algorithm("fedpsa", cfg, params, clients, test,
                         SimConfig(**kw), psa_cfg=psa, calib_batch=calib)
    ckdir = str(tmp_path / "psa")
    run_algorithm("fedpsa", cfg, params, clients, test,
                  SimConfig(checkpoint_dir=ckdir, checkpoint_every=1_200.0,
                            **kw), psa_cfg=psa, calib_batch=calib)
    _prune_to_mid_run(ckdir, base.dispatches)
    res = run_algorithm("fedpsa", cfg, params, clients, test,
                        SimConfig(checkpoint_dir=ckdir,
                                  checkpoint_every=1_200.0, resume=True,
                                  **kw), psa_cfg=psa, calib_batch=calib)
    np.testing.assert_allclose(np.asarray(res.digests),
                               np.asarray(base.digests), rtol=1e-6,
                               atol=1e-5)
    assert res.dispatches == base.dispatches


# ---------------------------------------------------------------------------
# Fuzzed lane parity (hypothesis tier)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=4, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.function_scoped_fixture])
    @given(
        tseed=st.integers(0, 3),
        data_seeds=st.lists(st.integers(0, 50), min_size=2, max_size=4),
        lane=st.integers(0, 3),
        alpha=st.floats(0.2, 0.9),
    )
    def test_fuzzed_lane_parity(world, tseed, data_seeds, lane, alpha):
        """Any lane of any (timeline seed x data seeds x alpha grid) sweep
        equals its standalone run: digest streams at FLOAT_TOL, shared
        counters exactly."""
        cfg, clients, test, calib, params = world
        lane = lane % len(data_seeds)
        hypers = [None] + [{"alpha": round(alpha, 3)}] * (len(data_seeds) - 1)
        sweep = SweepConfig(data_seeds=data_seeds, policy_params=hypers)
        res = run_sweep(
            "fedasync", cfg, params, clients, test,
            SimConfig(record_trajectory=True, seed=tseed, **QUICK), sweep)
        solo = _run_solo(
            world, "fedasync", dict(QUICK, timeline_seed=tseed),
            seed=data_seeds[lane],
            **({"server_kwargs": dict(hypers[lane])} if hypers[lane]
               else {}))
        assert solo.dispatches == res.dispatches
        _digest_close(res.digests[lane], solo.digests, FLOAT_TOL)
