"""Pallas TPU kernels for FedPSA's compute hot-spots.

* sens_sketch      — fused Eq. 8 sensitivity + on-the-fly Rademacher sketch
* buffer_agg       — Eq. 20 buffered weighted-sum apply
* flash_attention  — online-softmax attention forward (VMEM-resident state;
                     the §Perf answer to HBM-resident probability blocks)
* grouped_matmul   — grouped member-GEMM over the stacked cohort axis (one
                     wave of heterogeneous members' dense layers = one kernel)

Each kernel ships with a jit'd wrapper (ops.py) and a pure-jnp oracle
(ref.py); on CPU they run in interpret mode.
"""
from repro.kernels import ops, ref
from repro.kernels.sens_sketch import sens_sketch_pallas
from repro.kernels.buffer_agg import buffer_agg_pallas
from repro.kernels.flash_attention import flash_attention
from repro.kernels.grouped_matmul import grouped_matmul_pallas
