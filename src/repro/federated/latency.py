"""Client response-time distributions (paper §6.2 system heterogeneity).

Uniform(lo, hi) and a long-tail distribution over the same support (most
clients near ``lo``, a heavy tail toward ``hi`` — the paper notes long-tail
response times cluster around the minimum).
"""
from __future__ import annotations

import numpy as np


def make_latency_sampler(kind: str, lo: float, hi: float, seed: int = 0):
    rng = np.random.RandomState(seed)
    if kind == "uniform":
        def sample():
            return float(rng.uniform(lo, hi))
    elif kind == "longtail":
        # Pareto-shaped: mass near lo, tail to hi
        def sample():
            x = (np.power(1.0 - rng.rand(), -1.0 / 1.5) - 1.0)  # pareto(1.5)
            return float(np.clip(lo * (1.0 + x), lo, hi))
    else:
        raise ValueError(f"unknown latency kind {kind!r}")
    return sample


def per_client_latency(kind: str, lo: float, hi: float, num_clients: int,
                       seed: int = 0):
    """Fixed mean latency per client + per-dispatch jitter, as in FLGO:
    heterogeneity lives across clients, not only across dispatches."""
    rng = np.random.RandomState(seed)
    sampler = make_latency_sampler(kind, lo, hi, seed)
    means = np.array([sampler() for _ in range(num_clients)])

    def sample(client_id: int) -> float:
        jitter = rng.uniform(0.9, 1.1)
        return float(np.clip(means[client_id] * jitter, lo, hi))

    return sample, means
