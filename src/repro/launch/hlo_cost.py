"""Trip-count-aware cost analysis of optimized (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
but jax lowers ``lax.scan`` to while loops — a 126-layer scan or a 64-chunk
attention scan is undercounted by its trip count, which would corrupt every
roofline term. This analyzer walks the computation graph recursively and
multiplies loop bodies by their trip counts (XLA records them in the while
op's ``backend_config known_trip_count``; fallback: the loop-condition
compare constant).

Accounting model (per-device — the module is the SPMD per-device program):
* flops — dot: 2 * prod(result dims) * prod(lhs contracting dims);
          elementwise arithmetic / reduce: one flop per element (counted
          inside fusion bodies too).
* bytes — HBM-traffic approximation: operand + result bytes at FUSION
          BOUNDARIES (fusion calls, dots, convolutions, copies, collectives,
          data-movement ops at top level). Ops inside fusion bodies
          contribute flops only — the "a fusion reads its inputs once and
          writes its output once" TPU model.
* ici_bytes — ring-model estimate per collective (group size parsed from
          replica_groups), multiplied through loop trip counts.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "f16": 2, "bf16": 2, "s16": 2, "u16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1}

_ARRAY_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "sqrt", "rsqrt", "power", "compare", "select", "and", "or", "xor",
    "not", "sign", "floor", "ceil", "round-nearest-afz", "clamp", "atan2",
    "cosine", "sine", "logistic", "remainder", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "convert", "erf",
}
_TRANSCENDENTAL = {"exponential", "log", "tanh", "sqrt", "rsqrt", "power",
                   "logistic", "cosine", "sine", "erf", "atan2"}
COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute"}
_BYTES_AT_TOP = {"copy", "transpose", "dynamic-slice", "dynamic-update-slice",
                 "gather", "scatter", "concatenate", "slice", "pad", "reverse",
                 "broadcast", "iota", "sort", "select-and-scatter",
                 "reduce-window", "rng", "cholesky", "triangular-solve"}


def _shape_elems_bytes(text: str) -> Tuple[int, int]:
    elems, nbytes = 0, 0
    for dt, dims in _ARRAY_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclass
class Instr:
    name: str
    opcode: str
    result_txt: str
    operands_txt: str
    attrs: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # name -> result_txt


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    ici_bytes: float = 0.0
    transcendentals: float = 0.0
    collectives: Dict[str, Dict[str, float]] = field(default_factory=dict)
    unparsed_loops: int = 0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.ici_bytes += other.ici_bytes * mult
        self.transcendentals += other.transcendentals * mult
        self.unparsed_loops += other.unparsed_loops
        for op, s in other.collectives.items():
            t = self.collectives.setdefault(
                op, {"count": 0.0, "out_bytes": 0.0, "ici_bytes": 0.0})
            for k in t:
                t[k] += s[k] * mult


# `%name = <shape> <opcode>(operands)attrs` — shape may be a tuple with spaces;
# the opcode is the last bare token before the '(' of the operand list.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^{]*\))?\s*->.*\{\s*$")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_GROUPS_PAIR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_RE = re.compile(r"constant\((-?\d+)\)")
_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        ls = raw.strip()
        if cur is None:
            if ls.endswith("{") and "->" in ls:
                m = _COMP_RE.match(ls)
                if m:
                    cur = Computation(m.group(1))
                    if ls.startswith("ENTRY"):
                        entry = cur.name
            continue
        if ls == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(ls)
        if not m:
            continue
        name, result_txt, opcode, rest = m.groups()
        depth = 1
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands, attrs = rest[:end], rest[end + 1:]
        ins = Instr(name=name, opcode=opcode, result_txt=result_txt,
                    operands_txt=operands, attrs=attrs)
        cur.instrs.append(ins)
        cur.shapes[name] = result_txt
    return comps, entry


def _trip_count_from_cond(cond: Computation) -> Optional[int]:
    consts = {i.name: int(m.group(1)) for i in cond.instrs
              if i.opcode == "constant" and (m := _CONST_RE.search(i.operands_txt + i.attrs)
                                             or re.match(r"(-?\d+)", i.operands_txt))}
    # constants feeding a compare (possibly via a wrapper fusion)
    vals = [v for v in consts.values() if v > 0]
    return max(vals) if vals else None


def _group_size(attrs: str, world: int) -> int:
    m = _GROUPS_PAIR_RE.search(attrs)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(attrs)
    if m:
        g = m.group(1).strip()
        return len(g.split(",")) if g else world
    return world


def _collective_ici(op: str, out_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-gather":
        return out_bytes * (g - 1) / g
    if op == "all-reduce":
        return 2.0 * out_bytes * (g - 1) / g
    if op == "reduce-scatter":
        return float(out_bytes) * (g - 1)
    if op == "all-to-all":
        return out_bytes * (g - 1) / g
    return float(out_bytes)  # collective-permute


class HloCostAnalyzer:
    def __init__(self, text: str, world: int):
        self.comps, self.entry = parse_hlo(text)
        self.world = world
        self._memo: Dict[Tuple[str, bool], Cost] = {}

    def cost(self) -> Cost:
        assert self.entry, "no ENTRY computation found"
        return self.comp_cost(self.entry, False)

    def comp_cost(self, name: str, fusion_ctx: bool) -> Cost:
        key = (name, fusion_ctx)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = Cost()  # cycle guard
        comp = self.comps.get(name)
        total = Cost()
        if comp is not None:
            for ins in comp.instrs:
                total.add(self.instr_cost(comp, ins, fusion_ctx))
        self._memo[key] = total
        return total

    def _operand_bytes(self, comp: Computation, ins: Instr) -> Tuple[int, int]:
        elems, nbytes = 0, 0
        for name in _OPERAND_NAME_RE.findall(ins.operands_txt):
            shape_txt = comp.shapes.get(name)
            if shape_txt:
                e, b = _shape_elems_bytes(shape_txt)
                elems += e
                nbytes += b
        return elems, nbytes

    def instr_cost(self, comp: Computation, ins: Instr, fusion_ctx: bool) -> Cost:
        c = Cost()
        op = ins.opcode
        res_elems, res_bytes = _shape_elems_bytes(ins.result_txt)

        if op == "while":
            mb = _BODY_RE.search(ins.attrs)
            if mb:
                body = self.comp_cost(mb.group(1), False)
                trips = None
                mt = _TRIP_RE.search(ins.attrs)
                if mt:
                    trips = int(mt.group(1))
                else:
                    mc = _COND_RE.search(ins.attrs)
                    if mc and mc.group(1) in self.comps:
                        trips = _trip_count_from_cond(self.comps[mc.group(1)])
                if trips is None:
                    trips = 1
                    c.unparsed_loops += 1
                c.add(body, trips)
            return c

        if op in ("call", "conditional", "custom-call"):
            m = _CALLS_RE.search(ins.attrs)
            if m:
                c.add(self.comp_cost(m.group(1), fusion_ctx))
            return c

        if op == "fusion":
            m = _CALLS_RE.search(ins.attrs)
            called = m.group(1) if m else None
            if called:
                c.add(self.comp_cost(called, True))
            if not fusion_ctx:
                c.bytes += self._fusion_io_bytes(comp, ins, called, res_bytes)
            return c

        if op == "dot":
            contract_elems = 1
            m = _DOT_CONTRACT_RE.search(ins.attrs)
            lhs_names = _OPERAND_NAME_RE.findall(ins.operands_txt)
            if m and lhs_names:
                lhs_shape = comp.shapes.get(lhs_names[0], "")
                sm = _ARRAY_RE.search(lhs_shape)
                if sm:
                    sizes = [int(x) for x in sm.group(2).split(",") if x]
                    for d in (int(x) for x in m.group(1).split(",") if x):
                        if d < len(sizes):
                            contract_elems *= sizes[d]
            c.flops += 2.0 * res_elems * contract_elems
            if not fusion_ctx:
                _, opd_bytes = self._operand_bytes(comp, ins)
                c.bytes += opd_bytes + res_bytes
            return c

        if op == "convolution":
            opd_elems, opd_bytes = self._operand_bytes(comp, ins)
            c.flops += 2.0 * res_elems * max(opd_elems // max(res_elems, 1), 1)
            if not fusion_ctx:
                c.bytes += opd_bytes + res_bytes
            return c

        base = op[:-6] if op.endswith("-start") else op
        if base in COLLECTIVES:
            g = _group_size(ins.attrs, self.world)
            ici = _collective_ici(base, res_bytes, g)
            c.ici_bytes += ici
            s = c.collectives.setdefault(
                base, {"count": 0.0, "out_bytes": 0.0, "ici_bytes": 0.0})
            s["count"] += 1
            s["out_bytes"] += res_bytes
            s["ici_bytes"] += ici
            if not fusion_ctx:
                c.bytes += res_bytes
            return c

        if op in ELEMENTWISE:
            c.flops += res_elems
            if op in _TRANSCENDENTAL:
                c.transcendentals += res_elems
            return c

        if op in ("reduce", "reduce-window"):
            opd_elems, opd_bytes = self._operand_bytes(comp, ins)
            c.flops += opd_elems
            if not fusion_ctx:
                c.bytes += opd_bytes + res_bytes
            return c

        if op == "dynamic-slice":
            # reads only the slice (result), not the sliced buffer
            if not fusion_ctx:
                c.bytes += 2 * res_bytes
            return c

        if op == "dynamic-update-slice":
            # in-place: read-modify-write of the update slice only
            names = _OPERAND_NAME_RE.findall(ins.operands_txt)
            upd_bytes = 0
            if len(names) >= 2:
                _, upd_bytes = _shape_elems_bytes(comp.shapes.get(names[1], ""))
            if not fusion_ctx:
                c.bytes += 2 * upd_bytes
            return c

        if not fusion_ctx and op in _BYTES_AT_TOP:
            _, opd_bytes = self._operand_bytes(comp, ins)
            c.bytes += opd_bytes + res_bytes
            return c

        return c

    def _fusion_io_bytes(self, comp: Computation, ins: Instr,
                         called: Optional[str], res_bytes: int) -> float:
        """HBM traffic of one fusion call, with in-place slice semantics.

        A fusion parameter consumed ONLY as the sliced buffer of
        dynamic-slice ops contributes the slice bytes (not the whole
        buffer); a parameter used as the in-place target of a
        dynamic-update-slice contributes nothing for the read and the
        update bytes for the write (the result aliases it). This is what
        makes per-iteration scan input reads / output writes count as
        slice-sized instead of stacked-buffer-sized.
        """
        opd_names = _OPERAND_NAME_RE.findall(ins.operands_txt)
        cc = self.comps.get(called) if called else None
        if cc is None:
            _, opd_bytes = self._operand_bytes(comp, ins)
            return float(opd_bytes + res_bytes)

        # parameter name -> operand position
        param_pos: Dict[str, int] = {}
        for i2 in cc.instrs:
            if i2.opcode == "parameter":
                mnum = re.match(r"\s*(\d+)", i2.operands_txt)
                if mnum:
                    param_pos[i2.name] = int(mnum.group(1))

        # classify each parameter
        slice_bytes: Dict[int, int] = {}     # param pos -> effective read bytes
        aliased_out: Dict[int, int] = {}     # param pos -> write bytes (DUS)
        uses: Dict[str, List[Instr]] = {p: [] for p in param_pos}
        for i2 in cc.instrs:
            for nm in _OPERAND_NAME_RE.findall(i2.operands_txt):
                if nm in uses:
                    uses[nm].append(i2)
        for pname, plist in uses.items():
            pos = param_pos[pname]
            if not plist:
                slice_bytes[pos] = 0
                continue
            if all(u.opcode == "dynamic-slice"
                   and _OPERAND_NAME_RE.findall(u.operands_txt)[:1] == [pname]
                   for u in plist):
                _, b = _shape_elems_bytes(plist[0].result_txt)
                slice_bytes[pos] = b * len(plist)
            elif all(u.opcode == "dynamic-update-slice"
                     and _OPERAND_NAME_RE.findall(u.operands_txt)[:1] == [pname]
                     for u in plist):
                wb = 0
                for u in plist:
                    ops2 = _OPERAND_NAME_RE.findall(u.operands_txt)
                    if len(ops2) >= 2:
                        _, ub = _shape_elems_bytes(cc.shapes.get(ops2[1], ""))
                        wb += ub
                slice_bytes[pos] = 0       # buffer itself is not streamed
                aliased_out[pos] = wb

        total = 0.0
        for pos, nm in enumerate(opd_names):
            if pos in slice_bytes:
                total += slice_bytes[pos]
            else:
                _, b = _shape_elems_bytes(comp.shapes.get(nm, ""))
                total += b
        if aliased_out:
            total += 2.0 * sum(aliased_out.values())  # RMW of the slices
        else:
            total += res_bytes
        return total


def profile_instrs(text: str, world: int, top: int = 20):
    """Per-instruction (bytes, flops, ici) attribution including loop-nest
    multipliers — the dry-run 'profiler' used by the §Perf iterations."""
    an = HloCostAnalyzer(text, world)
    mult: Dict[str, float] = {}

    def walk(cname: str, m: float):
        mult[cname] = mult.get(cname, 0.0) + m
        comp = an.comps.get(cname)
        if comp is None:
            return
        for ins in comp.instrs:
            if ins.opcode == "while":
                mb = _BODY_RE.search(ins.attrs)
                mt = _TRIP_RE.search(ins.attrs)
                t = int(mt.group(1)) if mt else 1
                if mb:
                    walk(mb.group(1), m * t)
            elif ins.opcode in ("call", "conditional"):
                mc = _CALLS_RE.search(ins.attrs)
                if mc:
                    walk(mc.group(1), m)

    assert an.entry
    walk(an.entry, 1.0)
    rows = []
    for cname, m in mult.items():
        comp = an.comps[cname]
        for ins in comp.instrs:
            if ins.opcode in ("while", "call", "conditional"):
                continue  # children already attributed via walk
            c = an.instr_cost(comp, ins, False)
            if c.bytes or c.flops or c.ici_bytes:
                rows.append({
                    "bytes": c.bytes * m, "flops": c.flops * m,
                    "ici": c.ici_bytes * m, "op": ins.opcode,
                    "comp": cname, "name": ins.name,
                    "result": ins.result_txt[:60], "mult": m,
                })
    rows.sort(key=lambda r: -(r["bytes"] + r["ici"] * 16))
    return rows[:top]


def analyze(text: str, world: int) -> dict:
    a = HloCostAnalyzer(text, world)
    c = a.cost()
    return {
        "flops_per_device": c.flops,
        "bytes_per_device": c.bytes,
        "ici_bytes_per_device": c.ici_bytes,
        "transcendentals": c.transcendentals,
        "collectives": c.collectives,
        "unparsed_loops": c.unparsed_loops,
    }
