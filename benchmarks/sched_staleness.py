"""Scheduler x staleness-metric operating points (ROADMAP research surface).

The staleness-vs-update-frequency study: every combination of dispatch
scheduler (``federated.scheduler.SCHEDULERS``), asyncfeded distance metric
(``core.psa.DISTANCE_METRICS``), concurrency and tolerance (mixing alpha)
gets an AULC cell on the paper protocol (Dirichlet hardest setting), each
backed by seed lanes, with FedPSA as the per-(scheduler, concurrency)
baseline to beat.

Cost model: per (scheduler, concurrency) the whole metric x alpha x seed
grid for the traced metrics (l2/cosine — ``dist_mode`` is a lane
hyperparameter) runs as ONE ``run_sweep`` over a shared timeline; the
sketch metric changes the compiled step (structural) and the FedPSA
baseline is a different policy, so each adds one more sweep. 3 sweeps per
(scheduler, concurrency) pair regardless of grid width.

Grid preset via ``SCHED_BENCH_PRESET`` (default ``sched-paper``;
``sched-smoke`` is the tier-1 CI cell). Output:
``artifacts/bench/BENCH_sched_staleness.json``.
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

from benchmarks import common
from repro.configs import get_sched_preset
from repro.federated import SweepConfig

PRESET = os.environ.get("SCHED_BENCH_PRESET", "sched-paper")


def _lane_mean_aulc(res, lane_groups):
    """Mean AULC over each group of lane indices (NaN-safe: a short curve
    poisons its group to NaN, surfaced as null — never a fake 0.0)."""
    aulcs = res.aulc
    return {key: common.aulc_json(np.mean([aulcs[i] for i in idx]))
            for key, idx in lane_groups.items()}


def main(argv=None):
    p = get_sched_preset(PRESET)
    traced = [m for m in p.metrics if m != "sketch"]
    rows = {}
    detail = {}
    t_start = time.time()
    for sched in p.schedulers:
        for conc in p.concurrencies:
            tag = f"{sched}@c{conc}"
            sim = common.sim_config(concurrency=conc, scheduler=sched)

            if traced:
                lanes = [(m, a, s) for m in traced for a in p.alphas
                         for s in p.seeds]
                sweep = SweepConfig(
                    model_seeds=[s for _, _, s in lanes],
                    data_seeds=[s for _, _, s in lanes],
                    policy_params=[dict(alpha=a, dist_mode=m)
                                   for m, a, _ in lanes])
                res = common.sweep_cell("asyncfeded", p.dirichlet_alpha,
                                        sweep, sim=sim)
                groups = {}
                for i, (m, a, _) in enumerate(lanes):
                    groups.setdefault(f"{sched}/{m}@c{conc}/tol{a}",
                                      []).append(i)
                cell = _lane_mean_aulc(res, groups)
                rows.update(cell)
                detail[f"{tag}/traced"] = {
                    "lanes": [f"{m}/tol{a}/seed{s}" for m, a, s in lanes],
                    "aulc": [common.aulc_json(v) for v in res.aulc],
                    "launched": res.launched, "wall_s": res.wall_s}

            if "sketch" in p.metrics:
                lanes = [(a, s) for a in p.alphas for s in p.seeds]
                sweep = SweepConfig(
                    model_seeds=[s for _, s in lanes],
                    data_seeds=[s for _, s in lanes],
                    policy_params=[dict(alpha=a) for a, _ in lanes])
                res = common.sweep_cell("asyncfeded", p.dirichlet_alpha,
                                        sweep, sim=sim,
                                        server_kwargs=dict(metric="sketch"))
                groups = {}
                for i, (a, _) in enumerate(lanes):
                    groups.setdefault(f"{sched}/sketch@c{conc}/tol{a}",
                                      []).append(i)
                rows.update(_lane_mean_aulc(res, groups))
                detail[f"{tag}/sketch"] = {
                    "lanes": [f"sketch/tol{a}/seed{s}" for a, s in lanes],
                    "aulc": [common.aulc_json(v) for v in res.aulc],
                    "launched": res.launched, "wall_s": res.wall_s}

            # the baseline every combination is measured against
            sweep = SweepConfig(model_seeds=list(p.seeds),
                                data_seeds=list(p.seeds))
            res = common.sweep_cell("fedpsa", p.dirichlet_alpha, sweep,
                                    sim=sim)
            base_key = f"{sched}/fedpsa@c{conc}"
            rows[base_key] = common.aulc_json(np.mean(res.aulc))
            detail[f"{tag}/fedpsa"] = {
                "aulc": [common.aulc_json(v) for v in res.aulc],
                "launched": res.launched, "wall_s": res.wall_s}
            for k in sorted(cellk for cellk in rows
                            if cellk.startswith(f"{sched}/")
                            and f"@c{conc}" in cellk):
                print(f"sched,{k},{rows[k]}")

    # headline: the best operating point per scheduler vs FedPSA under the
    # same scheduler/concurrency (the ROADMAP deliverable question)
    summary = {}
    for sched in p.schedulers:
        pts = [(v, k) for k, v in rows.items()
               if k.startswith(f"{sched}/") and "fedpsa" not in k
               and v is not None]
        if not pts:
            continue
        best_v, best_k = max(pts)
        conc = best_k.split("@c")[1].split("/")[0]
        base = rows.get(f"{sched}/fedpsa@c{conc}")
        summary[sched] = {"best": best_k, "aulc": best_v,
                          "fedpsa_aulc": base,
                          "beats_fedpsa": (base is not None
                                           and best_v > base)}
        print(f"sched,best[{sched}],{best_k},{best_v},"
              f"beats_fedpsa={summary[sched]['beats_fedpsa']}")

    payload = {"preset": PRESET, "horizon": common.HORIZON,
               "grid": {"schedulers": list(p.schedulers),
                        "metrics": list(p.metrics),
                        "concurrencies": list(p.concurrencies),
                        "tolerances": list(p.alphas),
                        "seeds": list(p.seeds),
                        "dirichlet_alpha": p.dirichlet_alpha},
               "aulc": rows, "summary": summary, "detail": detail,
               "wall_s": time.time() - t_start}
    path = common.save("BENCH_sched_staleness", payload)
    print(f"sched,saved,{path},wall_s={payload['wall_s']:.1f}")
    return payload


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
