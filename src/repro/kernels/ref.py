"""Pure-jnp oracles for the Pallas kernels (bit-compatible hashing)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sk
from repro.core.sensitivity import sensitivity_from_parts


def sens_sketch_ref(theta, g, f, *, k: int = 16, seed: int = 0) -> jnp.ndarray:
    """Sensitivity (Eq. 8) of flat vectors followed by the hashed Rademacher
    projection — identical math to repro.core.sketch on a single flat leaf."""
    s = jnp.abs(g.astype(jnp.float32) * theta.astype(jnp.float32)
                - 0.5 * f.astype(jnp.float32) * jnp.square(theta.astype(jnp.float32)))
    lin = jnp.arange(s.shape[0], dtype=jnp.uint32)
    rows = [jnp.sum(s * sk.rademacher_row(jnp.uint32(seed), lin, r, k))
            for r in range(k)]
    return jnp.stack(rows) / np.sqrt(k)


def buffer_agg_ref(weights, global_vec, updates) -> jnp.ndarray:
    """global + sum_l w_l * updates_l in f32."""
    return global_vec.astype(jnp.float32) + jnp.einsum(
        "l,ld->d", weights.astype(jnp.float32), updates.astype(jnp.float32))


def grouped_matmul_ref(lhs, rhs, valid=None) -> jnp.ndarray:
    """lhs (G, M, K) @ rhs (G, K, N) -> (G, M, N), f32 accumulation, with
    the per-group validity mask zeroing padded member slots exactly."""
    out = jnp.einsum("gmk,gkn->gmn", lhs.astype(jnp.float32),
                     rhs.astype(jnp.float32))
    if valid is not None:
        out = out * valid.astype(jnp.float32)[:, None, None]
    return out.astype(jnp.promote_types(lhs.dtype, rhs.dtype))


def flash_attention_ref(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """Materialized-softmax GQA attention: q (B, Sq, H, hd), k/v
    (B, Sk, Hkv, hd) with H % Hkv == 0; returns (B, Sq, H, hd) in q.dtype,
    softmax math in f32 — the oracle for kernels.flash_attention."""
    B, Sq, H, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    assert H % Hkv == 0
    group = H // Hkv
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kf) / np.sqrt(hd)
    if causal:
        # same absolute-position rule as the kernel: key j attends to query
        # i iff j <= i (positions indexed from the start of each sequence)
        mask = jnp.tril(jnp.ones((Sq, Sk), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vf).astype(q.dtype)
