"""Paper Table 6: component ablation — w/o T (thermometer), w/o S
(sensitivity; raw-parameter sketch instead), w/o T&S, vs Full, under IID
(alpha=1 ~ the paper's IID) and non-IID (alpha=0.1), at concurrency p.
"""
from __future__ import annotations

import sys

from repro.core import PSAConfig
from benchmarks import common

VARIANTS = {
    "full": PSAConfig(),
    "wo_T": PSAConfig(use_thermometer=False),
    "wo_S": PSAConfig(use_sensitivity=False),
    "wo_TS": PSAConfig(use_thermometer=False, use_sensitivity=False),
}
CONCURRENCY_FULL = (0.1, 0.2, 0.3)
CONCURRENCY_FAST = (0.2,)


def main(argv=None):
    ps = CONCURRENCY_FULL if common.FULL else CONCURRENCY_FAST
    # the thermometer only differentiates once updates shrink (late stage):
    # the ablation needs a longer horizon than the accuracy tables
    horizon = common.HORIZON if common.FULL else 70_000.0
    rows = {}
    for alpha, tag in ((1.0, "iid"), (0.1, "niid")):
        for p in ps:
            for name, psa in VARIANTS.items():
                sim = common.sim_config(concurrency=p, horizon=horizon,
                                        eval_every=horizon / 5)
                res = common.run_cell("fedpsa", alpha, sim=sim, psa=psa)
                rows[f"{name}@{tag}_p{p}"] = res.final_accuracy
                print(f"t6,{name},{tag},p={p},{res.final_accuracy:.4f}")
    common.save("t6_ablation", rows)
    for p in ps:
        full_ = rows[f"full@niid_p{p}"]
        worst = min(rows[f"{v}@niid_p{p}"] for v in ("wo_T", "wo_S", "wo_TS"))
        print(f"t6,full_minus_worst_ablation_niid_p{p},{full_ - worst:+.4f}")
    return rows


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
