"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304. xLSTM blocks carry their own
up/down projections so there is no separate FFN (ffn_pattern "none"). The
mLSTM:sLSTM ratio follows the paper's mixed [x:1] configurations (here 3:1
tiled over 24 layers). Heads (4) do not divide the 16-way model axis, so the
sharding rules shard head_dim / ssm_inner instead (see launch.mesh.rules_for).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    ffn_pattern=("none", "none", "none", "none"),
    mlstm_proj_factor=2.0,
    slstm_ffn_factor=4.0 / 3.0,
    long_context_window=None,  # recurrent: O(1) state, no window needed
)
