"""Synthetic datasets (offline stand-ins for MNIST/FMNIST/CIFAR).

The paper's experiments need labelled classification data with controllable
class structure so that Dirichlet label-skew partitioning produces the same
heterogeneity protocol. We use an anisotropic Gaussian-mixture: one mean per
class on a random simplex, shared covariance, plus per-class rotation, which
gives a task that linear models solve partially and small MLPs/CNNs solve
well — enough dynamic range to reproduce the paper's *orderings*.

``make_lm_corpus`` generates token streams from a sparse random bigram
chain, giving a learnable non-uniform LM task for the pretrain example.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np


@dataclass
class SyntheticClassification:
    x: np.ndarray       # (N, ...) float32
    y: np.ndarray       # (N,) int64
    num_classes: int

    def __len__(self):
        return self.x.shape[0]

    def subset(self, idx) -> "SyntheticClassification":
        return SyntheticClassification(self.x[idx], self.y[idx], self.num_classes)


def make_classification(num_samples: int = 10_000, num_classes: int = 10,
                        dim: int = 32, *, image_hw=None, seed: int = 0,
                        class_sep: float = 1.8,
                        noise: float = 1.0) -> SyntheticClassification:
    """Gaussian mixture. ``image_hw=(H, W, C)`` reshapes features to images
    (for the CNN family); dim is then H*W*C."""
    rng = np.random.RandomState(seed)
    if image_hw is not None:
        dim = int(np.prod(image_hw))
    means = rng.randn(num_classes, dim).astype(np.float32)
    means *= class_sep / np.linalg.norm(means, axis=1, keepdims=True)
    y = rng.randint(0, num_classes, size=num_samples)
    x = means[y] + noise * rng.randn(num_samples, dim).astype(np.float32) / np.sqrt(dim) * np.sqrt(dim) * 0.3
    # mild class-dependent rotation so the task is not purely linear
    w = rng.randn(num_classes, dim, 8).astype(np.float32) / np.sqrt(dim)
    feats = np.einsum("nd,ndk->nk", x, w[y])
    x[:, :8] += 0.5 * np.tanh(feats)
    x = x.astype(np.float32)
    if image_hw is not None:
        x = x.reshape((num_samples,) + tuple(image_hw))
    return SyntheticClassification(x, y.astype(np.int64), num_classes)


def train_test_split(ds: SyntheticClassification, test_frac: float = 0.1,
                     seed: int = 7):
    """Paper protocol: 10% test split, remainder training."""
    rng = np.random.RandomState(seed)
    idx = rng.permutation(len(ds))
    n_test = int(len(ds) * test_frac)
    return ds.subset(idx[n_test:]), ds.subset(idx[:n_test])


def make_lm_corpus(num_tokens: int = 2_000_000, vocab: int = 512,
                   seed: int = 0, branching: int = 8) -> np.ndarray:
    """Sparse random bigram chain: each token has ``branching`` likely
    successors — cross-entropy floor ~ log(branching) < log(vocab)."""
    rng = np.random.RandomState(seed)
    succ = rng.randint(0, vocab, size=(vocab, branching))
    probs = rng.dirichlet(np.ones(branching) * 0.5, size=vocab)
    out = np.empty(num_tokens, np.int32)
    t = rng.randint(vocab)
    for i in range(num_tokens):
        out[i] = t
        t = succ[t, rng.choice(branching, p=probs[t])]
    return out
