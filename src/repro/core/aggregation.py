"""Buffer aggregation rules: FedPSA's temperature softmax (Eq. 19-20) and
the time-based staleness weightings used by the asynchronous baselines."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.common import tree as tu
from repro.kernels.buffer_agg import buffer_agg_pallas, resolve_interpret


def psa_weights(kappas: jnp.ndarray, temp: jnp.ndarray) -> jnp.ndarray:
    """Eq. 19: Weight_i = softmax(kappa_i / Temp) over the buffer."""
    temp = jnp.maximum(temp, 1e-6)
    return jax.nn.softmax(kappas.astype(jnp.float32) / temp)


def uniform_weights(n: int) -> jnp.ndarray:
    return jnp.full((n,), 1.0 / n, jnp.float32)


def aggregate_buffer(global_params, updates: Sequence, weights: jnp.ndarray,
                     server_lr: float = 1.0):
    """Eq. 20 over pytrees: w_g <- w_g + sum_i Weight_i * dw_i."""
    delta = tu.tree_weighted_sum(list(updates), weights * server_lr)
    return tu.tree_add(global_params, delta)


def aggregate_flat(global_vec: jnp.ndarray, updates: jnp.ndarray,
                   weights: jnp.ndarray, server_lr: float = 1.0) -> jnp.ndarray:
    """Eq. 20 over the flat layout: updates stacked (L, d), global (d,).

    On TPU this routes through the compiled Pallas buffer_agg kernel (one
    streaming pass, no (L x d) temporary); off-TPU the mathematically
    identical jnp contraction is cheaper than interpreting the kernel."""
    w = weights.astype(jnp.float32) * server_lr
    g = global_vec.astype(jnp.float32)
    if resolve_interpret(None):  # non-TPU backend
        return g + jnp.einsum("l,ld->d", w, updates.astype(jnp.float32))
    return buffer_agg_pallas(w, g, updates)


# ---------------------------------------------------------------------------
# Time-based staleness functions (baselines; FedAsync Sec. 5 of [14])
# ---------------------------------------------------------------------------

def staleness_constant(tau, alpha: float = 0.6):
    return jnp.full_like(jnp.asarray(tau, jnp.float32), alpha)


def staleness_polynomial(tau, alpha: float = 0.6, a: float = 0.5):
    """alpha * (1 + tau)^-a — the paper's traditional 1/sqrt(tau+1) curve."""
    tau = jnp.asarray(tau, jnp.float32)
    return alpha * jnp.power(1.0 + tau, -a)


def staleness_hinge(tau, alpha: float = 0.6, a: float = 10.0, b: float = 4.0):
    tau = jnp.asarray(tau, jnp.float32)
    return jnp.where(tau <= b, alpha, alpha / (a * (tau - b) + 1.0))
