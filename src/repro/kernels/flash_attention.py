"""Flash attention forward Pallas TPU kernel (GQA, causal / bidirectional).

The §Perf analysis showed the jax-native chunked attention materializes every
(q_chunk x kv_chunk) probability block to HBM (the single largest memory-term
item on llama3/internvl2/phi4 train shapes). This kernel keeps the running
(max, sum, accumulator) online-softmax state in VMEM scratch across the KV
grid dimension, so HBM traffic is exactly one read of Q/K/V and one write of
O — the TPU-native answer (DESIGN.md §3 hardware adaptation).

Tiling: grid (B*H, nq, nk), nk innermost; BlockSpecs give (block_q, head_dim)
Q/O tiles and (block_k, head_dim) K/V tiles in VMEM. GQA is handled in the
K/V index maps (head h reads kv-head h // group) — no repeated KV in HBM.
Block shapes default to multiples of (8, 128) for MXU alignment.

Validated in interpret mode against the pure-jnp oracle (ref.py) across
shapes / dtypes / GQA ratios / masks; see tests/test_flash_attention.py.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.buffer_agg import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, scale: float, block_q: int, block_k: int,
                  seq_k: int, num_k_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # (bq, hd)
    k = k_ref[0].astype(jnp.float32)          # (bk, hd)
    v = v_ref[0].astype(jnp.float32)          # (bk, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < seq_k                       # K padding
    if causal:
        mask &= k_pos <= q_pos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(p, v)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[...][:, None], 1e-30)
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """q: (B, Sq, H, hd); k, v: (B, Sk, Hkv, hd) with H % Hkv == 0.

    Returns (B, Sq, H, hd) in q.dtype; softmax math in f32.
    """
    interpret = resolve_interpret(interpret)
    B, Sq, H, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    assert H % Hkv == 0
    G = H // Hkv
    scale = 1.0 / math.sqrt(hd)

    block_q = min(block_q, max(Sq, 1))
    block_k = min(block_k, max(Sk, 1))
    nq = -(-Sq // block_q)
    nk = -(-Sk // block_k)
    Sq_p, Sk_p = nq * block_q, nk * block_k

    # (B*H, S, hd) layout; K/V keep their kv-heads (GQA via index maps)
    qh = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, hd)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * Hkv, Sk, hd)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * Hkv, Sk, hd)
    if Sq_p != Sq:
        qh = jnp.pad(qh, ((0, 0), (0, Sq_p - Sq), (0, 0)))
    if Sk_p != Sk:
        kh = jnp.pad(kh, ((0, 0), (0, Sk_p - Sk), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, Sk_p - Sk), (0, 0)))

    def q_index(h, qi, ki):
        return (h, qi, 0)

    def kv_index(h, qi, ki):
        return (h // G, ki, 0)  # GQA: query head h reads kv head h // G

    kernel = functools.partial(
        _flash_kernel, causal=causal, scale=scale, block_q=block_q,
        block_k=block_k, seq_k=Sk, num_k_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), q_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), q_index),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq_p, hd), q.dtype),
        scratch_shapes=[
            # VMEM-resident online-softmax state, carried across the nk axis
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)

    out = out[:, :Sq].reshape(B, H, Sq, hd)
    return jnp.moveaxis(out, 1, 2)
