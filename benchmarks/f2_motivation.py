"""Paper §4 / Figs. 1-2: behavioral vs round-gap staleness.

During a FedPSA run we record (tau_i, kappa_i) for every received update and
compare the induced weighting signal against the traditional 1/sqrt(tau+1)
curve. Properties validated (paper's motivation bullets):

1. Distribution awareness — at FIXED tau, kappa varies with the uploading
   client's data skew (round-gap weighting cannot: its weight is a constant
   per tau). Measured as the mean within-tau spread of kappa.
2. Saturation — mean kappa flattens for large tau instead of decaying
   unboundedly like 1/sqrt(tau+1).
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import PSAConfig, cosine, staleness_polynomial
from repro.federated import run_algorithm
from benchmarks import common


def main(argv=None):
    cfg, clients, test, calib, params = common.world(0.1)
    psa = PSAConfig()
    pairs = []

    def hook(server, w_client, delta, meta, t):
        kappa = float(cosine(meta["sketch"], server.psa.global_sketch))
        pairs.append((meta["tau"], kappa, t))

    run_algorithm("fedpsa", cfg, params, clients, test, common.sim_config(),
                  psa_cfg=psa, calib_batch=calib["gaussian"],
                  receive_hook=hook)

    taus = np.array([p[0] for p in pairs])
    kappas = np.array([p[1] for p in pairs])
    times = np.array([p[2] for p in pairs])

    # per-tau statistics
    rows = {"n": len(pairs)}
    uniq = [t for t in sorted(set(taus)) if (taus == t).sum() >= 5]
    mean_k = {int(t): float(kappas[taus == t].mean()) for t in uniq}
    std_k = {int(t): float(kappas[taus == t].std()) for t in uniq}
    rows["mean_kappa_by_tau"] = mean_k
    rows["std_kappa_by_tau"] = std_k
    for t in uniq[:8]:
        trad = float(staleness_polynomial(t, 1.0))
        print(f"f2,tau={t},mean_kappa={mean_k[t]:.4f},std_kappa={std_k[t]:.4f},"
              f"traditional={trad:.4f}")

    # 1. distribution awareness: same-tau spread is meaningfully nonzero
    spread = float(np.mean(list(std_k.values())))
    rows["within_tau_kappa_spread"] = spread
    print(f"f2,within_tau_kappa_spread,{spread:.4f}")
    print(f"f2,claim_distribution_awareness,{spread > 0.01}")

    # 2. saturation: kappa decay from small to large tau is much flatter
    # than the 1/sqrt curve's decay over the same range
    if len(uniq) >= 3:
        t_lo, t_hi = uniq[0], uniq[-1]
        kappa_drop = mean_k[t_lo] - mean_k[t_hi]
        trad_drop = float(staleness_polynomial(t_lo, 1.0)
                          - staleness_polynomial(t_hi, 1.0))
        rows["kappa_drop"] = kappa_drop
        rows["traditional_drop"] = trad_drop
        print(f"f2,kappa_drop_over_tau,{kappa_drop:.4f}")
        print(f"f2,traditional_drop_over_tau,{trad_drop:.4f}")
        print(f"f2,claim_saturation,{abs(kappa_drop) < trad_drop}")

    # 3. stage awareness: at fixed tau, kappa differs early vs late in training
    med_t = np.median(times)
    for t in uniq[:3]:
        sel = taus == t
        early = kappas[sel & (times < med_t)]
        late = kappas[sel & (times >= med_t)]
        if len(early) >= 3 and len(late) >= 3:
            rows[f"stage_gap_tau{int(t)}"] = float(abs(early.mean() - late.mean()))
            print(f"f2,stage_gap_tau{int(t)},{abs(early.mean()-late.mean()):.4f}")

    common.save("f2_motivation", rows)
    return rows


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
