"""Paper Fig. 4: hyperparameter sensitivity of FedPSA.

Grid over (gamma, delta) and (L_s buffer, L_q queue). Claims validated:
* performance degrades only when gamma AND delta are both very small
  (temperature collapses to ~0 -> argmax-like aggregation too early),
* L_s in 5..20 and L_q in 10..50 are flat; very large L_s slows updates.

The (gamma, delta) grid is timeline-preserving, so the WHOLE grid runs as
one ``run_sweep`` call — one compiled step serves every point, the
per-dispatch overhead is paid once instead of |grid| times. The (L_s, L_q)
points change state SHAPES (ring buffer / thermometer queue), so each is
its own single-lane sweep (a fresh compile per shape is unavoidable).
"""
from __future__ import annotations

import sys

from repro.core import PSAConfig
from repro.federated import SweepConfig
from benchmarks import common

GAMMA_DELTA_FULL = [(0.1, 0.05), (0.1, 0.5), (1, 0.5), (5, 0.5), (5, 2), (10, 1)]
GAMMA_DELTA_FAST = [(0.1, 0.05), (5, 0.5), (10, 1)]
LS_LQ_FULL = [(5, 10), (5, 50), (10, 50), (20, 50), (40, 50), (5, 200)]
LS_LQ_FAST = [(5, 50), (20, 50), (5, 200)]


def main(argv=None):
    gd = GAMMA_DELTA_FULL if common.FULL else GAMMA_DELTA_FAST
    sl = LS_LQ_FULL if common.FULL else LS_LQ_FAST
    horizon = common.HORIZON if common.FULL else 60_000.0
    rows = {}
    # (gamma, delta): one lane per grid point, one batched simulation
    sim = common.sim_config(horizon=horizon, eval_every=horizon / 4)
    sweep = SweepConfig(policy_params=[
        {"gamma": float(g), "delta": float(d)} for g, d in gd])
    res = common.sweep_cell("fedpsa", 0.1, sweep, sim=sim, psa=PSAConfig())
    for (gamma, delta), acc in zip(gd, res.final_accuracy):
        rows[f"gamma{gamma}_delta{delta}"] = acc
        print(f"f4,gamma={gamma},delta={delta},{acc:.4f}")
    # (L_s, L_q): shape-changing -> one single-lane sweep per point
    for ls, lq in sl:
        psa = PSAConfig(buffer_size=ls, queue_len=lq)
        sim = common.sim_config(horizon=horizon, eval_every=horizon / 4)
        res = common.sweep_cell("fedpsa", 0.1, SweepConfig(num_lanes=1),
                                sim=sim, psa=psa)
        rows[f"Ls{ls}_Lq{lq}"] = res.final_accuracy[0]
        print(f"f4,Ls={ls},Lq={lq},{res.final_accuracy[0]:.4f}")
    common.save("f4_hyperparams", rows)
    # the paper's warning: both gamma and delta very small hurts
    small = rows.get("gamma0.1_delta0.05")
    normal = rows.get("gamma5_delta0.5")
    if small is not None and normal is not None:
        print(f"f4,small_gamma_delta_penalty,{normal - small:+.4f}")
    return rows


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
