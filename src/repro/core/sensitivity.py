"""Parameter sensitivity (paper Eq. 3-8).

Sensitivity of parameter i is the loss change when zeroing it, approximated
by a 2nd-order Taylor expansion with the empirical-Fisher diagonal standing
in for the Hessian diagonal:

    s_i = | g_i * theta_i  -  1/2 * F_ii * theta_i^2 |          (Eq. 8)
    F_ii = mean_k ( (d loss_k / d theta_i)^2 )                  (Eq. 6)

Both the gradient and the Fisher diagonal are evaluated on the *shared
calibration batch* D_b (which may be pure Gaussian noise — paper Table 5),
so sensitivities are comparable across clients. The Fisher mean runs over
microbatches of D_b via lax.scan (memory-flat, jit-friendly).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.common import tree as tu


def _split_microbatches(batch: dict, num_micro: int) -> dict:
    """Reshape every leaf (B, ...) -> (m, B//m, ...)."""
    def rs(x):
        B = x.shape[0]
        assert B % num_micro == 0, f"batch {B} % microbatches {num_micro} != 0"
        return x.reshape((num_micro, B // num_micro) + x.shape[1:])
    return jax.tree_util.tree_map(rs, batch)


def fisher_diagonal(loss_fn: Callable, params, calib_batch: dict,
                    num_micro: int = 4):
    """Empirical Fisher diagonal: mean over microbatches of squared grads."""
    micro = _split_microbatches(calib_batch, num_micro)

    def body(acc, mb):
        g = jax.grad(loss_fn)(params, mb)
        acc = jax.tree_util.tree_map(
            lambda a, gi: a + jnp.square(gi.astype(jnp.float32)), acc, g)
        return acc, None

    acc0 = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    acc, _ = jax.lax.scan(body, acc0, micro)
    return jax.tree_util.tree_map(lambda a: a / num_micro, acc)


def sensitivity(loss_fn: Callable, params, calib_batch: dict,
                num_micro: int = 4):
    """Eq. 8 sensitivity pytree. ``loss_fn(params, batch) -> scalar``."""
    g = jax.grad(loss_fn)(params, calib_batch)
    fisher = fisher_diagonal(loss_fn, params, calib_batch, num_micro)
    return sensitivity_from_parts(params, g, fisher)


def sensitivity_from_parts(params, grads, fisher):
    """|g*theta - 0.5*F*theta^2| elementwise over the pytree (f32)."""
    def leaf(p, g, f):
        p32 = p.astype(jnp.float32)
        g32 = g.astype(jnp.float32)
        return jnp.abs(g32 * p32 - 0.5 * f * jnp.square(p32))
    return jax.tree_util.tree_map(leaf, params, grads, fisher)


def first_order_sensitivity(params, grads):
    """|g * theta| — the SNIP-style first-order variant (ablation)."""
    return jax.tree_util.tree_map(
        lambda p, g: jnp.abs(g.astype(jnp.float32) * p.astype(jnp.float32)),
        params, grads)
