"""Scheduler x staleness-metric benchmark presets (``make bench-sched``).

A preset pins the grid of ``benchmarks/sched_staleness.py``: which dispatch
schedulers (``federated.scheduler.SCHEDULERS``), which asyncfeded distance
metrics (``core.psa.DISTANCE_METRICS``), which concurrency levels and which
tolerance (alpha) levels get an AULC operating-point cell, plus how many
seed lanes back each cell. ``sched-paper`` is the study grid on the paper
protocol (Dirichlet alpha=0.1 hardest setting, paper concurrency 0.1 plus a
2x level for the staleness-vs-update-frequency axis); ``sched-smoke`` is
the tier-1 CI cell — a tiny grid proving the whole bench path end to end.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class SchedBenchPreset:
    schedulers: Tuple[str, ...] = ("uniform", "period", "staleness")
    metrics: Tuple[str, ...] = ("l2", "cosine", "sketch")
    concurrencies: Tuple[float, ...] = (0.1, 0.2)
    # asyncfeded mixing alpha — the tolerance knob of the operating point
    alphas: Tuple[float, ...] = (0.3, 0.6)
    seeds: Tuple[int, ...] = (0, 1)
    dirichlet_alpha: float = 0.1      # paper's hardest heterogeneity setting

    @property
    def cells(self) -> int:
        return (len(self.schedulers) * len(self.metrics)
                * len(self.concurrencies) * len(self.alphas))


SCHED_PRESETS = {
    "sched-paper": SchedBenchPreset(),
    # CI smoke: 2 schedulers x 3 metrics x 1 concurrency x 1 alpha,
    # 2 seed lanes — every code path (incl. the structural sketch step),
    # minutes not hours
    "sched-smoke": SchedBenchPreset(schedulers=("uniform", "period"),
                                    concurrencies=(0.1,), alphas=(0.6,),
                                    seeds=(0, 1)),
}


def get_sched_preset(name: str) -> SchedBenchPreset:
    if name not in SCHED_PRESETS:
        raise KeyError(f"unknown sched preset {name!r}; "
                       f"known: {sorted(SCHED_PRESETS)}")
    return SCHED_PRESETS[name]
