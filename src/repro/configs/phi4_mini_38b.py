"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2412.08905].

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    block_pattern=("attn",),
    ffn_pattern=("dense",),
    long_context_window=8192,
    # §Perf opt: pure data parallelism (binding term 73.4s -> 6.1s, 12x)
    pure_data_parallel=True,
)
