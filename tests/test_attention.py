"""Chunked online-softmax attention vs a naive full-score-matrix oracle."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import chunked_attention, decode_attention


def naive_attention(q, k, v, *, causal, window=None, kv_valid=None):
    B, Sq, H, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    G = H // Hkv
    qr = q.reshape(B, Sq, Hkv, G, hd).astype(np.float32)
    s = np.einsum("bqhgd,bkhd->bhgqk", qr, np.asarray(k, np.float32))
    s /= math.sqrt(hd)
    qpos = np.arange(Sq)[:, None]
    kpos = np.arange(Sk)[None, :]
    mask = np.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > (qpos - window)
    s = np.where(mask[None, None, None], s, -1e30)
    if kv_valid is not None:
        vm = kpos[0][None, :] < np.asarray(kv_valid)[:, None]
        s = np.where(vm[:, None, None, None, :], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    out = np.einsum("bhgqk,bkhd->bhgqd", p, np.asarray(v, np.float32))
    return np.transpose(out, (0, 3, 1, 2, 4)).reshape(B, Sq, H, hd)


@pytest.mark.parametrize("Sq,Sk,H,Hkv,hd,causal,window", [
    (16, 16, 4, 2, 8, True, None),
    (16, 16, 4, 4, 8, False, None),
    (33, 33, 2, 1, 16, True, None),       # non-multiple of chunk
    (64, 64, 4, 2, 8, True, 16),          # sliding window
    (17, 17, 2, 2, 4, False, 8),
])
def test_chunked_vs_naive(Sq, Sk, H, Hkv, hd, causal, window):
    key = jax.random.PRNGKey(0)
    B = 2
    q = jax.random.normal(key, (B, Sq, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Sk, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Sk, Hkv, hd))
    out = chunked_attention(q, k, v, causal=causal, window=window,
                            q_chunk=8, kv_chunk=8)
    want = naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)


def test_chunk_size_invariance():
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 40, 4, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 40, 2, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 40, 2, 8))
    outs = [chunked_attention(q, k, v, causal=True, q_chunk=qc, kv_chunk=kc)
            for qc, kc in [(8, 8), (16, 4), (40, 40), (5, 13)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=2e-4, atol=2e-4)


def test_decode_attention_matches_naive():
    key = jax.random.PRNGKey(4)
    B, C, Hkv, hd, H = 3, 12, 2, 8, 4
    q = jax.random.normal(key, (B, 1, H, hd))
    kc = jax.random.normal(jax.random.fold_in(key, 1), (B, C, Hkv, hd))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (B, C, Hkv, hd))
    valid = jnp.asarray([5, 12, 1])
    out = decode_attention(q, kc, vc, valid)
    want = naive_attention(q, kc, vc, causal=False, kv_valid=valid)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)


def test_decode_ring_permutation_invariance():
    """Softmax over the valid cache is order-invariant: rolling the (full)
    ring buffer must not change the output."""
    key = jax.random.PRNGKey(5)
    B, C, Hkv, hd, H = 1, 8, 2, 4, 4
    q = jax.random.normal(key, (B, 1, H, hd))
    kc = jax.random.normal(jax.random.fold_in(key, 1), (B, C, Hkv, hd))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (B, C, Hkv, hd))
    out1 = decode_attention(q, kc, vc, jnp.int32(C))
    out2 = decode_attention(q, jnp.roll(kc, 3, axis=1), jnp.roll(vc, 3, axis=1),
                            jnp.int32(C))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)
