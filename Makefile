# Repo CI entry points. `make test` is the tier-1 gate from ROADMAP.md.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast bench-smoke bench bench-sim

test:
	$(PY) -m pytest -x -q

test-fast:
	$(PY) -m pytest -x -q -m "not slow"

# Kernel + server-step microbenchmarks; writes artifacts/bench/*.json
# including BENCH_server_step.json (legacy ingest vs fused jitted step).
bench-smoke:
	$(PY) -m benchmarks.kernel_micro

# Simulator dispatch throughput: legacy per-client loop vs the cohort
# engine; writes artifacts/bench/BENCH_sim_throughput.json. Narrow with
# e.g. SIM_BENCH_CLIENTS=50,500.
bench-sim:
	$(PY) -m benchmarks.sim_throughput

bench:
	$(PY) -m benchmarks.run
