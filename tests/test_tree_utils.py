"""Pytree arithmetic: unit + seeded property tests (hypothesis-free)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import tree as tu


def _tree(vals):
    a, b, c = vals
    return {"x": jnp.asarray(a), "y": {"z": jnp.asarray(b), "w": jnp.asarray(c)}}


def _tree_pairs(n=25):
    shapes = [(3,), (2, 4), (1,), (5, 2)]
    for seed in range(n):
        rng = np.random.RandomState(seed)
        shape = shapes[seed % len(shapes)]

        def mk():
            return _tree([rng.uniform(-100, 100, shape).astype(np.float32)
                          for _ in range(3)])

        yield mk(), mk(), rng


def test_add_sub_roundtrip():
    for a, b, _ in _tree_pairs():
        back = tu.tree_sub(tu.tree_add(a, b), b)
        for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(back)):
            np.testing.assert_allclose(la, lb, rtol=1e-4, atol=1e-4)


def test_axpy_matches_scale_add():
    for x, y, rng in _tree_pairs():
        alpha = float(rng.uniform(-10, 10))
        got = tu.tree_axpy(alpha, x, y)
        want = tu.tree_add(tu.tree_scale(x, alpha), y)
        for la, lb in zip(jax.tree_util.tree_leaves(got), jax.tree_util.tree_leaves(want)):
            np.testing.assert_allclose(la, lb, rtol=1e-4, atol=1e-4)


def test_sq_norm_equals_self_dot():
    for a, _, _ in _tree_pairs():
        np.testing.assert_allclose(float(tu.tree_sq_norm(a)),
                                   float(tu.tree_dot(a, a)), rtol=1e-5)


def test_weighted_sum_matches_manual():
    key = jax.random.PRNGKey(0)
    trees = [_tree([jax.random.normal(jax.random.fold_in(key, 3 * i + j), (4, 3))
                    for j in range(3)]) for i in range(4)]
    w = jnp.asarray([0.1, 0.2, 0.3, 0.4])
    got = tu.tree_weighted_sum(trees, w)
    want = trees[0]
    want = jax.tree_util.tree_map(lambda *ls: sum(float(w[i]) * ls[i] for i in range(4)), *trees)
    for la, lb in zip(jax.tree_util.tree_leaves(got), jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(la, lb, rtol=1e-5, atol=1e-6)


def test_flatten_roundtrip():
    t = _tree([np.arange(6, dtype=np.float32).reshape(2, 3),
               np.ones(4, np.float32), np.zeros((2, 2), np.float32)])
    vec, unflatten = tu.flatten_to_vector(t)
    assert vec.shape == (tu.tree_size(t),)
    back = unflatten(vec)
    for la, lb in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(la, lb)


def test_all_finite():
    t = _tree([np.ones(3, np.float32)] * 3)
    assert bool(tu.tree_all_finite(t))
    t["x"] = jnp.asarray([1.0, np.nan, 2.0])
    assert not bool(tu.tree_all_finite(t))
