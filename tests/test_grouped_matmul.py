"""Grouped member-GEMM kernel + member_dot routing seam.

Three layers of contract, mirroring how the kernel is reached in production:

1. ``grouped_matmul_pallas`` vs the einsum oracle (``kernels/ref.py``) over
   ragged bucket shapes — G=1, non-power-of-2 everything, fully padded rows
   via the valid mask — in interpret mode (compiled mode only exists on TPU).
2. ``member_dot`` routing: both modes must agree through every composition
   the cohort engines actually build — vmap(grad), the sweep lane vmap on
   top, ncon=2 contractions, shared (unbatched) weights.
3. The cohort engines end to end: ``member_kernel="grouped"`` must match the
   default vmap path within the 1e-5 golden gate on real cohort updates.

Tolerances on the kernel are *relative*: with K padded to multiple 128-blocks
the f32 accumulation order differs from a single einsum reduction.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import tree as tu
from repro.configs import get_config
from repro.data import (ClientDataset, StackedClients, dirichlet_partition,
                        make_classification, train_test_split)
from repro.federated.cohort import CohortEngine
from repro.kernels.grouped_matmul import grouped_matmul_pallas
from repro.kernels.ref import grouped_matmul_ref
from repro.models import member_math
from repro.models import model as M


def _rel_close(got, want, tol=1e-5):
    scale = float(jnp.max(jnp.abs(want))) + 1e-9
    err = float(jnp.max(jnp.abs(got - want))) / scale
    assert err < tol, err


@pytest.mark.parametrize("G,Mm,K,N", [
    (1, 8, 16, 16),        # single-member bucket
    (3, 130, 200, 96),     # non-power-of-2 on every axis, K > one block
    (5, 1, 7, 3),          # tiny ragged odds
    (4, 32, 256, 64),      # K spans two 128-blocks exactly
])
def test_kernel_vs_ref(G, Mm, K, N):
    key = jax.random.PRNGKey(G * 1000 + K)
    lhs = jax.random.normal(key, (G, Mm, K), jnp.float32)
    rhs = jax.random.normal(jax.random.fold_in(key, 1), (G, K, N), jnp.float32)
    out = grouped_matmul_pallas(lhs, rhs, interpret=True)
    _rel_close(out, grouped_matmul_ref(lhs, rhs))


def test_kernel_padded_rows_are_exact_noops():
    """valid=0 groups must come back exactly zero, not approximately."""
    key = jax.random.PRNGKey(0)
    lhs = jax.random.normal(key, (4, 16, 64), jnp.float32)
    rhs = jax.random.normal(jax.random.fold_in(key, 1), (4, 64, 32), jnp.float32)
    valid = jnp.array([1.0, 0.0, 1.0, 0.0])
    out = grouped_matmul_pallas(lhs, rhs, valid=valid, interpret=True)
    np.testing.assert_array_equal(np.asarray(out[1]), 0.0)
    np.testing.assert_array_equal(np.asarray(out[3]), 0.0)
    _rel_close(out[0], grouped_matmul_ref(lhs, rhs)[0])
    _rel_close(out[2], grouped_matmul_ref(lhs, rhs)[2])


def test_kernel_dtype_promotion():
    key = jax.random.PRNGKey(3)
    lhs = jax.random.normal(key, (2, 8, 16), jnp.bfloat16)
    rhs = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, 8), jnp.float32)
    out = grouped_matmul_pallas(lhs, rhs, interpret=True)
    assert out.dtype == jnp.float32
    _rel_close(out, grouped_matmul_ref(lhs, rhs), tol=5e-3)


@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="compiled Pallas path needs a TPU backend")
def test_kernel_compiled_matches_interpret():
    key = jax.random.PRNGKey(5)
    lhs = jax.random.normal(key, (3, 64, 192), jnp.float32)
    rhs = jax.random.normal(jax.random.fold_in(key, 1), (3, 192, 64), jnp.float32)
    a = grouped_matmul_pallas(lhs, rhs, interpret=False)
    b = grouped_matmul_pallas(lhs, rhs, interpret=True)
    _rel_close(a, b)


# --- member_dot routing ---------------------------------------------------

def _both_modes(fn, *args):
    with member_math.routing("vmap"):
        a = fn(*args)
    with member_math.routing("grouped"):
        b = fn(*args)
    return a, b


def test_member_dot_grad_under_member_vmap():
    """The composition the cohort engines build: grad inside, vmap outside."""
    key = jax.random.PRNGKey(0)
    B, Mm, K, N = 4, 6, 24, 8
    x = jax.random.normal(key, (B, Mm, K))
    w = jax.random.normal(jax.random.fold_in(key, 1), (B, K, N))

    def loss(w1, x1):
        return jnp.sum(jnp.tanh(member_math.member_dot(x1, w1)) ** 2)

    f = jax.jit(jax.vmap(jax.value_and_grad(loss)))
    (la, ga), (lb, gb) = _both_modes(f, w, x)
    _rel_close(la, lb)
    _rel_close(ga, gb)


def test_member_dot_under_lane_vmap():
    """Sweep lanes fold into the group axis (vmap over vmap)."""
    key = jax.random.PRNGKey(1)
    L, B, Mm, K, N = 3, 4, 5, 16, 8
    x = jax.random.normal(key, (B, Mm, K))           # shared data across lanes
    w = jax.random.normal(jax.random.fold_in(key, 1), (L, B, K, N))
    f = jax.jit(jax.vmap(jax.vmap(member_math.member_dot),
                         in_axes=(None, 0)))
    a, b = _both_modes(f, x, w)
    _rel_close(a, b)


def test_member_dot_ncon2():
    """The attention output projection contracts two axes (heads, head_dim)."""
    key = jax.random.PRNGKey(2)
    B, S, H, D, O = 3, 5, 4, 8, 16
    x = jax.random.normal(key, (B, S, H, D))
    w = jax.random.normal(jax.random.fold_in(key, 1), (B, H, D, O))
    f = jax.jit(jax.vmap(lambda x1, w1: member_math.member_dot(x1, w1, ncon=2)))
    a, b = _both_modes(f, x, w)
    _rel_close(a, b)


def test_member_dot_shared_weights():
    """Weights not batched (wd=None): one big dot, no broadcast copies."""
    key = jax.random.PRNGKey(4)
    B, Mm, K, N = 5, 3, 12, 7
    x = jax.random.normal(key, (B, Mm, K))
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N))
    f = jax.jit(jax.vmap(member_math.member_dot, in_axes=(0, None)))
    a, b = _both_modes(f, x, w)
    _rel_close(a, b)


def test_member_dot_unbatched_fallback():
    """Outside any vmap the grouped mode still works (plain 2-D bind)."""
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (9, 13))
    w = jax.random.normal(jax.random.fold_in(key, 1), (13, 5))
    a, b = _both_modes(member_math.member_dot, x, w)
    _rel_close(a, b)


def test_routing_validates_and_restores():
    assert member_math.current_mode() == "vmap"
    with pytest.raises(ValueError):
        with member_math.routing("nope"):
            pass
    with member_math.routing("grouped"):
        assert member_math.current_mode() == "grouped"
    assert member_math.current_mode() == "vmap"


# --- cohort engines end to end --------------------------------------------

@pytest.fixture(scope="module")
def world():
    cfg = get_config("paper-synthetic-mlp")
    full = make_classification(3_000, 10, 32, seed=0, class_sep=0.7)
    train, _ = train_test_split(full, 0.1)
    parts = dirichlet_partition(train, 6, alpha=0.3, seed=0)
    datasets = [ClientDataset(train.subset(ix)) for ix in parts]
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, datasets, params


def _engine(cfg, params, datasets, member_kernel):
    spec = tu.FlatSpec(params)
    stacked = StackedClients.from_datasets(datasets)
    eng = CohortEngine(cfg, stacked, spec, params, local_epochs=2,
                       batch_size=32, member_kernel=member_kernel)
    return spec, eng


def test_cohort_grouped_matches_vmap(world):
    """The 1e-5 acceptance gate: grouped member math on a real cohort
    update pins to the default vmap path."""
    cfg, datasets, params = world
    spec, eng_v = _engine(cfg, params, datasets, "vmap")
    _, eng_g = _engine(cfg, params, datasets, "grouped")
    flat = jnp.array(spec.flatten(params), copy=True)
    cids, lrs, seeds = [0, 2, 5], [0.01, 0.008, 0.012], [11, 22, 33]
    thetas = jnp.stack([flat] * 3)
    dv, wv = eng_v.cohort_update(thetas, cids, lrs, seeds)
    dg, wg = eng_g.cohort_update(thetas, cids, lrs, seeds)
    assert float(jnp.max(jnp.abs(dv - dg))) <= 1e-5
    assert float(jnp.max(jnp.abs(wv - wg))) <= 1e-5


def test_sweep_grouped_matches_vmap(world):
    """Same gate one vmap deeper: the S-lane sweep folds lanes into the
    grouped kernel's group axis and must still pin to the vmap path."""
    cfg, datasets, params = world
    spec, eng_v = _engine(cfg, params, datasets, "vmap")
    _, eng_g = _engine(cfg, params, datasets, "grouped")
    flat = jnp.array(spec.flatten(params), copy=True)
    S, cids, lrs = 2, [0, 3], [0.01, 0.009]
    thetas = jnp.stack([jnp.stack([flat] * len(cids))] * S)
    seeds = np.array([[7, 8], [9, 10]])
    dv, wv = eng_v.sweep_update(thetas, cids, lrs, seeds)
    dg, wg = eng_g.sweep_update(thetas, cids, lrs, seeds)
    assert float(jnp.max(jnp.abs(dv - dg))) <= 1e-5
    assert float(jnp.max(jnp.abs(wv - wg))) <= 1e-5


def test_cohort_rejects_unknown_member_kernel(world):
    cfg, datasets, params = world
    with pytest.raises(ValueError, match="member_kernel"):
        _engine(cfg, params, datasets, "einsum")
