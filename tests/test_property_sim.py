"""Simulator wave invariants, fuzzed over latency/availability draws.

The batched (cohort) drain trains whole completion waves up front, which is
only sound if every wave is *re-dispatch-safe*: each arrival must have
trained from exactly the global snapshot that existed at its
version-at-dispatch, no matter how receives, dropouts, and eval boundaries
interleave. These tests check that directly — instrumenting
``CohortEngine.cohort_update`` (what was trained from) and
``PolicyServer.receive_many`` (what version each arrival claimed) and
requiring the trained bytes to equal the recorded snapshot of that version
— plus the bookkeeping invariants: event times monotone, and
``launched == concurrency + completions + dropped`` (every processed event
re-dispatches exactly once; the remainder is still in flight at the
horizon).

Deterministic parametrized draws always run; with ``hypothesis`` installed
(``requirements-dev.txt``) the same invariant is fuzzed over random
latency/dropout configurations.
"""
import hashlib

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import (ClientDataset, dirichlet_partition,
                        make_classification, train_test_split)
from repro.federated import SimConfig, run_algorithm
from repro.federated import cohort as cohort_mod
from repro.federated import servers as servers_mod
from repro.models import model as M

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

NUM_CLIENTS = 6
CONCURRENCY = max(1, int(round(0.2 * NUM_CLIENTS)))


@pytest.fixture(scope="module")
def world():
    cfg = get_config("paper-synthetic-mlp")
    full = make_classification(800, 10, 32, seed=0, class_sep=0.7)
    train, test = train_test_split(full, 0.1)
    parts = dirichlet_partition(train, NUM_CLIENTS, alpha=0.3, seed=0)
    clients = [ClientDataset(train.subset(ix)) for ix in parts]
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, clients, test, params


def _sim(seed, latency_kind, availability_kind, dropout_rate, engine,
         scheduler="uniform"):
    return SimConfig(num_clients=NUM_CLIENTS, horizon=3_500.0,
                     eval_every=1_750.0, seed=seed,
                     latency_kind=latency_kind,
                     availability_kind=availability_kind,
                     dropout_rate=dropout_rate, engine=engine,
                     scheduler=scheduler,
                     record_trajectory=True)


def _digest(row) -> bytes:
    return hashlib.md5(np.ascontiguousarray(np.asarray(row)).tobytes()).digest()


def _run_cohort_instrumented(world, sim):
    """Run the cohort engine while recording (a) the byte-exact snapshot
    every trained arrival started from and (b) the snapshot the server held
    at every global version; returns (result, trained, vdisp, by_version)."""
    cfg, clients, test, params = world
    trained, vdisp = [], []
    by_version = {}
    orig_update = cohort_mod.CohortEngine.cohort_update
    orig_many = servers_mod.PolicyServer.receive_many

    def spy_update(self, params_stack, cids, lrs, seeds):
        trained.extend(_digest(r) for r in np.asarray(params_stack))
        return orig_update(self, params_stack, cids, lrs, seeds)

    def spy_many(self, deltas, client_params, cids, sizes, v_dispatch,
                 sketches=None):
        by_version.setdefault(self._version, _digest(self.flat_params))
        vdisp.extend(int(v) for v in v_dispatch)
        v = self._version
        upd, taus, snaps = orig_many(self, deltas, client_params, cids,
                                     sizes, v_dispatch, sketches)
        rows = np.asarray(snaps)
        for i in range(rows.shape[0]):
            if upd[i]:
                v += 1
            by_version[v] = _digest(rows[i])
        return upd, taus, snaps

    cohort_mod.CohortEngine.cohort_update = spy_update
    servers_mod.PolicyServer.receive_many = spy_many
    try:
        result = run_algorithm("fedbuff", cfg, params, clients, test, sim)
    finally:
        cohort_mod.CohortEngine.cohort_update = orig_update
        servers_mod.PolicyServer.receive_many = orig_many
    return result, trained, vdisp, by_version


def _check_invariants(world, seed, latency_kind, availability_kind,
                      dropout_rate, scheduler="uniform"):
    cfg, clients, test, params = world
    seq = run_algorithm("fedbuff", cfg, params, clients, test,
                        _sim(seed, latency_kind, availability_kind,
                             dropout_rate, "sequential", scheduler))
    coh, trained, vdisp, by_version = _run_cohort_instrumented(
        world, _sim(seed, latency_kind, availability_kind, dropout_rate,
                    "cohort", scheduler))

    # -- re-dispatch safety: each arrival trained from the exact snapshot
    #    of its version-at-dispatch
    assert len(trained) == len(vdisp) == coh.dispatches
    for j, (got, v) in enumerate(zip(trained, vdisp)):
        assert got == by_version[v], (j, v)

    # -- event times monotone
    t_recv = [e["t"] for e in coh.receive_log]
    assert all(a <= b for a, b in zip(t_recv, t_recv[1:]))
    assert all(a < b for a, b in zip(coh.times, coh.times[1:]))

    # -- dispatch accounting: every processed event re-dispatches once
    for r in (seq, coh):
        assert r.launched == CONCURRENCY + r.dispatches + r.dropped

    # -- the batched drain is the sequential oracle
    assert [(e["t"], e["client"], e["tau"]) for e in seq.receive_log] == \
           [(e["t"], e["client"], e["tau"]) for e in coh.receive_log]
    assert (seq.dispatches, seq.dropped, seq.versions, seq.launched) == \
           (coh.dispatches, coh.dropped, coh.versions, coh.launched)
    assert len(seq.digests) == len(coh.digests)
    np.testing.assert_allclose(np.asarray(coh.digests),
                               np.asarray(seq.digests), rtol=1e-4, atol=1e-4)
    assert seq.dispatches > 0


# every dispatch scheduler must uphold the wave invariants — in particular
# the period scheduler's deferred launches and the staleness scheduler's
# sequential weighted draws may not break re-dispatch safety or the
# sequential-vs-cohort oracle parity
@pytest.mark.parametrize("scheduler", ["uniform", "period", "staleness"])
@pytest.mark.parametrize("seed,latency_kind,availability_kind,dropout_rate", [
    (0, "uniform", "always", 0.0),
    (1, "longtail", "hetero", 0.3),
    (2, "uniform", "slow-fragile", 0.25),
])
def test_wave_invariants_fixed_draws(world, seed, latency_kind,
                                     availability_kind, dropout_rate,
                                     scheduler):
    _check_invariants(world, seed, latency_kind, availability_kind,
                      dropout_rate, scheduler)


if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 10_000),
           latency_kind=st.sampled_from(["uniform", "longtail"]),
           availability_kind=st.sampled_from(
               ["always", "uniform", "hetero", "slow-fragile"]),
           dropout_rate=st.floats(0.05, 0.45),
           scheduler=st.sampled_from(["uniform", "period", "staleness"]))
    @settings(max_examples=5, deadline=None,
              suppress_health_check=[HealthCheck.function_scoped_fixture])
    def test_wave_invariants_fuzzed(world, seed, latency_kind,
                                    availability_kind, dropout_rate,
                                    scheduler):
        _check_invariants(world, seed, latency_kind, availability_kind,
                          dropout_rate, scheduler)
