"""Per-assigned-architecture smoke tests (reduced variants, CPU).

Each of the 10 architectures instantiates its reduced config (<=2
superblocks, d_model<=256, <=4 experts) and runs one forward + one train
step asserting output shapes and no NaNs. Decode-capable archs also check
prefill+decode consistency against the full forward.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.sharding import SINGLE_DEVICE_RULES as R
from repro.configs import ASSIGNED, get_config
from repro.models import model as M

ARCHS = list(ASSIGNED)


def _batch(cfg, key, B=2, S=24):
    if cfg.frontend == "audio":
        return {
            "features": jax.random.normal(key, (B, S, cfg.d_model), jnp.float32),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        }, S
    if cfg.frontend == "vision":
        P = cfg.num_prefix_tokens
        return {
            "tokens": jax.random.randint(key, (B, S - P), 0, cfg.vocab_size),
            "patches": jax.random.normal(key, (B, P, cfg.d_model), jnp.float32),
            "labels": jax.random.randint(key, (B, S - P), 0, cfg.vocab_size),
        }, S
    return {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }, S


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 256 and cfg.num_experts <= 4
    assert cfg.num_layers <= 2 * max(len(cfg.block_pattern), 1)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    batch, S = _batch(cfg, key)
    loss = M.loss_fn(params, batch, cfg, R)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0

    grads = jax.grad(lambda p: M.loss_fn(p, batch, cfg, R))(params)
    gleaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in gleaves), arch
    # one SGD step moves the loss
    new = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g.astype(p.dtype), params, grads)
    loss2 = M.loss_fn(new, batch, cfg, R)
    assert np.isfinite(float(loss2))
    assert float(loss2) < float(loss), f"{arch}: step did not reduce loss"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_output_shapes(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    batch, S = _batch(cfg, key)
    if cfg.is_encoder_only:
        logits = M.encode(params, batch, cfg, R)
    else:
        logits = M.forward_logits(params, batch, cfg, R)
    # logits carry the padded vocab width; pad columns are masked to -inf
    assert logits.shape == (2, S, cfg.vocab_padded)
    assert int(jnp.argmax(logits, -1).max()) < cfg.vocab_size
    real = np.asarray(logits[..., :cfg.vocab_size], np.float32)
    assert np.isfinite(real).all()


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "hubert-xlarge"])
def test_smoke_prefill_decode_consistency(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = M.init_params(key, cfg)
    B, S = 2, 16
    P = cfg.num_prefix_tokens if cfg.frontend == "vision" else 0
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(key, (B, P, cfg.d_model), jnp.float32)
    cache, logits_pre = M.prefill(params, batch, cfg, R, max_len=S + P + 4)
    full = M.forward_logits(params, batch, cfg, R)
    np.testing.assert_allclose(np.asarray(logits_pre), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)
    cache2, logits_dec = M.decode_step(params, cache, toks[:, S:S + 1],
                                       jnp.int32(S + P), cfg, R)
    batch2 = dict(batch)
    batch2["tokens"] = toks
    full2 = M.forward_logits(params, batch2, cfg, R)
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]), np.asarray(full2[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_decode_matches_windowed_forward():
    """long_500k path: ring-buffer decode == windowed full attention."""
    cfg = dataclasses.replace(get_config("phi4-mini-3.8b").reduced(),
                              sliding_window=8)
    key = jax.random.PRNGKey(3)
    params = M.init_params(key, cfg)
    B, S = 1, 24
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    cache, _ = M.prefill(params, {"tokens": toks[:, :S]}, cfg, R)
    assert cache["p0"]["k"].shape[2] == 8  # (layers, B, C=window, ...)
    _, logits_dec = M.decode_step(params, cache, toks[:, S:S + 1], jnp.int32(S), cfg, R)
    full = M.forward_logits(params, {"tokens": toks}, cfg, R)
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_hubert_has_no_decode():
    cfg = get_config("hubert-xlarge")
    assert cfg.is_encoder_only
    with pytest.raises(AssertionError):
        M.init_cache(cfg.reduced(), 1, 8)


@pytest.mark.parametrize("arch", ARCHS)
def test_exact_assigned_dimensions(arch):
    """The FULL configs carry the exact assigned dimensions."""
    cfg = get_config(arch)
    expect = {
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect


def test_moe_expert_counts():
    q = get_config("qwen2-moe-a2.7b")
    assert (q.num_experts, q.top_k, q.num_shared_experts) == (60, 4, 4)
    a = get_config("arctic-480b")
    assert (a.num_experts, a.top_k) == (128, 2)
    j = get_config("jamba-v0.1-52b")
    assert (j.num_experts, j.top_k) == (16, 2)


def test_param_counts_scale():
    """eval_shape-based counting puts each arch in its advertised ballpark."""
    total, active = M.count_params(get_config("llama3-405b"))
    assert 3.7e11 < total < 4.4e11, total
    total, active = M.count_params(get_config("phi4-mini-3.8b"))
    assert 3.0e9 < total < 4.6e9, total
    total, active = M.count_params(get_config("qwen2-moe-a2.7b"))
    assert active < total  # MoE discount
    assert 1.0e10 < total < 2.0e10, total
    assert 2.0e9 < active < 4.5e9, active
