"""Server-side aggregation strategies — thin shims over the policy core.

Every async algorithm (fedasync, fedbuff, fedpsa, ca2fl, fedfa, fedpac,
asyncfeded; the synchronous fedavg runs round-based in the simulator) is a
pure jit-compiled ``policy.step`` in ``repro.federated.policies``.
``PolicyServer`` adapts that functional core to the legacy object interface
the simulator and benchmarks speak:

    receive(delta, client_params, meta) -> bool   # True if global updated
    receive_many(...)                             # batched ingest (one scan)
    params                                        # current global pytree
    flat_params                                   # current global (d,) vector
    version                                       # number of global updates

``meta`` carries tau (version gap), client_id, data_size and, for FedPSA,
the uploaded sensitivity sketch. One ``receive`` costs exactly one jitted
device call; ``receive_many`` ingests a whole completion wave by scanning
the policy's raw step — equivalent to B receives but with O(log B) device
calls. ``params`` unflattens the flat state vector lazily (cached per
version). The original unjitted classes live in ``repro.federated.legacy``
as the numerical reference.

``ShardedPolicyServer`` is the mesh-sharded drop-in: the same policy steps
run under ``shard_map`` with every ``(…, d)`` tensor of ``ServerState``
partitioned over the mesh's flat-parameter axis (see
``server_state_specs`` for the layout contract) and only scalar reductions
crossing shards via ``psum`` (``common.sharding.param_axis_sum``).

Policy keyword arguments flow through ``make_server``/``make_lane_server``
``**kw`` to the policy factory — e.g. ``metric="cosine"``/``"sketch"``
selects the asyncfeded distance-staleness variant (the traced l2/cosine
``dist_mode`` may instead vary per sweep lane via the lane hyper dicts; see
``core.psa.DISTANCE_METRICS``).
"""
from __future__ import annotations

from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common import sharding
from repro.common import tree as tu
from repro.core import psa as psa_lib
from repro.federated import policies as pol


_STEP_MANY_CACHE = {}
_SKETCH_REFRESH_CACHE = {}
_SHARDED_STEP_CACHE = {}
_SHARDED_MANY_CACHE = {}


def _scan_many(raw):
    """The ONE batched-ingest body both layouts compile: scan ``raw`` over
    a batch of arrivals ordered by completion time. ``arrs.tau`` carries
    each arrival's version-at-dispatch; the true staleness depends on
    updates applied by *earlier arrivals in this same batch*, so it is
    resolved inside the scan, which also emits the post-receive flat
    vector per arrival (what a re-dispatch at that instant snapshots)."""

    def many(state, arrs):
        def body(s, a):
            tau = s.version.astype(jnp.float32) - a.tau
            s, info = raw(s, a._replace(tau=tau))
            return s, (info, s.params)

        state, (infos, params_seq) = jax.lax.scan(body, state, arrs)
        return state, infos, params_seq

    return many


class PolicyServer:
    """Host-side adapter around one ``Policy``: owns the ``ServerState``,
    converts metas to ``Arrival``s, and renders ``StepInfo`` into the
    per-update log the benchmarks consume."""

    def __init__(self, policy: pol.Policy, params):
        self.policy = policy
        self.name = policy.name
        self.needs_sketch = policy.needs_sketch
        self.client_align = policy.client_align
        self.state = policy.init(params)
        self._step = policy.step
        self._step_many = None
        self.log: List[dict] = []
        self._version = 0
        self._tree_cache = None
        self._tree_cache_version = -1
        self._flat_cache = None
        self._flat_cache_version = -1
        self._unflatten = tu.jit_unflatten(policy.spec)

    # -- layout hooks (identity here; ShardedPolicyServer pads/strips) ------

    def _prep_vec(self, x):
        """Adapt one delta/client-params argument to the step's layout."""
        return x

    def _prep_stack(self, x):
        """Adapt a stacked (B, d) argument to the batched step's layout."""
        return x

    def _strip_stack(self, snaps):
        """Undo ``_prep_stack`` on the returned (B, d) snapshot rows."""
        return snaps

    @property
    def params(self):
        if self._tree_cache_version != self._version:
            self._tree_cache = self._unflatten(self.flat_params)
            self._tree_cache_version = self._version
        return self._tree_cache

    @property
    def flat_params(self):
        """Current global model as the flat (d,) vector — the dispatch
        snapshot the cohort engine trains from. Copied (cached per version):
        the live ``state.params`` buffer is donated to the next jitted step,
        so a reference held across ``receive`` would be a deleted array."""
        if self._flat_cache_version != self._version:
            self._flat_cache = jnp.copy(self.state.params)
            self._flat_cache_version = self._version
        return self._flat_cache

    @property
    def version(self) -> int:
        return self._version

    @property
    def psa(self) -> Optional[psa_lib.PSAState]:
        """Snapshot of the FedPSA sub-state (e.g. ``server.psa.global_sketch``).

        Copied: the live state's buffers are donated to the next jitted step,
        so a reference held across ``receive`` would be a deleted array."""
        if self.state.psa is None:
            return None
        return jax.tree_util.tree_map(jnp.copy, self.state.psa)

    def receive(self, delta, client_params, meta) -> bool:
        """Ingest one completion. ``delta``/``client_params`` may be pytrees
        (legacy path) or flat (d,) vectors (cohort path) — ``spec.flatten``
        inside the jitted step is the identity on an already-flat vector, so
        the two layouts just select different traced variants of the same
        policy step."""
        if self.needs_sketch and "sketch" not in meta:
            raise KeyError(
                f"{self.name} requires meta['sketch'] (behavioral sketch)")
        if self.state.cache is not None:
            cid = int(meta["client_id"])  # cache policies require a real id
            if not 0 <= cid < self.state.cache.data.shape[0]:
                raise ValueError(
                    f"client_id {cid} outside the server's num_clients="
                    f"{self.state.cache.data.shape[0]} cache")
        else:
            cid = int(meta.get("client_id", 0))
        arrival = pol.Arrival(
            update=self._prep_vec(delta),
            client_params=self._prep_vec(client_params),
            tau=jnp.float32(meta.get("tau", 0)),
            client_id=jnp.int32(cid),
            data_size=jnp.float32(meta.get("data_size", 1.0)),
            sketch=jnp.asarray(
                meta["sketch"], jnp.float32) if "sketch" in meta
            else jnp.zeros((self.policy.sketch_k,), jnp.float32),
        )
        self.state, info = self._step(self.state, arrival)
        updated = bool(info.updated)
        if updated:
            self._version += 1
            if self.policy.log_fn is not None:
                entry = self.policy.log_fn(info, meta)
                if entry is not None:
                    self.log.append(entry)
        return updated

    def _build_step_many(self):
        # keyed on the raw step — shared across every policy instance with
        # the same structure (hyper values live in the traced state), so
        # repeated runs AND hyperparameter grids reuse one compiled scan per
        # chunk size
        raw = self.policy.raw_step
        assert raw is not None, f"{self.name} has no raw_step for batched ingest"
        cached = _STEP_MANY_CACHE.get(raw)
        if cached is not None:
            return cached
        fn = jax.jit(_scan_many(raw), donate_argnums=(0,))
        _STEP_MANY_CACHE[raw] = fn
        return fn

    def receive_many(self, deltas, client_params, client_ids, data_sizes,
                     v_dispatch, sketches=None):
        """Batched ingest: apply B completions (stacked flat (B, d) arrays,
        ordered by completion time) with one scanned device call per
        power-of-two chunk instead of B separate ``receive`` calls.

        Exactly equivalent to B sequential ``receive``s: the scan threads the
        state through in order, staleness is resolved per-arrival inside the
        scan from ``v_dispatch`` (version at dispatch), and the returned
        ``snapshots[i]`` is the flat global vector *after* arrival i — what a
        completion-triggered re-dispatch at that instant must train from.
        Returns (updated (B,) bool, taus (B,) int list, snapshots (B, d)).
        """
        if self.needs_sketch and sketches is None:
            raise KeyError(f"{self.name} requires behavioral sketches")
        B = int(deltas.shape[0])
        ids = np.asarray(client_ids, np.int64)
        if self.state.cache is not None:
            n = self.state.cache.data.shape[0]
            if ids.size and (ids.min() < 0 or ids.max() >= n):
                raise ValueError(
                    f"client_id outside the server's num_clients={n} cache")
        if self.policy.raw_step is None:
            # policy registered without a raw step (pre-batching style):
            # degrade to per-event ingest instead of failing
            return self._receive_many_fallback(deltas, client_params, ids,
                                               data_sizes, v_dispatch,
                                               sketches)
        if self._step_many is None:
            self._step_many = self._build_step_many()
        if sketches is None:
            sketches = jnp.zeros((B, self.policy.sketch_k), jnp.float32)
        deltas = self._prep_stack(deltas)
        client_params = self._prep_stack(client_params)
        state = self.state
        infos_parts, snap_parts = [], []
        off = 0
        while off < B:
            # largest power-of-two chunk so the jit cache stays O(log B)
            chunk = 1 << int(np.log2(B - off))
            sl = slice(off, off + chunk)
            arrs = pol.Arrival(
                update=deltas[sl], client_params=client_params[sl],
                tau=jnp.asarray(v_dispatch[sl], jnp.float32),
                client_id=jnp.asarray(ids[sl], jnp.int32),
                data_size=jnp.asarray(data_sizes[sl], jnp.float32),
                sketch=sketches[sl])
            state, infos, snaps = self._step_many(state, arrs)
            if self.policy.log_fn is None:
                # only the update flags cross to the host (one sync, not six)
                infos = infos._replace(updated=np.asarray(infos.updated))
            else:
                infos = jax.tree_util.tree_map(np.asarray, infos)
            infos_parts.append(infos)
            snap_parts.append(snaps)
            off += chunk
        self.state = state
        updated = np.concatenate([p.updated.reshape(-1) for p in infos_parts])
        snapshots = (snap_parts[0] if len(snap_parts) == 1
                     else jnp.concatenate(snap_parts))
        taus: List[int] = []
        v = self._version
        row = 0
        for part in infos_parts:
            for i in range(part.updated.shape[0]):
                tau = v - int(v_dispatch[row])
                taus.append(tau)
                if part.updated[i]:
                    v += 1
                    if self.policy.log_fn is not None:
                        info_row = pol.StepInfo(*[np.asarray(f)[i]
                                                  for f in part])
                        meta = {"tau": tau, "client_id": int(ids[row]),
                                "data_size": float(data_sizes[row])}
                        entry = self.policy.log_fn(info_row, meta)
                        if entry is not None:
                            self.log.append(entry)
                row += 1
        self._version = v
        return updated, taus, self._strip_stack(snapshots)

    def _receive_many_fallback(self, deltas, client_params, ids, data_sizes,
                               v_dispatch, sketches):
        """Per-event equivalent of ``receive_many`` for policies with no
        ``raw_step`` — B ``receive`` calls plus per-row snapshot copies."""
        B = int(deltas.shape[0])
        updated = np.zeros((B,), bool)
        taus: List[int] = []
        rows = []
        for i in range(B):
            tau = self._version - int(v_dispatch[i])
            taus.append(tau)
            meta = {"tau": tau, "client_id": int(ids[i]),
                    "data_size": float(data_sizes[i])}
            if sketches is not None:
                meta["sketch"] = sketches[i]
            updated[i] = self.receive(deltas[i], client_params[i], meta)
            rows.append(self.flat_params)
        return updated, taus, jnp.stack(rows)


# ---------------------------------------------------------------------------
# Mesh-sharded execution layer
# ---------------------------------------------------------------------------

def server_state_specs(state: pol.ServerState, axis: str) -> pol.ServerState:
    """The sharded-layout contract, as a ``ServerState`` of PartitionSpecs.

    Exactly the tensors whose TRAILING axis is the flat parameter axis shard
    over the mesh: ``params`` (d,), ``ring.data`` (L, d), ``psa.buffer``
    (L_s, d), ``cache.data`` (C, d) and ``cache.total`` (d,). Everything
    else — versions, fill counts, kappas, the thermometer queue, sketches,
    cache validity — is small and replicated, so all cross-shard traffic is
    the scalar psums in ``param_axis_sum`` (plus FedPSA's all_gather on its
    sketch-refresh branch). A new policy opts in by storing its d-sized
    state in these fields (or extending this template alongside them)."""
    rep = P()
    row = P(axis)
    mat = P(None, axis)
    ring = None if state.ring is None else pol.RingState(data=mat, count=rep)
    cache = None if state.cache is None else pol.CacheState(
        data=mat, valid=rep, total=row)
    psa = None
    if state.psa is not None:
        psa = psa_lib.PSAState(
            buffer=mat, kappas=rep, count=rep,
            thermo=jax.tree_util.tree_map(lambda _: rep, state.psa.thermo),
            global_sketch=rep)
    hyper = (None if state.hyper is None else
             jax.tree_util.tree_map(lambda _: rep, state.hyper))
    return pol.ServerState(params=row, version=rep, ring=ring, psa=psa,
                           cache=cache, hyper=hyper)


def _arrival_specs(axis: str, batched: bool) -> pol.Arrival:
    vec = P(None, axis) if batched else P(axis)
    rep = P()
    return pol.Arrival(update=vec, client_params=vec, tau=rep, client_id=rep,
                       data_size=rep, sketch=rep)


_INFO_SPECS = pol.StepInfo(updated=P(), weights=P(), kappas=P(), temp=P(),
                           temp_valid=P(), mix=P())


def _pad_last(x: jnp.ndarray, d_pad: int) -> jnp.ndarray:
    """Zero-pad the trailing (flat parameter) axis up to the divisible
    width. The pad region is all-zero in every d-sized input, so it stays
    identically zero through every policy's elementwise update rules and
    contributes nothing to the psum'd reductions."""
    pad = d_pad - x.shape[-1]
    if pad == 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])


class ShardedPolicyServer(PolicyServer):
    """``PolicyServer`` with ``ServerState`` laid out over a one-axis mesh.

    The flat parameter axis is zero-padded to a device-count multiple and
    partitioned per ``server_state_specs``; the policy's *raw* step runs
    under ``shard_map`` (traced inside ``common.sharding.param_axis`` so
    its d-contractions psum), which makes the per-shard program the same
    elementwise/ring arithmetic as the single-device step — including the
    per-shard Pallas ``buffer_agg`` path on TPU. Host-facing results
    (``flat_params``, ``receive_many`` snapshots) strip the padding, so the
    simulator and cohort engine are layout-agnostic."""

    def __init__(self, policy: pol.Policy, params, mesh: Mesh,
                 rules: Optional[sharding.LogicalRules] = None):
        rules = rules or sharding.FEDERATED_RULES
        axis = rules.mesh_axes(("param_shard",))[0]
        if axis is None or axis not in mesh.axis_names:
            raise ValueError(
                f"rules must map 'param_shard' onto a mesh axis of "
                f"{mesh.axis_names}, got {axis!r}")
        self.mesh = mesh
        self.axis = axis
        self._d = policy.spec.size
        n = mesh.shape[axis]
        self._d_pad = -(-self._d // n) * n
        super().__init__(policy, params)
        self._specs = server_state_specs(self.state, axis)
        self.state = self._shard_state(self.state)
        self._step = self._build_step()

    # -- layout ------------------------------------------------------------

    def _shard_state(self, state: pol.ServerState) -> pol.ServerState:
        padded = jax.tree_util.tree_map(
            lambda x, s: _pad_last(x, self._d_pad)
            if (len(s) and s[-1] == self.axis) else x,
            state, self._specs)
        put = jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), self._specs,
            is_leaf=lambda s: isinstance(s, P))
        return jax.device_put(padded, put)

    def _prep_vec(self, x):
        # flatten is the identity reshape on an already-flat vector
        return _pad_last(self.policy.spec.flatten(x), self._d_pad)

    def _prep_stack(self, x):
        return _pad_last(jnp.asarray(x), self._d_pad)

    def _strip_stack(self, snaps):
        return snaps[:, :self._d] if snaps.shape[-1] != self._d else snaps

    @property
    def flat_params(self):
        """Current global model as the *unpadded* (d,) vector (the slice
        allocates a fresh buffer, so donation of the live state is safe)."""
        if self._flat_cache_version != self._version:
            # copy: when d == d_pad the slice can alias the live state
            # buffer, which the next donating step would invalidate
            self._flat_cache = jnp.copy(self.state.params[:self._d])
            self._flat_cache_version = self._version
        return self._flat_cache

    # -- compiled steps ----------------------------------------------------

    def _build_step(self):
        raw = self.policy.raw_step
        assert raw is not None, \
            f"{self.name} has no raw_step; cannot run sharded"
        key = (raw, self.mesh, self.axis)
        cached = _SHARDED_STEP_CACHE.get(key)
        if cached is not None:
            return cached
        axis = self.axis

        def body(state, arr):
            with sharding.param_axis(axis):
                return raw(state, arr)

        fn = jax.jit(shard_map(
            body, mesh=self.mesh,
            in_specs=(self._specs, _arrival_specs(axis, batched=False)),
            out_specs=(self._specs, _INFO_SPECS), check_rep=False),
            donate_argnums=(0,))
        _SHARDED_STEP_CACHE[key] = fn
        return fn

    def _build_step_many(self):
        raw = self.policy.raw_step
        assert raw is not None, \
            f"{self.name} has no raw_step; cannot run sharded"
        key = (raw, self.mesh, self.axis)
        cached = _SHARDED_MANY_CACHE.get(key)
        if cached is not None:
            return cached
        axis = self.axis
        scan_many = _scan_many(raw)

        def many(state, arrs):
            # the context wraps the TRACE of the shared scan body, so its
            # d-contractions psum exactly as in the per-arrival step
            with sharding.param_axis(axis):
                return scan_many(state, arrs)

        fn = jax.jit(shard_map(
            many, mesh=self.mesh,
            in_specs=(self._specs, _arrival_specs(axis, batched=True)),
            out_specs=(self._specs, _INFO_SPECS, P(None, axis)),
            check_rep=False), donate_argnums=(0,))
        _SHARDED_MANY_CACHE[key] = fn
        return fn


# ---------------------------------------------------------------------------
# Lane-stacked execution layer (the sweep engine's server half)
# ---------------------------------------------------------------------------

_LANE_MANY_CACHE = {}


class LanePolicyServer:
    """S experiment lanes of one policy as ONE stacked server.

    ``ServerState`` is stacked with a leading lane axis — per-lane global
    vectors, ring buffers, PSA state AND per-lane ``PolicyParams`` hyper
    leaves — and batched ingest runs ``jax.vmap`` of the same
    ``_scan_many(raw_step)`` body the single-run server scans, so one
    compiled program serves the whole hyperparameter/seed grid. The event
    TIMELINE (completion order, client ids, version bookkeeping, data
    sizes) is shared across lanes by construction: every policy's
    update/flush decision depends only on arrival counts, never on
    parameter values, so the ``updated`` flags are lane-invariant (asserted
    at ingest).

    Host-facing surface mirrors ``PolicyServer`` where it can: ``version``
    (shared), ``flat_params`` — now ``(S, d)`` — and ``receive_many`` over
    ``(S, B, d)`` stacks. Per-update host logs are not rendered (sweeps
    consume digest streams and metrics instead).
    """

    def __init__(self, policy: pol.Policy, params_per_lane,
                 hypers: List[pol.PolicyParams]):
        assert len(params_per_lane) == len(hypers) and len(hypers) >= 1
        self.policy = policy
        self.name = policy.name
        self.needs_sketch = policy.needs_sketch
        self.client_align = policy.client_align
        self.num_lanes = len(hypers)
        states = [policy.init(p, h) for p, h in zip(params_per_lane, hypers)]
        self.state = jax.tree_util.tree_map(
            lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *states)
        self._step_many = None
        self.log: List[dict] = []
        self._version = 0
        self._flat_cache = None
        self._flat_cache_version = -1

    @property
    def version(self) -> int:
        return self._version

    @property
    def flat_params(self) -> jnp.ndarray:
        """(S, d) stack of the lanes' current global vectors (copied: the
        live buffers are donated to the next jitted step)."""
        if self._flat_cache_version != self._version:
            self._flat_cache = jnp.copy(self.state.params)
            self._flat_cache_version = self._version
        return self._flat_cache

    def _build_step_many(self):
        raw = self.policy.raw_step
        assert raw is not None, \
            f"{self.name} has no raw_step; cannot run lane-stacked"
        cached = _LANE_MANY_CACHE.get(raw)
        if cached is not None:
            return cached
        scan_many = _scan_many(raw)
        arr_axes = pol.Arrival(update=0, client_params=0, tau=None,
                               client_id=None, data_size=None, sketch=0)
        fn = jax.jit(jax.vmap(scan_many, in_axes=(0, arr_axes)),
                     donate_argnums=(0,))
        _LANE_MANY_CACHE[raw] = fn
        return fn

    def receive_many(self, deltas, client_params, client_ids, data_sizes,
                     v_dispatch, sketches=None):
        """Batched lane ingest: apply B completions to every lane at once.

        ``deltas``/``client_params`` are ``(S, B, d)`` stacks (lane-major);
        the scalar arrival fields are shared across lanes. Returns
        ``(updated (B,) bool, taus (B,) ints, snapshots (S, B, d))`` — the
        same contract as ``PolicyServer.receive_many`` with a lane axis on
        the tensors.
        """
        if self.needs_sketch and sketches is None:
            raise KeyError(f"{self.name} requires behavioral sketches")
        S, B = int(deltas.shape[0]), int(deltas.shape[1])
        assert S == self.num_lanes, (S, self.num_lanes)
        ids = np.asarray(client_ids, np.int64)
        if self.state.cache is not None:
            n = self.state.cache.data.shape[1]
            if ids.size and (ids.min() < 0 or ids.max() >= n):
                raise ValueError(
                    f"client_id outside the server's num_clients={n} cache")
        if self._step_many is None:
            self._step_many = self._build_step_many()
        if sketches is None:
            sketches = jnp.zeros((S, B, self.policy.sketch_k), jnp.float32)
        state = self.state
        upd_parts, snap_parts = [], []
        off = 0
        while off < B:
            # largest power-of-two chunk, as in PolicyServer.receive_many
            chunk = 1 << int(np.log2(B - off))
            sl = slice(off, off + chunk)
            arrs = pol.Arrival(
                update=deltas[:, sl], client_params=client_params[:, sl],
                tau=jnp.asarray(v_dispatch[sl], jnp.float32),
                client_id=jnp.asarray(ids[sl], jnp.int32),
                data_size=jnp.asarray(data_sizes[sl], jnp.float32),
                sketch=sketches[:, sl])
            state, infos, snaps = self._step_many(state, arrs)
            upd_parts.append(np.asarray(infos.updated))   # (S, chunk) bool
            snap_parts.append(snaps)
            off += chunk
        self.state = state
        upd_lanes = np.concatenate(upd_parts, axis=1)
        # the lane contract: update decisions are count-driven, never
        # value-driven, so they cannot diverge across lanes
        assert bool(np.all(upd_lanes == upd_lanes[:1])), \
            "policy update decisions diverged across sweep lanes"
        updated = upd_lanes[0]
        snapshots = (snap_parts[0] if len(snap_parts) == 1
                     else jnp.concatenate(snap_parts, axis=1))
        taus: List[int] = []
        v = self._version
        for i in range(B):
            taus.append(v - int(v_dispatch[i]))
            v += int(updated[i])
        self._version = v
        return updated, taus, snapshots


def make_lane_server(name: str, params_per_lane, lane_hypers, *,
                     num_clients: int = 50,
                     psa_cfg: Optional[psa_lib.PSAConfig] = None,
                     sketch_fn: Optional[Callable] = None,
                     **kw) -> LanePolicyServer:
    """Build the lane-stacked server for one algorithm.

    ``params_per_lane`` is a list of S parameter pytrees (identical
    layouts); ``lane_hypers`` a list of S dicts of per-lane hyperparameter
    overrides (``PolicyParams`` field names — e.g. ``{"alpha": 0.3}`` or
    ``{"gamma": 0.1, "use_thermometer": False}``) merged over the policy's
    factory defaults. Structural kwargs (buffer_size, psa_cfg shapes, ...)
    are shared by all lanes — ``make_hyper`` rejects them per lane."""
    spec = tu.FlatSpec(params_per_lane[0])
    sketch_refresh = None
    if name == "fedpsa":
        assert psa_cfg is not None and sketch_fn is not None
        key = (id(sketch_fn), spec)
        sketch_refresh = _SKETCH_REFRESH_CACHE.get(key)
        if sketch_refresh is None:
            sketch_refresh = lambda vec: sketch_fn(spec.unflatten(vec))
            sketch_refresh._sketch_fn = sketch_fn   # keep the id() key alive
            _SKETCH_REFRESH_CACHE[key] = sketch_refresh
    policy = pol.make_policy(name, spec, num_clients=num_clients,
                             psa_cfg=psa_cfg, sketch_refresh=sketch_refresh,
                             **kw)
    defaults = dict(policy.hyper_defaults)
    hypers = []
    for over in lane_hypers:
        merged = dict(defaults)
        merged.update(over or {})
        hypers.append(pol.make_hyper(**merged))
    return LanePolicyServer(policy, params_per_lane, hypers)


def make_server(name: str, params, *, num_clients: int = 50,
                psa_cfg: Optional[psa_lib.PSAConfig] = None,
                sketch_fn: Optional[Callable] = None,
                mesh: Optional[Mesh] = None,
                rules: Optional[sharding.LogicalRules] = None,
                **kw) -> PolicyServer:
    """Build the policy-backed server for one algorithm.

    ``sketch_fn`` (fedpsa) maps a params *pytree* to its (k,) sketch; the
    policy core re-expresses it over the flat layout so the global-sketch
    refresh fuses into the jitted step. With ``mesh`` the server state is
    laid out over the mesh's flat-parameter axis (``ShardedPolicyServer``);
    ``rules`` (default ``common.sharding.FEDERATED_RULES``) names the mesh
    axis via the ``param_shard`` logical axis."""
    spec = tu.FlatSpec(params)
    sketch_refresh = None
    if name == "fedpsa":
        assert psa_cfg is not None and sketch_fn is not None
        key = (id(sketch_fn), spec)
        sketch_refresh = _SKETCH_REFRESH_CACHE.get(key)
        if sketch_refresh is None:
            sketch_refresh = lambda vec: sketch_fn(spec.unflatten(vec))
            sketch_refresh._sketch_fn = sketch_fn   # keep the id() key alive
            _SKETCH_REFRESH_CACHE[key] = sketch_refresh
    policy = pol.make_policy(name, spec, num_clients=num_clients,
                             psa_cfg=psa_cfg, sketch_refresh=sketch_refresh,
                             **kw)
    if mesh is not None:
        return ShardedPolicyServer(policy, params, mesh, rules)
    return PolicyServer(policy, params)
