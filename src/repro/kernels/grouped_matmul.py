"""Grouped member-GEMM Pallas kernel.

One wave of B heterogeneous cohort members executes its dense layers as a
single grouped matmul over the stacked member axis: ``lhs (G, M, K) @ rhs
(G, K, N) -> (G, M, N)``, accumulated in f32 on the MXU. The per-group
``valid`` mask turns ragged bucket padding into exact no-op rows — padded
member slots emit exact zeros regardless of what garbage their padded
params slab holds.

Grid: ``(G, nm, nn, nk)`` with the contraction innermost so each (g, i, j)
output tile is revisited across k-steps and accumulated in a VMEM f32
scratch tile; the finalize step applies the mask and casts to the promoted
input dtype. M pads to a multiple of 8 (f32 sublane), K/N to multiples of
128 (lane) — zero padding is exact under matmul.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.buffer_agg import resolve_interpret

DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 128


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _grouped_matmul_kernel(valid_ref, lhs_ref, rhs_ref, out_ref, acc_ref,
                           *, nk: int):
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = lhs_ref[0].astype(jnp.float32)            # (bm, bk)
    b = rhs_ref[0].astype(jnp.float32)            # (bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kk == nk - 1)
    def _done():
        out_ref[0] = (acc_ref[...] * valid_ref[0, 0]).astype(out_ref.dtype)


def grouped_matmul_pallas(lhs: jnp.ndarray, rhs: jnp.ndarray,
                          valid: Optional[jnp.ndarray] = None, *,
                          block_m: int = DEFAULT_BLOCK_M,
                          block_n: int = DEFAULT_BLOCK_N,
                          block_k: int = DEFAULT_BLOCK_K,
                          interpret: Optional[bool] = None) -> jnp.ndarray:
    """``lhs (G, M, K) @ rhs (G, K, N) -> (G, M, N)``, f32 accumulation.

    ``valid`` is an optional (G,) mask (bool or float); groups with
    ``valid == 0`` produce exact-zero output tiles. Blocks clamp to the
    (padded) problem so tiny smoke shapes are not tiled out to 128^3.
    """
    interpret = resolve_interpret(interpret)
    G, M, K = lhs.shape
    G2, K2, N = rhs.shape
    assert (G, K) == (G2, K2), (lhs.shape, rhs.shape)
    out_dtype = jnp.promote_types(lhs.dtype, rhs.dtype)

    bm = min(block_m, _round_up(M, 8))
    bn = min(block_n, _round_up(N, 128))
    bk = min(block_k, _round_up(K, 128))
    Mp, Np, Kp = _round_up(M, bm), _round_up(N, bn), _round_up(K, bk)
    nm, nn, nk = Mp // bm, Np // bn, Kp // bk

    lp = jnp.pad(lhs, [(0, 0), (0, Mp - M), (0, Kp - K)])
    rp = jnp.pad(rhs, [(0, 0), (0, Kp - K), (0, Np - N)])
    if valid is None:
        v = jnp.ones((G, 1), jnp.float32)
    else:
        v = valid.astype(jnp.float32).reshape(G, 1)

    out = pl.pallas_call(
        functools.partial(_grouped_matmul_kernel, nk=nk),
        grid=(G, nm, nn, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda g, i, j, kk: (g, 0)),
            pl.BlockSpec((1, bm, bk), lambda g, i, j, kk: (g, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda g, i, j, kk: (g, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, i, j, kk: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((G, Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(v, lp, rp)
    return out[:, :M, :N]
