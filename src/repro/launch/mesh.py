"""Production meshes and per-architecture sharding-rule resolution.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): single-pod (data=16, model=16) = 256 chips, multi-pod
(pod=2, data=16, model=16) = 512 chips.

``rules_for(cfg, mesh, global_batch)`` resolves the MaxText-style logical
rules against the concrete architecture: any logical axis whose tensor
dimension does not divide its mesh-axis product falls back to replication,
with one targeted upgrade — when an arch's head counts don't divide the
model axis (xlstm 4H, phi4 24H, arctic 56H, internvl2 14H) but head_dim
does, attention/recurrent tensor parallelism moves to the head_dim axis.
This is how every assigned architecture lowers on the same mesh without
per-arch hand-written specs.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

import jax
import numpy as np
from jax.sharding import Mesh

from repro.common.sharding import (EXPERT_TP_RULES, PRODUCTION_RULES,
                                   LogicalRules)
from repro.models.config import ModelConfig


def make_fed_mesh(num_devices: Optional[int] = None, axis: str = "d") -> Mesh:
    """One-axis mesh for the federated policy server / cohort engine.

    The federated stack shards exactly one thing — the flat ``(d,)``
    parameter axis of ``ServerState`` (and, data-parallel, the client axis
    of a completion wave) — so its mesh is a single named axis over however
    many devices are available (or the first ``num_devices`` of them). On a
    CPU box, virtual devices come from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    devices = jax.devices()
    n = len(devices) if num_devices is None else int(num_devices)
    if not 1 <= n <= len(devices):
        raise ValueError(
            f"make_fed_mesh: asked for {n} devices, have {len(devices)} "
            f"(on CPU set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n})")
    return Mesh(np.asarray(devices[:n]), (axis,))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(devices, axes)


def axis_dims(cfg: ModelConfig, global_batch: Optional[int] = None) -> Dict[str, List[int]]:
    """Every concrete tensor dimension each logical axis annotates, per arch.
    Used to verify divisibility before assigning a mesh axis."""
    dims: Dict[str, List[int]] = {
        "embed": [cfg.d_model],
        "heads": [cfg.num_heads],
        "kv_heads": [cfg.num_kv_heads],
        "head_dim": [cfg.head_dim] if cfg.head_dim else [],
        "vocab": [cfg.vocab_padded],
        "mlp": [],
        "expert": [],
        "expert_mlp": [],
        "ssm_inner": [],
    }
    if "dense" in cfg.ffn_pattern or cfg.d_ff:
        dims["mlp"].append(cfg.d_ff)
    if cfg.num_shared_experts:
        dims["mlp"].append(cfg.shared_d_ff or cfg.num_shared_experts * cfg.moe_d_ff)
    if cfg.num_experts:
        dims["expert"].append(cfg.num_experts)
        dims["expert_mlp"].append(cfg.moe_d_ff)
    if "mamba" in cfg.block_pattern:
        dims["ssm_inner"] += [cfg.ssm_inner, 2 * cfg.ssm_inner]
    if "mlstm" in cfg.block_pattern:
        inner = int(cfg.d_model * cfg.mlstm_proj_factor)
        dims["ssm_inner"] += [inner, 2 * inner]
        dims["heads"].append(cfg.num_heads)
        dims["head_dim"].append(inner // cfg.num_heads)
    if "slstm" in cfg.block_pattern:
        dims["mlp"].append(cfg.slstm_ffn_dim)
        dims["head_dim"].append(cfg.d_model // cfg.num_heads)
    if global_batch is not None:
        dims["batch"] = [global_batch]
        dims["tokens"] = [global_batch]  # token arrays lead with batch too
    return {k: [d for d in v if d] for k, v in dims.items()}


def _nshards(mesh: Mesh, assign) -> int:
    if assign is None:
        return 1
    axes = assign if isinstance(assign, (list, tuple)) else (assign,)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(np.prod([sizes[a] for a in axes]))


def rules_for(cfg: ModelConfig, mesh: Mesh,
              global_batch: Optional[int] = None) -> LogicalRules:
    world = int(np.prod(mesh.devices.shape))
    if cfg.pure_data_parallel and global_batch and global_batch >= world:
        # pure DP only pays off when every chip gets >= 1 sequence; the
        # small-batch inference shapes fall back to the standard rules
        return _pure_dp_rules(mesh, global_batch)
    base = EXPERT_TP_RULES if cfg.expert_tensor_parallel else PRODUCTION_RULES
    rules = dict(base.rules)
    # the pod axis only exists on the multi-pod mesh
    present = set(mesh.axis_names)
    for name, assign in list(rules.items()):
        if assign is None:
            continue
        axes = assign if isinstance(assign, (list, tuple)) else (assign,)
        kept = tuple(a for a in axes if a in present)
        rules[name] = kept if len(kept) > 1 else (kept[0] if kept else None)

    dims = axis_dims(cfg, global_batch)
    dropped = set()
    for name, sizes in dims.items():
        assign = rules.get(name)
        if assign is None or not sizes:
            continue
        ns = _nshards(mesh, assign)
        if any(d % ns for d in sizes):
            rules[name] = None
            dropped.add(name)

    # Targeted fallback: heads-based TP impossible -> head_dim TP, but ONLY
    # for recurrent mixers. For softmax attention, sharding head_dim makes
    # every score block contract a sharded dim -> a per-(q,kv)-chunk
    # all-reduce of the f32 probability block (measured: the single largest
    # ICI term on internvl2/phi4/arctic). Those archs instead run attention
    # replicated over `model` (batch-parallel only) — see EXPERIMENTS.md §Perf.
    if "heads" in dropped and "attn" not in cfg.block_pattern:
        hd_sizes = dims.get("head_dim", [])
        ns = _nshards(mesh, base.rules.get("heads"))
        if hd_sizes and all(d % ns == 0 for d in hd_sizes):
            rules["head_dim"] = base.rules.get("heads")

    # Decode KV caches: when kv-head TP is impossible, shard the cache over
    # its sequence dim — decode attention reduces over it with only
    # (B, H)-sized softmax-stat collectives instead of replicating the cache.
    if rules.get("kv_heads") is None and "attn" in cfg.block_pattern:
        rules["cache_seq"] = "model" if "model" in present else None
    return LogicalRules(rules)


def dims_conflict(cfg: ModelConfig) -> set:
    """Logical axes that must stay replicated for this arch (reserved)."""
    return set()


def _pure_dp_rules(mesh: Mesh, global_batch: Optional[int]) -> LogicalRules:
    """All weights replicated; batch sharded over the largest axis prefix
    whose product divides it (gradients sync with one all-reduce)."""
    names = list(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    best: list = []
    best_prod = 1
    for i in range(len(names)):
        for j in range(i + 1, len(names) + 1):
            trial = names[i:j]
            prod = int(np.prod([sizes[a] for a in trial]))
            if (global_batch is None or global_batch % prod == 0) and prod > best_prod:
                best, best_prod = trial, prod
    assign = tuple(best) if len(best) > 1 else (best[0] if best else None)
    rules = {k: None for k in PRODUCTION_RULES.rules}
    rules["batch"] = assign
    rules["tokens"] = assign
    return LogicalRules(rules)


def describe_rules(cfg: ModelConfig, mesh: Mesh, global_batch=None) -> str:
    r = rules_for(cfg, mesh, global_batch)
    return "\n".join(f"  {k:16s} -> {v}" for k, v in sorted(r.rules.items()))
