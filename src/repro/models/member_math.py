"""Member-math routing: one seam for every dense layer a cohort member runs.

The cohort engines execute B members as ``vmap(member)``; XLA CPU lowers the
batched per-member GEMMs to B independent dots it never vectorizes (the
ROADMAP "accelerator-true hot path" item). ``member_dot`` is the seam that
fixes this: model code calls it for every dense contraction, and the active
routing mode decides what the batched program looks like.

* ``"vmap"`` (default): a plain ``lax.dot_general`` — the identical HLO the
  previous einsum call sites produced, so the golden digest streams are
  untouched bit for bit.
* ``"grouped"``: a custom ``member_dot2d`` primitive whose batching rule
  collapses the member (and lane) axes into the group axis of the Pallas
  grouped-GEMM kernel (``kernels/grouped_matmul.py``) — one wave of
  heterogeneous members' layers executes as one grouped kernel launch.

Autodiff happens *inside* the member vmap (each member runs ``jax.grad`` of
its local loss), so JVP/transpose rules live on the 2-D primitive and the
binds they emit are batched afterwards; the grouped primitive still carries
its own bilinear rules for robustness. The mode is a trace-time switch
(``routing(...)`` context entered inside the traced member body); the cohort
run caches key on it, so each mode traces exactly once.
"""
from __future__ import annotations

import contextlib
import math

import jax
import jax.numpy as jnp
from jax.core import ShapedArray
from jax.extend.core import Primitive
from jax.interpreters import ad, batching, mlir

from repro.kernels.grouped_matmul import grouped_matmul_pallas

_MODE = "vmap"
MODES = ("vmap", "grouped")


@contextlib.contextmanager
def routing(mode: str):
    """Trace-time member-math mode; enter inside the function being traced."""
    if mode not in MODES:
        raise ValueError(f"member_kernel must be one of {MODES}, got {mode!r}")
    global _MODE
    prev, _MODE = _MODE, mode
    try:
        yield
    finally:
        _MODE = prev


def current_mode() -> str:
    return _MODE


# --- 2-D primitive: (M, K) @ (K, N) as seen by one (unbatched) member -----

member_dot_p = Primitive("member_dot2d")


def _dot2d(x, w):
    return jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())))


def _dot2d_abstract(x, w):
    assert x.ndim == 2 and w.ndim == 2 and x.shape[1] == w.shape[0], \
        (x.shape, w.shape)
    return ShapedArray((x.shape[0], w.shape[1]),
                       jnp.promote_types(x.dtype, w.dtype))


member_dot_p.def_impl(_dot2d)
member_dot_p.def_abstract_eval(_dot2d_abstract)
mlir.register_lowering(member_dot_p,
                       mlir.lower_fun(_dot2d, multiple_results=False))
ad.defbilinear(member_dot_p,
               lambda ct, x, w: member_dot_p.bind(ct, w.T),
               lambda ct, x, w: member_dot_p.bind(x.T, ct))


def _dot2d_batch(args, dims):
    x, w = args
    xd, wd = dims
    if wd is None:
        # shared weights across the batch: one big (G*M, K) @ (K, N) dot
        x = jnp.moveaxis(x, xd, 0)
        g, m, k = x.shape
        out = member_dot_p.bind(x.reshape(g * m, k), w)
        return out.reshape(g, m, w.shape[1]), 0
    if xd is None:
        w = jnp.moveaxis(w, wd, 0)
        x = jnp.broadcast_to(x, (w.shape[0],) + x.shape)
    else:
        x = jnp.moveaxis(x, xd, 0)
        w = jnp.moveaxis(w, wd, 0)
    return grouped_dot_p.bind(x, w), 0


batching.primitive_batchers[member_dot_p] = _dot2d_batch


# --- grouped primitive: (G, M, K) @ (G, K, N), lowered to the Pallas kernel

grouped_dot_p = Primitive("member_dot_grouped")


def _grouped_impl(x, w):
    return grouped_matmul_pallas(x, w)


def _grouped_abstract(x, w):
    assert x.ndim == 3 and w.ndim == 3 and x.shape[0] == w.shape[0] \
        and x.shape[2] == w.shape[1], (x.shape, w.shape)
    return ShapedArray((x.shape[0], x.shape[1], w.shape[2]),
                       jnp.promote_types(x.dtype, w.dtype))


grouped_dot_p.def_impl(_grouped_impl)
grouped_dot_p.def_abstract_eval(_grouped_abstract)
mlir.register_lowering(grouped_dot_p,
                       mlir.lower_fun(_grouped_impl, multiple_results=False))
ad.defbilinear(grouped_dot_p,
               lambda ct, x, w: grouped_dot_p.bind(ct, jnp.swapaxes(w, 1, 2)),
               lambda ct, x, w: grouped_dot_p.bind(jnp.swapaxes(x, 1, 2), ct))


def _grouped_batch(args, dims):
    # a further vmap (the sweep lane axis) folds into the group axis
    x, w = args
    xd, wd = dims
    if xd is None:
        w = jnp.moveaxis(w, wd, 0)
        x = jnp.broadcast_to(x, (w.shape[0],) + x.shape)
    elif wd is None:
        x = jnp.moveaxis(x, xd, 0)
        w = jnp.broadcast_to(w, (x.shape[0],) + w.shape)
    else:
        x = jnp.moveaxis(x, xd, 0)
        w = jnp.moveaxis(w, wd, 0)
    lanes, g = x.shape[:2]
    out = grouped_dot_p.bind(x.reshape((lanes * g,) + x.shape[2:]),
                             w.reshape((lanes * g,) + w.shape[2:]))
    return out.reshape((lanes, g) + out.shape[1:]), 0


batching.primitive_batchers[grouped_dot_p] = _grouped_batch


# --- public seam ----------------------------------------------------------

def member_dot(x: jnp.ndarray, w: jnp.ndarray, ncon: int = 1) -> jnp.ndarray:
    """Contract the last ``ncon`` axes of ``x`` with the first ``ncon`` of
    ``w`` (output = x-free axes ++ w-free axes, exactly the einsum the call
    sites used to spell). Routes by the active member-math mode."""
    if x.dtype != w.dtype:
        common = jnp.promote_types(x.dtype, w.dtype)
        x, w = x.astype(common), w.astype(common)
    if _MODE == "vmap":
        lhs_c = tuple(range(x.ndim - ncon, x.ndim))
        rhs_c = tuple(range(ncon))
        return jax.lax.dot_general(x, w, ((lhs_c, rhs_c), ((), ())))
    batch_shape = x.shape[:-ncon]
    m = math.prod(batch_shape)
    k = math.prod(x.shape[-ncon:])
    n = math.prod(w.shape[ncon:])
    out = member_dot_p.bind(x.reshape(m, k), w.reshape(k, n))
    return out.reshape(batch_shape + w.shape[ncon:])
