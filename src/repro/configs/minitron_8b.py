"""minitron-8b [dense] — pruned nemotron [arXiv:2407.14679].

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000. Nemotron uses
squared-ReLU MLPs (no gate); the 256k vocabulary dominates the embedding
footprint, so the unembedding/loss path is vocab-sharded + seq-chunked.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    block_pattern=("attn",),
    ffn_pattern=("dense",),
    ffn_act="relu2",
    long_context_window=8192,
)
