"""Paper Table 6: component ablation — w/o T (thermometer), w/o S
(sensitivity; raw-parameter sketch instead), w/o T&S, vs Full, under IID
(alpha=1 ~ the paper's IID) and non-IID (alpha=0.1), at concurrency p.

The thermometer switch is a traced per-lane hyperparameter
(``use_thermometer``), so {full, wo_T} run as lanes of ONE batched
simulation; ``use_sensitivity`` changes the client sketch PROGRAM (a
structural parameter), so {wo_S, wo_TS} form a second two-lane sweep.
Each (alpha, concurrency) cell therefore costs two compiled sweeps instead
of four python-driven re-runs. alpha and p reshape the world/timeline and
legitimately stay python loops.
"""
from __future__ import annotations

import sys

from repro.core import PSAConfig
from repro.federated import SweepConfig
from benchmarks import common

# lanes grouped by the structural use_sensitivity flag
GROUPS = [
    (PSAConfig(), (("full", None),
                   ("wo_T", {"use_thermometer": False}))),
    (PSAConfig(use_sensitivity=False), (("wo_S", None),
                                        ("wo_TS", {"use_thermometer": False}))),
]
CONCURRENCY_FULL = (0.1, 0.2, 0.3)
CONCURRENCY_FAST = (0.2,)


def main(argv=None):
    ps = CONCURRENCY_FULL if common.FULL else CONCURRENCY_FAST
    # the thermometer only differentiates once updates shrink (late stage):
    # the ablation needs a longer horizon than the accuracy tables
    horizon = common.HORIZON if common.FULL else 70_000.0
    rows = {}
    for alpha, tag in ((1.0, "iid"), (0.1, "niid")):
        for p in ps:
            for psa, variants in GROUPS:
                sim = common.sim_config(concurrency=p, horizon=horizon,
                                        eval_every=horizon / 5)
                sweep = SweepConfig(policy_params=[h for _, h in variants])
                res = common.sweep_cell("fedpsa", alpha, sweep, sim=sim,
                                        psa=psa)
                for (name, _), acc in zip(variants, res.final_accuracy):
                    rows[f"{name}@{tag}_p{p}"] = acc
                    print(f"t6,{name},{tag},p={p},{acc:.4f}")
    common.save("t6_ablation", rows)
    for p in ps:
        full_ = rows[f"full@niid_p{p}"]
        worst = min(rows[f"{v}@niid_p{p}"] for v in ("wo_T", "wo_S", "wo_TS"))
        print(f"t6,full_minus_worst_ablation_niid_p{p},{full_ - worst:+.4f}")
    return rows


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
