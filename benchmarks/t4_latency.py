"""Paper Table 4: robustness to system heterogeneity.

FedBuff / CA2FL / FedPSA under uniform + long-tail latency at increasing
scales (10-500, 20-1000, 50-2500). The claim: FedPSA degrades least as
response times grow, because behavioral staleness does not dilate with
wall-clock delay the way round-gap staleness does.
"""
from __future__ import annotations

import sys

from benchmarks import common

ALGS = ("fedbuff", "ca2fl", "fedpsa")
SETTINGS_FULL = [("uniform", 10, 500), ("longtail", 10, 500),
                 ("uniform", 20, 1000), ("longtail", 20, 1000),
                 ("uniform", 50, 2500), ("longtail", 50, 2500)]
SETTINGS_FAST = [("uniform", 10, 500), ("uniform", 50, 2500),
                 ("longtail", 10, 500), ("longtail", 50, 2500)]


def main(argv=None):
    settings = SETTINGS_FULL if common.FULL else SETTINGS_FAST
    rows = {}
    for kind, lo, hi in settings:
        for alg in ALGS:
            sim = common.sim_config(latency_kind=kind, latency_lo=lo,
                                    latency_hi=hi)
            res = common.run_cell(alg, 0.1, sim=sim)
            rows[f"{alg}@{kind}{lo}-{hi}"] = res.final_accuracy
            print(f"t4,{alg},{kind}{lo}-{hi},{res.final_accuracy:.4f}")
    common.save("t4_latency", rows)
    # degradation uniform 10-500 -> 50-2500 per algorithm
    for alg in ALGS:
        a, b = rows.get(f"{alg}@uniform10-500"), rows.get(f"{alg}@uniform50-2500")
        if a is not None and b is not None:
            print(f"t4,degradation_{alg},{a - b:+.4f}")
    return rows


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
