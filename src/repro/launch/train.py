"""Federated training driver (the paper's experiment runner).

    PYTHONPATH=src python -m repro.launch.train \
        --alg fedpsa --model paper-synthetic-mlp --alpha 0.1 \
        --clients 50 --horizon 86400 --out artifacts/runs

Runs one (algorithm x Dirichlet-alpha x latency setting) cell of the paper's
tables on the synthetic stand-in datasets and writes the learning curve +
summary JSON. ``--arch`` accepts any architecture id whose family is in the
model-family registry: cnn/mlp train the paper's classification worlds,
token families (dense/ssm/moe/hybrid — e.g. ``--arch fed-lm-smoke``, or any
assigned arch's ``-smoke`` reduction) train the federated LM fine-tuning
scenario on a document-partitioned synthetic corpus. The full-scale configs
are exercised by the dry-run, not by CPU training.

``--sweep seeds=0,1,2`` (or ``--sweep alpha=0.3,0.6,0.9`` etc.) runs the
variants as lanes of ONE batched simulation over a shared event timeline
(``run_sweep``), printing per-lane and mean±std accuracy.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import PSAConfig
from repro.data import (ClientDataset, dirichlet_partition,
                        document_partition, iid_partition,
                        make_calibration_batch, make_classification,
                        make_lm_corpus, train_test_split)
from repro.data.synthetic import SyntheticClassification
from repro.federated import (SimConfig, SweepConfig, run_algorithm,
                             run_sweep, ALGORITHMS)
from repro.models import model as model_lib
from repro.models import registry


def build_lm_task(cfg, num_samples: int, alpha: float, num_clients: int,
                  seed: int, calib_source: str = "gaussian",
                  seq_len: int = 32):
    """The federated LM fine-tuning world: a synthetic bigram corpus,
    document-partitioned across clients (Dirichlet-skewed shard sizes when
    ``alpha > 0``), chopped into ``(n_i, seq_len)`` token sequences; the
    held-out HEAD of the corpus (its first ``n_test`` sequences) is the
    next-token-accuracy test set and the remainder is partitioned for
    training. ``num_samples`` counts sequences across train + test."""
    n_test = max(2, num_samples // 10)
    doc_len = 4 * seq_len
    corpus = make_lm_corpus((num_samples - n_test) * seq_len + doc_len
                            + n_test * seq_len,
                            vocab=cfg.vocab_size, seed=seed)
    test_toks = corpus[:n_test * seq_len].reshape(n_test, seq_len)
    test = SyntheticClassification(x=test_toks, y=test_toks,
                                   num_classes=cfg.vocab_size)
    parts = document_partition(corpus[n_test * seq_len:], num_clients,
                               seq_len, doc_len=doc_len, alpha=alpha,
                               seed=seed)
    clients = [ClientDataset(SyntheticClassification(x=p, y=p,
                                                     num_classes=cfg.vocab_size))
               for p in parts]
    calib = make_calibration_batch(test, 8, calib_source)
    return cfg, clients, test, calib


def build_task(model_name: str, num_samples: int, alpha: float, num_clients: int,
               seed: int, calib_source: str = "gaussian", seq_len: int = 32):
    cfg = get_config(model_name)
    if cfg.family == "cnn":
        hw = cfg.input_hw
        full = make_classification(num_samples, cfg.num_classes,
                                   image_hw=hw, seed=seed, class_sep=0.7)
    elif cfg.family == "mlp":
        full = make_classification(num_samples, cfg.num_classes,
                                   dim=cfg.input_hw[0], seed=seed, class_sep=0.7)
    elif (registry.is_registered(cfg.family)
          and registry.get_family(cfg).data_kind == "tokens"):
        return build_lm_task(cfg, num_samples, alpha, num_clients, seed,
                             calib_source, seq_len)
    else:
        raise ValueError(
            f"{model_name}: family {cfg.family!r} has no federated data "
            f"path (registered families train via the registry; audio/vlm "
            f"archs are exercised via the dry-run)")
    train, test = train_test_split(full, 0.1)
    if alpha <= 0:
        parts = iid_partition(train, num_clients, seed)
    else:
        parts = dirichlet_partition(train, num_clients, alpha, seed)
    clients = [ClientDataset(train.subset(ix)) for ix in parts]
    calib = make_calibration_batch(train, 64, calib_source)
    return cfg, clients, test, calib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--alg", default="fedpsa", choices=ALGORITHMS)
    ap.add_argument("--arch", "--model", dest="model",
                    default="paper-synthetic-mlp",
                    help="architecture registry id; any family in the "
                         "model-family registry trains (token families get "
                         "the federated LM scenario, e.g. fed-lm-smoke)")
    ap.add_argument("--alpha", type=float, default=0.1,
                    help="Dirichlet alpha; <=0 for IID")
    ap.add_argument("--clients", type=int, default=50)
    ap.add_argument("--concurrency", type=float, default=0.2)
    ap.add_argument("--horizon", type=float, default=86_400)
    ap.add_argument("--samples", type=int, default=10_000,
                    help="total samples (image) or sequences (token tasks)")
    ap.add_argument("--seq", type=int, default=32,
                    help="sequence length for token (LM) tasks")
    ap.add_argument("--engine", default="cohort",
                    choices=["cohort", "sequential"])
    ap.add_argument("--latency", default="uniform",
                    choices=["uniform", "longtail", "lognormal"])
    ap.add_argument("--lat-lo", type=float, default=10)
    ap.add_argument("--lat-hi", type=float, default=500)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--calib", default="gaussian", choices=["gaussian", "real"])
    ap.add_argument("--buffer", type=int, default=5)
    ap.add_argument("--queue", type=int, default=50)
    ap.add_argument("--gamma", type=float, default=5.0)
    ap.add_argument("--delta", type=float, default=0.5)
    ap.add_argument("--sketch-k", type=int, default=16)
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="shard the policy server (and train waves "
                         "data-parallel) over an N-device mesh; on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    ap.add_argument("--sweep", default=None, metavar="SPEC",
                    help="run S variants as ONE batched simulation "
                         "(run_sweep; lanes share the event timeline). "
                         "SPEC is either 'seeds=0,1,2' (per-lane model+"
                         "shuffle seeds) or a policy hyperparameter grid "
                         "like 'alpha=0.3,0.6,0.9' or "
                         "'gamma=0.1,1,5' (PolicyParams field names)")
    ap.add_argument("--out", default="artifacts/runs")
    args = ap.parse_args()

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_fed_mesh
        mesh = make_fed_mesh(args.mesh)
    cfg, clients, test, calib = build_task(
        args.model, args.samples, args.alpha, args.clients, args.seed,
        args.calib, seq_len=args.seq)
    params = model_lib.init_params(jax.random.PRNGKey(args.seed), cfg)
    sim = SimConfig(num_clients=args.clients, concurrency=args.concurrency,
                    horizon=args.horizon, latency_kind=args.latency,
                    latency_lo=args.lat_lo, latency_hi=args.lat_hi,
                    seed=args.seed, engine=args.engine, mesh=mesh)
    psa = PSAConfig(buffer_size=args.buffer, queue_len=args.queue,
                    gamma=args.gamma, delta=args.delta, sketch_k=args.sketch_k)
    os.makedirs(args.out, exist_ok=True)
    name = f"{args.alg}_{args.model}_a{args.alpha}_{args.latency}{int(args.lat_hi)}_s{args.seed}"
    if args.mesh:
        name += f"_mesh{args.mesh}"

    if args.sweep:
        key, _, vals = args.sweep.partition("=")
        if not vals:
            raise SystemExit("--sweep wants 'seeds=...' or '<hyper>=v1,v2'")
        if key == "seeds":
            seeds = [int(v) for v in vals.split(",")]
            sweep = SweepConfig(model_seeds=seeds, data_seeds=seeds)
            lane_tags = [f"seed{s}" for s in seeds]
        else:
            grid = [float(v) for v in vals.split(",")]
            sweep = SweepConfig(policy_params=[{key: v} for v in grid])
            lane_tags = [f"{key}{v:g}" for v in grid]
        t0 = time.time()
        res = run_sweep(args.alg, cfg, params, clients, test, sim, sweep,
                        psa_cfg=psa, calib_batch=calib)
        wall = time.time() - t0
        mean, std = res.accuracy_mean_std()
        rec = {
            "alg": args.alg, "model": args.model, "alpha": args.alpha,
            "latency": [args.latency, args.lat_lo, args.lat_hi],
            "sweep": args.sweep, "lanes": lane_tags,
            "final_accuracy": res.final_accuracy, "aulc": res.aulc,
            "final_accuracy_mean": mean, "final_accuracy_std": std,
            "versions": res.versions, "dispatches": res.dispatches,
            "times": res.times, "lane_accuracies": res.lane_accuracies,
            "wall_s": round(wall, 1), "engine": res.engine,
        }
        name += f"_sweep-{key}{len(lane_tags)}"
        path = os.path.join(args.out, name + ".json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        for tag, acc in zip(lane_tags, res.final_accuracy):
            print(f"[train]   lane {tag}: final={acc:.4f}")
        print(f"[train] {name}: mean={mean:.4f}±{std:.4f} ({wall:.0f}s, "
              f"one batched simulation) -> {path}")
        return

    t0 = time.time()
    res = run_algorithm(args.alg, cfg, params, clients, test, sim,
                        psa_cfg=psa, calib_batch=calib)
    wall = time.time() - t0
    rec = {
        "alg": args.alg, "model": args.model, "alpha": args.alpha,
        "latency": [args.latency, args.lat_lo, args.lat_hi],
        "final_accuracy": res.final_accuracy, "aulc": res.aulc,
        "versions": res.versions, "dispatches": res.dispatches,
        "times": res.times, "accuracies": res.accuracies,
        "wall_s": round(wall, 1), "mesh_devices": args.mesh or None,
        "engine": res.engine,
    }
    path = os.path.join(args.out, name + ".json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[train] {name}: final={res.final_accuracy:.4f} aulc={res.aulc:.4f} "
          f"({wall:.0f}s) -> {path}")


if __name__ == "__main__":
    main()
