"""MoE dispatch path vs the dropless oracle + router invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.sharding import SINGLE_DEVICE_RULES as R
from repro.configs import get_config
from repro.models import moe
from repro.models.config import ModelConfig


def _moe_cfg(E=4, K=2, cf=10.0, shared=0, name="test-moe"):
    return ModelConfig(
        name=name, family="moe", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=0, vocab_size=64, block_pattern=("attn",),
        ffn_pattern=("moe",), num_experts=E, top_k=K, moe_d_ff=16,
        capacity_factor=cf, num_shared_experts=shared,
        shared_d_ff=48 if shared else 0,
        dtype="float32", param_dtype="float32", remat="none")


@pytest.mark.parametrize("E,K,shared", [(4, 2, 0), (8, 2, 0), (4, 1, 1), (6, 4, 2)])
def test_dispatch_equals_dropless_with_lossless_capacity(E, K, shared):
    cfg = _moe_cfg(E=E, K=K, cf=float(E) / K, shared=shared)
    key = jax.random.PRNGKey(0)
    params = moe.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model))
    y1, aux1 = moe.moe_forward(params, x, cfg, R)
    y2, aux2 = moe.moe_forward_dense(params, x, cfg, R)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-5)


def test_capacity_drops_tokens():
    """With capacity factor << 1 most expert slots overflow; output energy
    must drop versus the dropless path (never increase)."""
    cfg = _moe_cfg(E=4, K=2, cf=0.1)
    key = jax.random.PRNGKey(1)
    params = moe.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, cfg.d_model))
    y1, _ = moe.moe_forward(params, x, cfg, R)
    y2, _ = moe.moe_forward_dense(params, x, cfg, R)
    assert float(jnp.sum(jnp.square(y1))) < float(jnp.sum(jnp.square(y2)))


def test_aux_loss_bounds():
    """Switch aux loss = coef * E * sum(f_e * P_e) >= coef (perfect balance)."""
    cfg = _moe_cfg(E=8, K=2)
    key = jax.random.PRNGKey(2)
    params = moe.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 3), (4, 64, cfg.d_model))
    _, aux = moe.moe_forward_dense(params, x, cfg, R)
    coef = cfg.router_aux_coef
    # K choices per token: sum_e f_e = K, so minimum is coef*K under balance
    assert float(aux) >= coef * cfg.top_k * 0.5
    assert float(aux) < coef * cfg.top_k * cfg.num_experts


def test_qwen_renormalization():
    cfg = dataclasses.replace(_moe_cfg(E=4, K=2, cf=2.0), name="qwen2-moe-test")
    key = jax.random.PRNGKey(4)
    params = moe.init_moe(key, cfg)
    x = jax.random.normal(key, (1, 4, cfg.d_model))
    y1, _ = moe.moe_forward(params, x, cfg, R)
    y2, _ = moe.moe_forward_dense(params, x, cfg, R)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)


def test_assigned_moe_configs_capacity():
    for arch in ("qwen2-moe-a2.7b", "arctic-480b", "jamba-v0.1-52b"):
        cfg = get_config(arch)
        c = moe.moe_capacity(cfg, 1024)
        assert c >= 1
        assert c * cfg.num_experts >= cfg.top_k * 1024  # cf >= 1 configs
