"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16) per-expert d_ff=1408 vocab=151936, 60 experts
top-4 with renormalized gates plus 4 always-on shared experts (shared path
d_ff = 4*1408 = 5632). 60 experts do not divide the 16-way model axis, so
this config uses expert-tensor-parallel sharding: experts replicated, the
per-expert hidden dim (1408 = 16*88) sharded over ``model``.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    block_pattern=("attn",),
    ffn_pattern=("moe",),
    num_experts=60,
    top_k=4,
    num_shared_experts=4,
    moe_d_ff=1408,
    shared_d_ff=5632,
    expert_tensor_parallel=True,
    # §Perf opt: GShard group-local dispatch (16 groups = data shards) —
    # collective term 230.7s -> 14.1s (16.4x)
    dispatch_groups=16,
    long_context_window=8192,
)
