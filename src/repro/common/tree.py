"""Pytree arithmetic used across the framework.

Everything here is jit-friendly (pure jnp) and works on arbitrary nested
dict/list/tuple pytrees of arrays — the framework's parameters, updates and
optimizer states are all plain pytrees (no flax dependency).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y."""
    return jax.tree_util.tree_map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_zeros_like(a):
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def tree_dot(a, b):
    """Sum of elementwise products across the whole pytree (float32 accum)."""
    leaves = jax.tree_util.tree_map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return jax.tree_util.tree_reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_sq_norm(a):
    """Squared l2 norm of the flattened pytree (Eq. 16 of the paper)."""
    leaves = jax.tree_util.tree_map(
        lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), a
    )
    return jax.tree_util.tree_reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_norm(a):
    return jnp.sqrt(tree_sq_norm(a))


def tree_size(a) -> int:
    """Total number of scalar parameters (static python int)."""
    return int(sum(np.prod(x.shape) for x in jax.tree_util.tree_leaves(a)))


def tree_weighted_sum(trees, weights):
    """sum_i weights[i] * trees[i] for a list of pytrees.

    `weights` may be a jnp vector (traced) of length len(trees).
    """
    def leaf_sum(*leaves):
        stacked = jnp.stack([l.astype(jnp.float32) for l in leaves], axis=0)
        w = weights.astype(jnp.float32).reshape((-1,) + (1,) * (stacked.ndim - 1))
        return jnp.sum(stacked * w, axis=0).astype(leaves[0].dtype)

    return jax.tree_util.tree_map(leaf_sum, *trees)


def tree_cast(a, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, a
    )


def tree_all_finite(a):
    leaves = jax.tree_util.tree_map(lambda x: jnp.all(jnp.isfinite(x)), a)
    return jax.tree_util.tree_reduce(jnp.logical_and, leaves, jnp.bool_(True))


def flatten_to_vector(a):
    """Concatenate all leaves to a single f32 vector. Returns (vec, unflatten)."""
    leaves, treedef = jax.tree_util.tree_flatten(a)
    shapes = [l.shape for l in leaves]
    dtypes = [l.dtype for l in leaves]
    vec = jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves])

    def unflatten(v):
        out, off = [], 0
        for shp, dt in zip(shapes, dtypes):
            n = int(np.prod(shp)) if shp else 1
            out.append(v[off : off + n].reshape(shp).astype(dt))
            off += n
        return jax.tree_util.tree_unflatten(treedef, out)

    return vec, unflatten


def unflatten_from_vector(vec, like):
    """Reshape a flat vector into the structure of `like`."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out, off = [], 0
    for l in leaves:
        n = int(np.prod(l.shape)) if l.shape else 1
        out.append(vec[off : off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def ring_update(data, row, count):
    """Write ``row`` into the ring slot ``count % capacity`` of the stacked
    buffer ``data`` (leading axis = capacity). The single ring-write used by
    every fixed-size buffer in the server core."""
    slot = jnp.mod(count, data.shape[0])
    return jax.lax.dynamic_update_index_in_dim(data, row, slot, axis=0), slot


class FlatSpec:
    """Flatten-once descriptor of a pytree's flat f32 layout.

    Built once from a template tree; afterwards ``flatten``/``unflatten`` are
    pure shape/offset arithmetic (static under jit, no re-walking of python
    structure per call). This is the parameter layout the functional server
    core operates on: a single contiguous ``(d,)`` f32 vector.
    """

    def __init__(self, template):
        leaves, self.treedef = jax.tree_util.tree_flatten(template)
        self.shapes = tuple(l.shape for l in leaves)
        self.dtypes = tuple(l.dtype for l in leaves)
        self.sizes = tuple(int(np.prod(s)) if s else 1 for s in self.shapes)
        self.offsets = tuple(np.cumsum((0,) + self.sizes)[:-1].tolist())
        self.size = int(sum(self.sizes))

    # Two specs of the same layout are interchangeable, so they compare (and
    # hash) by layout. This is what lets jit-compiled artifacts built around
    # a spec be cached across runs that each construct their own FlatSpec.
    def _sig(self):
        return (self.treedef, self.shapes, self.dtypes)

    def __eq__(self, other):
        return isinstance(other, FlatSpec) and self._sig() == other._sig()

    def __hash__(self):
        return hash(self._sig())

    def flatten(self, tree) -> jnp.ndarray:
        """Tree -> contiguous (d,) f32 vector (jit-friendly)."""
        leaves = jax.tree_util.tree_leaves(tree)
        return jnp.concatenate(
            [l.astype(jnp.float32).reshape(-1) for l in leaves])

    def unflatten(self, vec: jnp.ndarray):
        """(d,) vector -> tree with the template's shapes/dtypes."""
        out = [
            jax.lax.dynamic_slice_in_dim(vec, off, n).reshape(shp).astype(dt)
            for off, n, shp, dt in zip(self.offsets, self.sizes,
                                       self.shapes, self.dtypes)
        ]
        return jax.tree_util.tree_unflatten(self.treedef, out)


_JIT_UNFLATTEN_CACHE = {}


def jit_unflatten(spec: "FlatSpec"):
    """Jitted ``spec.unflatten`` shared by every spec with the same layout —
    repeated runs reuse one compiled program instead of recompiling a fresh
    per-run closure."""
    fn = _JIT_UNFLATTEN_CACHE.get(spec)
    if fn is None:
        fn = jax.jit(spec.unflatten)
        _JIT_UNFLATTEN_CACHE[spec] = fn
    return fn
