"""Quickstart: FedPSA vs FedBuff on a non-IID synthetic task in ~1 minute.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the public API end to end: build data -> partition -> pick the
paper's hyperparameters -> run two algorithms -> compare.
"""
import jax

from repro.configs import get_config
from repro.core import PSAConfig
from repro.data import (ClientDataset, dirichlet_partition,
                        make_calibration_batch, make_classification,
                        train_test_split)
from repro.federated import SimConfig, run_algorithm
from repro.models import model as M


def main():
    # 1. Task: synthetic 10-class Gaussian mixture, Dirichlet(0.1) split
    full = make_classification(8_000, num_classes=10, dim=32, seed=0,
                               class_sep=0.7)
    train, test = train_test_split(full, test_frac=0.1)
    parts = dirichlet_partition(train, num_clients=30, alpha=0.1, seed=0)
    clients = [ClientDataset(train.subset(ix)) for ix in parts]

    # 2. Shared calibration batch: pure Gaussian noise (paper Table 5 shows
    #    this matches real data, with zero privacy cost)
    calib = make_calibration_batch(train, batch_size=64, source="gaussian")

    # 3. Model + the paper's hyperparameters
    cfg = get_config("paper-synthetic-mlp")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    sim = SimConfig(num_clients=30, concurrency=0.2, horizon=30_000,
                    eval_every=6_000, seed=0)
    psa = PSAConfig(buffer_size=5, queue_len=50, gamma=5.0, delta=0.5,
                    sketch_k=16)

    # 4. Run FedPSA and the FedBuff baseline
    for alg in ("fedbuff", "fedpsa"):
        res = run_algorithm(alg, cfg, params, clients, test, sim,
                            psa_cfg=psa, calib_batch=calib)
        print(f"{alg:8s} final accuracy {res.final_accuracy:.3f}  "
              f"AULC {res.aulc:.3f}  global updates {res.versions}")


if __name__ == "__main__":
    main()
