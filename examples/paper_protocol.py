"""Paper protocol run: one full cell of Table 2 + the Fig. 6 diagnostic.

    PYTHONPATH=src python examples/paper_protocol.py [--horizon 60000]

50 clients, 20% concurrency, 5 local epochs, batch 64, SGD lr 0.01 with
x0.999 decay, latency ~ U(10, 500) — exactly §6.1 — on the synthetic
CIFAR-10 stand-in, comparing all 7 algorithms at Dirichlet alpha = 0.1,
then inspecting FedPSA's aggregation internals (weights / kappa / Temp).
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core import PSAConfig
from repro.data import (ClientDataset, dirichlet_partition,
                        make_calibration_batch, make_classification,
                        train_test_split)
from repro.federated import SimConfig, run_algorithm, ALGORITHMS
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--horizon", type=float, default=60_000)
    ap.add_argument("--clients", type=int, default=50)
    args = ap.parse_args()

    full = make_classification(10_000, 10, 32, seed=0, class_sep=0.7)
    train, test = train_test_split(full, 0.1)
    parts = dirichlet_partition(train, args.clients, alpha=0.1, seed=0)
    clients = [ClientDataset(train.subset(ix)) for ix in parts]
    calib = make_calibration_batch(train, 64, "gaussian")
    cfg = get_config("paper-synthetic-mlp")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    sim = SimConfig(num_clients=args.clients, concurrency=0.2,
                    horizon=args.horizon, eval_every=10_000, seed=0)

    results = {}
    for alg in ALGORITHMS:
        res = run_algorithm(alg, cfg, params, clients, test, sim,
                            psa_cfg=PSAConfig(), calib_batch=calib)
        results[alg] = res
        print(f"{alg:9s} final={res.final_accuracy:.3f} aulc={res.aulc:.3f} "
              f"updates={res.versions}")

    print("\nTable-2-style ordering at alpha=0.1 "
          "(paper: FedPSA > FedBuff > FedAsync/FedFa):")
    order = sorted(results, key=lambda a: -results[a].final_accuracy)
    print("  " + " > ".join(order))

    psa_log = results["fedpsa"].server_log
    temps = [e["temp"] for e in psa_log if e["temp"] is not None]
    if temps:
        print(f"\nFedPSA thermometer: Temp first={temps[0]:.2f} "
              f"last={temps[-1]:.2f} (cooling => sharper softmax late)")
    kappas = np.concatenate([e["kappas"] for e in psa_log])
    print(f"kappa over run: mean={kappas.mean():.3f} min={kappas.min():.3f} "
          f"max={kappas.max():.3f}")


if __name__ == "__main__":
    main()
