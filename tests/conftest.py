import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_collection_modifyitems(config, items):
    """Tiering: everything that is neither ``slow`` nor ``multidevice`` is
    the ``tier1`` gate; ``multidevice`` tests auto-skip on single-device
    hosts (CI's second matrix entry forces 4 virtual CPU devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``)."""
    import jax

    ndev = jax.device_count()
    skip_multi = pytest.mark.skip(
        reason=f"needs >= 2 jax devices, have {ndev} (set XLA_FLAGS="
               "--xla_force_host_platform_device_count=4)")
    for item in items:
        multi = "multidevice" in item.keywords
        if multi and ndev < 2:
            item.add_marker(skip_multi)
        if not multi and "slow" not in item.keywords:
            item.add_marker(pytest.mark.tier1)
