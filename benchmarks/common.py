"""Shared benchmark harness: builds the paper's experimental worlds.

FAST mode (default, used by ``benchmarks.run``) shrinks horizons so the full
suite completes on one CPU core; BENCH_FULL=1 restores paper-scale horizons
(10 virtual days). Results are written as JSON under artifacts/bench/.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs import get_config
from repro.core import PSAConfig
from repro.data import (ClientDataset, dirichlet_partition, iid_partition,
                        make_calibration_batch, make_classification,
                        train_test_split)
from repro.federated import SimConfig, SweepConfig, run_algorithm, run_sweep
from repro.models import model as model_lib

FULL = os.environ.get("BENCH_FULL", "0") == "1"
OUT_DIR = os.environ.get("BENCH_OUT", "artifacts/bench")

HORIZON = 864_000.0 if FULL else 30_000.0
EVAL_EVERY = 20_000.0 if FULL else 6_000.0
NUM_CLIENTS = 50
SAMPLES = 10_000

_WORLD_CACHE: Dict = {}


def world(alpha: float, model: str = "paper-synthetic-mlp", seed: int = 0):
    key = (alpha, model, seed)
    if key not in _WORLD_CACHE:
        cfg = get_config(model)
        if cfg.family == "cnn":
            full = make_classification(SAMPLES, cfg.num_classes,
                                       image_hw=cfg.input_hw, seed=seed,
                                       class_sep=0.7)
        else:
            full = make_classification(SAMPLES, cfg.num_classes,
                                       dim=cfg.input_hw[0], seed=seed,
                                       class_sep=0.7)
        train, test = train_test_split(full, 0.1)
        if alpha <= 0:
            parts = iid_partition(train, NUM_CLIENTS, seed)
        else:
            parts = dirichlet_partition(train, NUM_CLIENTS, alpha, seed)
        clients = [ClientDataset(train.subset(ix)) for ix in parts]
        calib = {
            "gaussian": make_calibration_batch(train, 64, "gaussian"),
            "real": make_calibration_batch(train, 64, "real"),
        }
        params = model_lib.init_params(jax.random.PRNGKey(seed), cfg)
        _WORLD_CACHE[key] = (cfg, clients, test, calib, params)
    return _WORLD_CACHE[key]


def sim_config(**kw) -> SimConfig:
    base = dict(num_clients=NUM_CLIENTS, horizon=HORIZON,
                eval_every=EVAL_EVERY, seed=0)
    base.update(kw)
    return SimConfig(**base)


def run_cell(alg: str, alpha: float, *, sim: Optional[SimConfig] = None,
             psa: Optional[PSAConfig] = None, calib_source: str = "gaussian",
             model: str = "paper-synthetic-mlp", seed: int = 0, **kw):
    cfg, clients, test, calib, params = world(alpha, model, seed)
    sim = sim or sim_config(seed=seed)
    t0 = time.time()
    res = run_algorithm(alg, cfg, params, clients, test, sim,
                        psa_cfg=psa or PSAConfig(),
                        calib_batch=calib[calib_source], **kw)
    res.wall_s = time.time() - t0
    return res


def sweep_cell(alg: str, alpha: float, sweep: SweepConfig, *,
               sim: Optional[SimConfig] = None,
               psa: Optional[PSAConfig] = None,
               calib_source: str = "gaussian",
               model: str = "paper-synthetic-mlp", seed: int = 0, **kw):
    """Run S lanes of one benchmark cell as ONE batched simulation
    (``run_sweep``): same world/timeline as the matching ``run_cell``, with
    the lane grid (seeds / timeline-preserving hyperparameters) from
    ``sweep``. Returns a ``SweepResult`` (``.lane(k)`` views one lane)."""
    cfg, clients, test, calib, params = world(alpha, model, seed)
    sim = sim or sim_config(seed=seed)
    t0 = time.time()
    res = run_sweep(alg, cfg, params, clients, test, sim, sweep,
                    psa_cfg=psa or PSAConfig(),
                    calib_batch=calib[calib_source], **kw)
    res.wall_s = time.time() - t0
    return res


def aulc_json(value):
    """JSON-safe AULC table cell: ``SimResult.aulc`` reports NaN when a run
    recorded fewer than two eval points (no area to integrate), and
    ``json.dump`` would emit bare ``NaN`` — invalid JSON that many readers
    coerce to 0 or reject. Surface it as ``None`` (JSON ``null``) so a
    missing curve can never masquerade as a zero-accuracy result."""
    v = float(value)
    return v if np.isfinite(v) else None


def save(name: str, payload: dict):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path
