"""Client response-time and availability models (paper §6.2 heterogeneity).

Latency: Uniform(lo, hi) plus two heavy-tailed distributions over the same
support — ``longtail`` (Pareto-shaped) and ``lognormal`` (log-space normal)
— with most clients near ``lo`` and a straggler tail toward ``hi`` (the
paper notes long-tail response times cluster around the minimum).

Availability: FLGo-style intermittent clients — each dispatch succeeds with
a per-client probability; a failed dispatch still occupies its concurrency
slot for the full response time (the server only learns about the dropout
when the reply fails to arrive) and is then re-dispatched. ``SimConfig``
plumbs this through as ``availability_kind`` / ``dropout_rate``.
"""
from __future__ import annotations

import numpy as np


def make_latency_sampler(kind: str, lo: float, hi: float, seed: int = 0):
    rng = np.random.RandomState(seed)
    if kind == "uniform":
        def sample():
            return float(rng.uniform(lo, hi))
    elif kind == "longtail":
        # Pareto-shaped: mass near lo, tail to hi
        def sample():
            x = (np.power(1.0 - rng.rand(), -1.0 / 1.5) - 1.0)  # pareto(1.5)
            return float(np.clip(lo * (1.0 + x), lo, hi))
    elif kind == "lognormal":
        # Heavy-tail in log space: median at the lower quartile of the
        # log-range, sigma a quarter of the log-range — most clients sit
        # near ``lo`` with a long straggler tail toward ``hi`` (clipped to
        # the support, like the other kinds).
        span = np.log(hi / lo)
        mu = np.log(lo) + 0.25 * span
        sigma = 0.25 * span

        def sample():
            return float(np.clip(np.exp(rng.normal(mu, sigma)), lo, hi))
    else:
        raise ValueError(f"unknown latency kind {kind!r}")
    return sample


def _subseed(seed: int, stream: int) -> int:
    """Derive decorrelated 32-bit sub-seeds from one base seed (multiplicative
    hashing): distinct streams must never share a MT19937 state."""
    return (int(seed) * 0x9E3779B1 + 0x85EBCA77 * (stream + 1)) % (2 ** 32)


class PerClientLatency:
    """Fixed mean latency per client + per-dispatch jitter, as in FLGO:
    heterogeneity lives across clients, not only across dispatches.

    The per-client means and the per-dispatch jitter draw from DISTINCT
    sub-seeded RNG streams (they used to share ``RandomState(seed)``, which
    correlated the means with the first jitter draws). The jitter stream is
    exposed as ``self.rng`` so the simulator can snapshot/restore it across
    checkpoints.
    """

    def __init__(self, kind: str, lo: float, hi: float, num_clients: int,
                 seed: int = 0):
        sampler = make_latency_sampler(kind, lo, hi, _subseed(seed, 0))
        self.means = np.array([sampler() for _ in range(num_clients)])
        self.lo, self.hi = lo, hi
        self.rng = np.random.RandomState(_subseed(seed, 1))

    def __call__(self, client_id: int) -> float:
        jitter = self.rng.uniform(0.9, 1.1)
        return float(np.clip(self.means[client_id] * jitter,
                             self.lo, self.hi))


def per_client_latency(kind: str, lo: float, hi: float, num_clients: int,
                       seed: int = 0):
    """Build the per-client latency process; returns (sampler, means) where
    ``sampler(client_id)`` draws one jittered response time (and carries its
    RNG as ``sampler.rng`` — see ``PerClientLatency``)."""
    lat = PerClientLatency(kind, lo, hi, num_clients, seed)
    return lat, lat.means


AVAILABILITY_KINDS = ("always", "uniform", "hetero", "slow-fragile")


def per_client_availability(kind: str, dropout_rate: float, num_clients: int,
                            seed: int = 0,
                            latency_means=None) -> np.ndarray:
    """Per-client probability that a dispatch completes successfully.

    ``always``        every dispatch succeeds (dropout disabled)
    ``uniform``       every client succeeds w.p. 1 - dropout_rate
    ``hetero``        per-client Beta-distributed success probs with mean
                      1 - dropout_rate — some clients are chronically flaky
                      (FLGo's intermittently-available population)
    ``slow-fragile``  dropout concentrated on the slowest clients (success
                      prob decays with the client's mean latency) — couples
                      system heterogeneity to availability, the adversarial
                      case for staleness policies
    """
    if kind == "always" or dropout_rate <= 0.0:
        return np.ones(num_clients)
    if not 0.0 < dropout_rate < 1.0:
        raise ValueError(f"dropout_rate must be in (0, 1), got {dropout_rate}")
    rng = np.random.RandomState(seed + 0x5EED)
    if kind == "uniform":
        return np.full(num_clients, 1.0 - dropout_rate)
    if kind == "hetero":
        # Beta(a, b) with mean 1-rate and fixed concentration a+b=8
        conc = 8.0
        a = conc * (1.0 - dropout_rate)
        return rng.beta(a, conc - a, size=num_clients)
    if kind == "slow-fragile":
        if latency_means is None:
            raise ValueError("slow-fragile availability needs latency_means")
        m = np.asarray(latency_means, np.float64)
        rank = (m - m.min()) / max(m.max() - m.min(), 1e-12)
        # fastest client ~always available; slowest drops at 2x the mean rate
        p = 1.0 - dropout_rate * 2.0 * rank
        return np.clip(p, 0.05, 1.0)
    raise ValueError(f"unknown availability kind {kind!r}; "
                     f"known: {AVAILABILITY_KINDS}")
