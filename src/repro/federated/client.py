"""Client-side local training (paper protocol: E epochs of SGD, batch 64).

The per-batch step is jit'd once per (model config, variant) and cached.
``local_update`` returns the parameter delta dw = w_after - w_before plus
optional extras (FedPSA sensitivity sketch, FedPAC alignment stats).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.common import tree as tu
from repro.data.loader import ClientDataset
from repro.models import model as model_lib
from repro.models.config import ModelConfig

_STEP_CACHE = {}


def _loss_for(cfg: ModelConfig, prox: float, align: float):
    def loss(params, batch, anchor):
        base = model_lib.loss_fn(params, batch, cfg, _RULES)
        if prox > 0.0:  # FedProx-style proximal pull toward the anchor
            base = base + 0.5 * prox * tu.tree_sq_norm(tu.tree_sub(params, anchor))
        if align > 0.0:  # FedPAC-lite: align the classifier head with global
            head_p = _head(params)
            head_a = _head(anchor)
            base = base + 0.5 * align * tu.tree_sq_norm(tu.tree_sub(head_p, head_a))
        return base
    return loss


def _head(params):
    """Classifier head leaves (last fc layer) of the paper models."""
    fc_keys = sorted(k for k in params if k.startswith("fc"))
    return params[fc_keys[-1]] if fc_keys else params


from repro.common.sharding import SINGLE_DEVICE_RULES as _RULES


def _get_step(cfg: ModelConfig, prox: float, align: float):
    key = (cfg, prox, align)
    if key not in _STEP_CACHE:
        loss = _loss_for(cfg, prox, align)

        @jax.jit
        def step(params, batch, anchor, lr):
            g = jax.grad(loss)(params, batch, anchor)
            return jax.tree_util.tree_map(
                lambda p, gi: p - lr * gi.astype(p.dtype), params, g)

        _STEP_CACHE[key] = step
    return _STEP_CACHE[key]


def local_update(global_params, cfg: ModelConfig, dataset: ClientDataset, *,
                 epochs: int = 5, batch_size: int = 64, lr: float = 0.01,
                 seed: int = 0, prox: float = 0.0, align: float = 0.0):
    """Run E local epochs of SGD from ``global_params``; returns (delta, w_i)."""
    step = _get_step(cfg, prox, align)
    params = global_params
    lr = jnp.float32(lr)
    for batch in dataset.epochs(epochs, batch_size, seed):
        params = step(params, batch, global_params, lr)
    delta = tu.tree_sub(params, global_params)
    return delta, params
