"""FedPSA core math vs the paper's equations (Eq. 3-20).

Property-based (hypothesis) variants of these invariants live in
``tests/test_property.py`` behind ``pytest.importorskip``; everything here
runs on a bare pytest install.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PSAConfig, aggregate_buffer, buffer_full, cosine,
                        dense_projection, fisher_diagonal, init_state,
                        init_thermometer, is_full, psa_weights, push,
                        sensitivity, sensitivity_from_parts, server_aggregate,
                        server_receive, server_step, sketch_tree,
                        staleness_polynomial, temperature, uniform_weights)
from repro.core import psa as psa_lib
from repro.common import tree as tu


def _quad_loss(params, batch):
    """loss = 0.5 * sum((x @ w - y)^2) / B — analytic grads & Fisher."""
    pred = batch["x"] @ params["w"]
    return 0.5 * jnp.mean(jnp.sum(jnp.square(pred - batch["y"]), -1))


def test_sensitivity_matches_manual_eq8():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (4, 3))
    params = {"w": w}
    x = jax.random.normal(jax.random.fold_in(key, 1), (8, 4))
    y = jax.random.normal(jax.random.fold_in(key, 2), (8, 3))
    batch = {"x": x, "y": y}
    s = sensitivity(_quad_loss, params, batch, num_micro=4)["w"]

    g = jax.grad(_quad_loss)(params, batch)["w"]
    # empirical Fisher: mean over the 4 microbatches of squared microbatch grads
    fs = []
    for i in range(4):
        mb = {"x": x[2 * i:2 * i + 2], "y": y[2 * i:2 * i + 2]}
        fs.append(jnp.square(jax.grad(_quad_loss)(params, mb)["w"]))
    F = sum(fs) / 4
    want = jnp.abs(g * w - 0.5 * F * jnp.square(w))
    np.testing.assert_allclose(np.asarray(s), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_sensitivity_second_order_approximates_zeroing():
    """Eq. 3 ground truth: |F(theta) - F(theta - theta_i e_i)| vs Eq. 8,
    on a quadratic loss where the 2nd-order Taylor expansion is EXACT in the
    Hessian — the empirical-Fisher approximation is the only error source.
    Evaluated near the optimum (the regime the paper's sensitivity targets)
    and averaged across seeds: a 6-point rank correlation is too coarse to
    assert on a single draw."""
    def rank(a):
        order = np.argsort(a.ravel())
        r = np.empty_like(order)
        r[order] = np.arange(len(order))
        return r

    corrs = []
    for seed in range(8):
        key = jax.random.PRNGKey(seed)
        w_true = jax.random.normal(jax.random.fold_in(key, 2), (3, 2))
        w = w_true + 0.3 * jax.random.normal(key, (3, 2))
        params = {"w": w}
        x = jax.random.normal(jax.random.fold_in(key, 1), (64, 3))
        batch = {"x": x, "y": x @ w_true}
        s = np.asarray(sensitivity(_quad_loss, params, batch, num_micro=4)["w"])

        base = float(_quad_loss(params, batch))
        true = np.zeros_like(s)
        for i in range(3):
            for j in range(2):
                wz = np.asarray(w).copy()
                wz[i, j] = 0.0
                true[i, j] = abs(
                    base - float(_quad_loss({"w": jnp.asarray(wz)}, batch)))
        corrs.append(np.corrcoef(rank(s), rank(true))[0, 1])
    # the approximation must order parameters like the truth, on average
    assert np.mean(corrs) > 0.7, corrs
    assert min(corrs) > 0.3, corrs


def test_sketch_equals_dense_projection():
    key = jax.random.PRNGKey(2)
    tree = {"a": jax.random.normal(key, (9, 5)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (7,))}
    for k in (4, 16, 64):
        y = sketch_tree(tree, seed=11, k=k)
        R = dense_projection(11, [l.shape for l in jax.tree_util.tree_leaves(tree)], k)
        flat = np.concatenate([np.asarray(l).ravel() for l in jax.tree_util.tree_leaves(tree)])
        np.testing.assert_allclose(np.asarray(y), R @ flat, rtol=1e-4, atol=1e-4)


def test_sketch_vectorized_bit_identical_to_unrolled():
    """The vectorized default and the legacy per-row loop hash the same
    indices with the same uint32 math — bit-identical sums, not just close."""
    key = jax.random.PRNGKey(4)
    tree = {"a": jax.random.normal(key, (13, 7)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (31,)),
            "c": jnp.float32(0.5)}  # scalar leaf
    for k in (4, 16):
        vec = sketch_tree(tree, seed=3, k=k)
        loop = sketch_tree(tree, seed=3, k=k, unroll=True)
        np.testing.assert_array_equal(np.asarray(vec), np.asarray(loop))


def test_sketch_compile_time_budget():
    """Compile-time regression guard: the vectorized sketch of the fed-lm
    smoke parameter tree must trace+compile in seconds. The unrolled legacy
    form took ~85s here (k x n_leaves distinct hash/reduce chains) — a
    regression back to per-row programs blows this budget immediately."""
    import time

    from repro.configs import get_config
    from repro.models import model as model_lib

    cfg = get_config("fed-lm-smoke")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    f = jax.jit(lambda p: sketch_tree(p, 0, 16))
    t0 = time.time()
    f.lower(params).compile()
    elapsed = time.time() - t0
    assert elapsed < 30.0, f"fed-lm sketch compile took {elapsed:.1f}s"


def test_cosine_bounds():
    for seed in range(20):
        rng = np.random.RandomState(seed)
        a = jnp.asarray(rng.randn(16).astype(np.float32))
        b = jnp.asarray(rng.randn(16).astype(np.float32))
        c = float(cosine(a, b))
        assert -1.0001 <= c <= 1.0001
        assert abs(float(cosine(a, a)) - 1.0) < 1e-5


def test_jl_cosine_preservation():
    """JL (Eq. 14-15): sketch cosine approximates full cosine."""
    rng = np.random.RandomState(0)
    d, k = 4096, 128
    errs = []
    for t in range(10):
        a = rng.randn(d).astype(np.float32)
        b = (0.6 * a + 0.4 * rng.randn(d)).astype(np.float32)
        sa = sketch_tree({"x": jnp.asarray(a)}, seed=t, k=k)
        sb = sketch_tree({"x": jnp.asarray(b)}, seed=t, k=k)
        full = float(np.dot(a, b) / np.linalg.norm(a) / np.linalg.norm(b))
        errs.append(abs(full - float(cosine(sa, sb))))
    assert np.mean(errs) < 0.08, errs


def test_thermometer_eq16_18():
    st_ = init_thermometer(4)
    assert not bool(is_full(st_))
    for m in (4.0, 4.0, 4.0, 4.0):
        st_ = push(st_, m)
    assert bool(is_full(st_))
    assert float(st_.m0) == 4.0
    # Temp = (M_cur/M_0)*gamma + delta
    assert abs(float(temperature(st_, 5.0, 0.5)) - 5.5) < 1e-6
    for m in (1.0, 1.0, 1.0, 1.0):  # ring overwrites, M_cur = 1
        st_ = push(st_, m)
    assert abs(float(temperature(st_, 5.0, 0.5)) - (0.25 * 5 + 0.5)) < 1e-6


def test_psa_weights_simplex():
    for seed in range(20):
        rng = np.random.RandomState(seed)
        kappas = rng.uniform(-1, 1, size=rng.randint(2, 9)).astype(np.float32)
        temp = float(rng.uniform(0.125, 20.0))
        w = np.asarray(psa_weights(jnp.asarray(kappas), jnp.float32(temp)))
        assert abs(w.sum() - 1.0) < 1e-4
        assert (w >= 0).all()
        # monotone: higher kappa never gets lower weight
        order = np.argsort(kappas)
        assert (np.diff(w[order]) >= -1e-6).all()


def test_temperature_sharpens_weights():
    k = jnp.asarray([0.9, 0.1, -0.5])
    w_hot = np.asarray(psa_weights(k, jnp.float32(10.0)))
    w_cold = np.asarray(psa_weights(k, jnp.float32(0.1)))
    assert w_cold[0] > w_hot[0]          # cold focuses on the best update
    assert w_cold[0] > 0.99
    assert np.std(w_hot) < np.std(w_cold)


def test_algorithm1_uniform_until_queue_full():
    cfg = PSAConfig(buffer_size=2, queue_len=6)
    d = 3
    state = init_state(cfg, d, jnp.ones(cfg.sketch_k))
    params = jnp.zeros((d,))
    infos = []
    for i in range(6):  # 3 aggregations x buffer 2 = 6 receives = queue fills
        upd = jnp.full((d,), 0.1 * (i + 1))
        sk = jnp.ones(cfg.sketch_k) * (1.0 if i % 2 == 0 else -1.0)
        state = server_receive(state, upd, sk)
        if bool(buffer_full(state)):
            state, params, info = server_aggregate(state, params, cfg)
            infos.append(info)
    # first aggregations: queue not yet full -> uniform
    np.testing.assert_allclose(np.asarray(infos[0].weights), [0.5, 0.5], atol=1e-6)
    assert not bool(infos[0].temp_valid)
    # last aggregation: queue full -> temperature softmax, kappa +1 vs -1
    assert bool(infos[-1].temp_valid) and float(infos[-1].temp) > 0
    w = np.asarray(infos[-1].weights)
    assert w[0] > w[1]  # kappa=+1 entry outweighs kappa=-1


def test_psa_stacked_ring_buffer_semantics():
    """The (L_s, d) stacked buffer behaves as a ring: slot j of push n lands
    at n % L_s, the fill count tracks receives and resets on aggregation."""
    cfg = PSAConfig(buffer_size=3, queue_len=8)
    d = 4
    state = init_state(cfg, d, jnp.ones(cfg.sketch_k))
    updates = [jnp.full((d,), float(i + 1)) for i in range(5)]
    for i, u in enumerate(updates[:2]):
        state = server_receive(state, u, jnp.ones(cfg.sketch_k))
        assert int(state.count) == i + 1
        assert not bool(buffer_full(state))
        np.testing.assert_allclose(np.asarray(state.buffer[i]), np.asarray(u))
    state = server_receive(state, updates[2], jnp.ones(cfg.sketch_k))
    assert bool(buffer_full(state))
    state, _, _ = server_aggregate(state, jnp.zeros((d,)), cfg)
    assert int(state.count) == 0
    # next cycle overwrites slots starting at 0 (implicit clear)
    state = server_receive(state, updates[3], jnp.ones(cfg.sketch_k))
    np.testing.assert_allclose(np.asarray(state.buffer[0]),
                               np.asarray(updates[3]))
    assert int(state.thermo.count) == 4  # thermometer tracks ALL receives


def test_fused_server_step_matches_two_phase():
    """server_step (lax.cond fused) == server_receive + server_aggregate."""
    cfg = PSAConfig(buffer_size=2, queue_len=4)
    d = 6
    rng = np.random.RandomState(3)
    sketches = [jnp.asarray(rng.randn(cfg.sketch_k), jnp.float32)
                for _ in range(8)]
    updates = [jnp.asarray(rng.randn(d) * 0.1, jnp.float32) for _ in range(8)]

    gs = jnp.asarray(rng.randn(cfg.sketch_k), jnp.float32)
    s_a = init_state(cfg, d, gs)
    s_b = init_state(cfg, d, gs)
    g_a = jnp.zeros((d,))
    g_b = jnp.zeros((d,))
    fused = jax.jit(lambda st, g, u, sk: server_step(st, g, u, sk, cfg))
    for u, sk in zip(updates, sketches):
        s_a, g_a, info = fused(s_a, g_a, u, sk)
        s_b = server_receive(s_b, u, sk)
        if bool(buffer_full(s_b)):
            s_b, g_b, _ = server_aggregate(s_b, g_b, cfg)
            assert bool(info.updated)
        else:
            assert not bool(info.updated)
        np.testing.assert_allclose(np.asarray(g_a), np.asarray(g_b),
                                   rtol=1e-6, atol=1e-6)
    assert int(s_a.count) == int(s_b.count)


def test_staleness_polynomial_decreasing():
    taus = jnp.arange(0, 20)
    w = np.asarray(staleness_polynomial(taus))
    assert (np.diff(w) < 0).all()
    assert abs(w[0] - 0.6) < 1e-6
