"""Logical-axis sharding rules (MaxText-style).

Model code annotates tensors with *logical* axis names ("batch", "embed",
"heads", "expert", ...). A `LogicalRules` table maps logical names to mesh
axes; `None` means replicated. The same model code then runs on a 1-device
CPU (empty rules), a 16x16 single pod, or a 2x16x16 multi-pod mesh.
"""
from __future__ import annotations

import contextlib
from typing import Mapping, Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxis = Union[str, Sequence[str], None]


class LogicalRules:
    """Mapping logical axis name -> mesh axis (or tuple of mesh axes)."""

    def __init__(self, rules: Mapping[str, MeshAxis]):
        self.rules = dict(rules)

    def mesh_axes(self, logical_axes: Sequence[Optional[str]]) -> P:
        """Resolve logical names to a PartitionSpec. A mesh axis may appear
        at most once per spec; when two logical axes of one tensor resolve to
        the same mesh axis (e.g. mLSTM's ssm_inner x head_dim after the
        head_dim TP fallback), the FIRST occurrence wins and later ones are
        replicated — deterministic best-effort sharding."""
        out = []
        used: set = set()
        for name in logical_axes:
            if name is None:
                out.append(None)
                continue
            ax = self.rules.get(name)
            axes = tuple(ax) if isinstance(ax, (list, tuple)) else ((ax,) if ax else ())
            kept = tuple(a for a in axes if a not in used)
            if len(kept) != len(axes):
                kept = ()  # partial overlap: replicate rather than half-shard
            used.update(kept)
            out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        return P(*out)

    def __repr__(self):
        return f"LogicalRules({self.rules})"


# Default production rules. `batch` spans pod+data so a single client step is
# synchronous data-parallel across the whole slice it owns; asynchrony lives in
# the AFL runtime above the step.
PRODUCTION_RULES = LogicalRules(
    {
        "batch": ("pod", "data"),
        "tokens": ("pod", "data"),
        "seq": None,
        "embed": "data",          # FSDP: contraction/embed dim of weights
        "embed_act": None,        # activations keep embed replicated
        "seq_act": "model",       # sequence sharding of the residual stream
                                  # (only constrained when cfg.seq_shard)
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "vocab_lookup": None,   # replicated: vocab-sharded gathers reshard badly
        "expert": "model",
        "expert_mlp": None,
        "expert_capacity": None,
        "qkv_inner": "model",
        "conv_kernel": None,
        "ssm_inner": "model",
        "ssm_state": None,
        "layers": None,
        "sketch": None,
        "buffer": None,
        "cache_seq": None,        # decode KV cache seq dim (rules_for upgrades)
    }
)

# Variant for architectures whose expert count does not divide the `model`
# axis (qwen2-moe: 60 experts). Experts are replicated; per-expert mlp dim is
# tensor-parallel instead.
EXPERT_TP_RULES = LogicalRules({**PRODUCTION_RULES.rules, "expert": None, "expert_mlp": "model"})

SINGLE_DEVICE_RULES = LogicalRules({})


def logical_to_pspec(rules: LogicalRules, logical_axes) -> P:
    return rules.mesh_axes(logical_axes)


def shard_pytree_spec(rules: LogicalRules, logical_tree):
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree_util.tree_map(
        lambda ax: rules.mesh_axes(ax),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


def with_logical_constraint(x, rules: LogicalRules, logical_axes):
    """sharding_constraint by logical names; no-op when rules are empty."""
    if not rules.rules:
        return x
    spec = rules.mesh_axes(logical_axes)
    return jax.lax.with_sharding_constraint(x, spec)


def named_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


# ---------------------------------------------------------------------------
# Federated flat-parameter sharding (the (d,) axis of the policy server)
# ---------------------------------------------------------------------------

# Logical rules for the federated stack. ``param_shard`` is the flat (d,)
# parameter axis of ServerState (params / ring rows / cache rows);
# ``cohort`` is the client axis of a completion wave (data parallel).
# Both map onto the one-axis federated mesh from ``launch.mesh.make_fed_mesh``.
FEDERATED_RULES = LogicalRules({"param_shard": "d", "cohort": "d"})

# Mesh-axis name the surrounding code is currently shard_map-ed over on the
# flat parameter axis, or None when tracing single-device code. Policy steps
# are pure functions traced in both layouts; the two helpers below let the
# SAME step body lower to a plain reduction on one device and to a
# psum/all_gather-completed one under ``shard_map`` — so sharding never
# forks the numerics-bearing code.
_PARAM_AXIS: list = [None]


@contextlib.contextmanager
def param_axis(name: Optional[str]):
    """Trace-time context: mark that code is being traced per-shard over the
    flat parameter axis ``name`` (inside shard_map)."""
    _PARAM_AXIS.append(name)
    try:
        yield
    finally:
        _PARAM_AXIS.pop()


def current_param_axis() -> Optional[str]:
    return _PARAM_AXIS[-1]


def param_axis_sum(x: jnp.ndarray) -> jnp.ndarray:
    """``jnp.sum(x)`` over (an elementwise function of) the flat parameter
    axis, psum-completed across shards when traced under ``param_axis``.
    The ONE reduction primitive d-contracting policy code may use."""
    s = jnp.sum(x)
    ax = current_param_axis()
    if ax is not None:
        s = jax.lax.psum(s, ax)
    return s


def gather_param_axis(vec: jnp.ndarray, d: int) -> jnp.ndarray:
    """Materialize the full (d,) flat vector from a per-shard slice when
    traced under ``param_axis`` (all_gather + strip the divisibility
    padding); the identity on single-device traces. Used by the rare
    whole-vector consumers (FedPSA's global-sketch refresh) that cannot be
    expressed per-shard."""
    ax = current_param_axis()
    if ax is None:
        return vec
    full = jax.lax.all_gather(vec, ax, tiled=True)
    return full[:d]
