"""FedPSA core — the paper's primary contribution in JAX.

Submodules: sensitivity (Eq. 3-8), sketch (Eq. 11-15), thermometer
(Eq. 16-18), aggregation (Eq. 19-20 + baseline staleness fns), psa
(Algorithm 1 glue).
"""
from repro.core.sensitivity import (
    sensitivity,
    sensitivity as compute_sensitivity,  # alias: the bare name shadows the submodule
    sensitivity_from_parts,
    fisher_diagonal,
    first_order_sensitivity,
)
from repro.core.sketch import (
    sketch_tree,
    sketch_leaf,
    cosine,
    pcg_hash,
    rademacher_row,
    dense_projection,
    DEFAULT_K,
)
from repro.core.thermometer import (
    ThermometerState,
    init_thermometer,
    push,
    temperature,
    is_full,
    current_mean,
)
from repro.core.aggregation import (
    psa_weights,
    uniform_weights,
    aggregate_buffer,
    aggregate_flat,
    staleness_constant,
    staleness_polynomial,
    staleness_hinge,
)
from repro.core.psa import (
    PSAConfig,
    PSAState,
    PSAInfo,
    init_state,
    client_sketch,
    server_receive,
    server_aggregate,
    server_step,
    buffer_full,
)
