"""Paper Table 3: AULC (area under the learning curve) per algorithm.

Reads the learning curves produced by t1_t2_accuracy (same runs, as in the
paper) and integrates them.
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np

from benchmarks import common


def main(argv=None):
    path = os.path.join(common.OUT_DIR, "t3_curves.json")
    if not os.path.exists(path):
        print("t3_aulc: run t1_t2_accuracy first", file=sys.stderr)
        return None
    curves = json.load(open(path))
    rows = {}
    for name, c in curves.items():
        t = np.asarray(c["times"])
        a = np.asarray(c["accuracies"])
        # Same convention as SimResult.aulc: normalize by the run's actual
        # span, so the number is mean accuracy over the run regardless of
        # horizon.
        # (NaN, surfaced as JSON null — not a fake 0.0 — when the curve is
        # too short to integrate, matching SimResult.aulc)
        span = float(t[-1] - t[0]) if len(t) > 1 else 0.0
        aulc = float(np.trapezoid(a, t) / span) if span > 0.0 else float("nan")
        rows[name] = common.aulc_json(aulc)
        print(f"t3,{name},{aulc:.4f}")
    common.save("t3_aulc", rows)
    # the paper's claim: FedPSA has the best AULC on the hardest setting
    best = max((v, k) for k, v in rows.items()
               if k.endswith("@a0.1") and v is not None)
    print(f"t3,best_aulc_a0.1,{best[1]}")
    return rows


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
