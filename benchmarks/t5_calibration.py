"""Paper Table 5: calibration-batch ablation — real data vs Gaussian noise.

The claim: FedPSA is insensitive to the source of D_b (|delta| small), so a
pure-noise calibration batch avoids any data-sharing privacy cost.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import PSAConfig
from repro.data import make_calibration_batch
from repro.data.synthetic import SyntheticClassification
from repro.federated import run_algorithm
from benchmarks import common

BATCH_SIZES_FULL = (16, 32, 128, 512)
BATCH_SIZES_FAST = (16, 64)


def main(argv=None):
    sizes = BATCH_SIZES_FULL if common.FULL else BATCH_SIZES_FAST
    cfg, clients, test, _, params = common.world(0.1)
    pool = SyntheticClassification(
        np.concatenate([c.data.x for c in clients[:8]]),
        np.concatenate([c.data.y for c in clients[:8]]), 10)
    rows = {}
    for bs in sizes:
        for source in ("real", "gaussian"):
            db = make_calibration_batch(pool, bs, source)
            micro = 4 if bs % 4 == 0 else 1
            res = run_algorithm(
                "fedpsa", cfg, params, clients, test, common.sim_config(),
                psa_cfg=PSAConfig(fisher_microbatches=micro), calib_batch=db)
            rows[f"{source}@bs{bs}"] = res.final_accuracy
            print(f"t5,fedpsa,{source},bs={bs},{res.final_accuracy:.4f}")
    for bs in sizes:
        d = rows[f"real@bs{bs}"] - rows[f"gaussian@bs{bs}"]
        print(f"t5,delta_real_minus_gaussian_bs{bs},{d:+.4f}")
    common.save("t5_calibration", rows)
    return rows


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
