"""Model configuration.

One dataclass covers every assigned architecture family (dense / moe / ssm /
hybrid / audio / vlm) plus the paper's small CNN/MLP models. A model is
described by a *superblock pattern*: `block_pattern` gives the sequence mixer
kind per layer inside one superblock ("attn" | "mamba" | "mlstm" | "slstm"),
`ffn_pattern` the feed-forward kind ("dense" | "moe" | "moe+dense" | "none");
the pattern tiles to `num_layers`, and the layer stack is executed with
`lax.scan` over superblocks (stacked parameters) to keep the HLO compact at
126-layer / 16k-dim scale.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm | cnn | mlp
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    causal: bool = True             # False => encoder-only (hubert)
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    # Superblock patterns (tiled to num_layers).
    block_pattern: Tuple[str, ...] = ("attn",)
    ffn_pattern: Tuple[str, ...] = ("dense",)
    # Attention windowing. None = full attention. When a dense arch is lowered
    # for long_500k the launcher swaps in `long_context_window`.
    sliding_window: Optional[int] = None
    long_context_window: Optional[int] = 8192
    # MoE
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01   # load-balance loss coefficient
    expert_tensor_parallel: bool = False  # shard per-expert d_ff instead of experts
    # GShard-style grouped dispatch: tokens are split into G groups aligned
    # with the data shards; cumsum/scatter/capacity are group-LOCAL, so the
    # dispatch never materializes (or all-reduces) a global (E, C, D) buffer.
    # 1 = single global group (the naive baseline).
    dispatch_groups: int = 1
    # Pure data parallelism: replicate ALL weights and shard the batch over
    # every mesh axis whose product divides it. The right regime for ~1B
    # models at large global batch (model parallelism only adds collectives).
    pure_data_parallel: bool = False
    # Gradient accumulation (microbatching) inside train_step: divides the
    # per-step activation footprint by this factor.
    grad_accum: int = 1
    # SSM (mamba)
    ssm_expand: int = 2
    ssm_state_dim: int = 16
    conv_kernel: int = 4
    dt_rank: int = 0                # 0 => ceil(d_model/16)
    # xLSTM
    mlstm_proj_factor: float = 2.0
    slstm_ffn_factor: float = 4.0 / 3.0
    # Frontend stubs (audio/vlm): inputs are precomputed embeddings.
    frontend: Optional[str] = None  # None | "audio" | "vision"
    num_prefix_tokens: int = 256    # patch tokens prepended for vlm
    # Vocab padding: embedding/unembedding tables round the vocab up to a
    # multiple of this so the vocab dim always shards over the model axis
    # (pad logits are masked in the loss; ids never reference pad rows).
    pad_vocab_to: int = 128
    # Numerics / memory knobs
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: str = "full"             # none | full | dots
    # Two-level remat scan: outer scan over `scan_groups` groups saves only
    # G carries for backward; the inner layers-in-group scan is inside the
    # checkpoint and recomputed. Cuts the saved-activation stack from
    # num_superblocks x (B,S,D) to scan_groups x (B,S,D). 0 = single level.
    scan_groups: int = 0
    # Megatron-SP-style sequence sharding of the residual stream between
    # blocks ("seq_act" -> model axis): activations and the saved carries
    # shrink by the model-axis size; GSPMD turns the row-parallel all-reduces
    # into reduce-scatter + all-gather pairs at the block boundaries.
    seq_shard: bool = False
    q_chunk: int = 512
    kv_chunk: int = 2048
    # Activation function for dense FFN: "swiglu" | "gelu" | "relu"
    ffn_act: str = "swiglu"
    tie_embeddings: bool = False
    # CNN/MLP family (the paper's own models)
    cnn_channels: Tuple[int, ...] = ()
    cnn_kernel: int = 5
    mlp_hidden: Tuple[int, ...] = ()
    input_hw: Tuple[int, int, int] = (0, 0, 0)  # H, W, C for cnn; (features,) via H
    num_classes: int = 10

    def __post_init__(self):
        if self.head_dim is None and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_layers % len(self.block_pattern) == 0, (
            f"{self.name}: num_layers {self.num_layers} must tile block_pattern "
            f"of length {len(self.block_pattern)}"
        )
        assert len(self.block_pattern) == len(self.ffn_pattern)

    @property
    def num_superblocks(self) -> int:
        return self.num_layers // len(self.block_pattern)

    @property
    def dt_rank_actual(self) -> int:
        return self.dt_rank if self.dt_rank > 0 else -(-self.d_model // 16)

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def vocab_padded(self) -> int:
        m = self.pad_vocab_to
        return -(-self.vocab_size // m) * m if self.vocab_size else 0

    @property
    def slstm_ffn_dim(self) -> int:
        """sLSTM post-cell FFN width, rounded up to a multiple of 128 so the
        MXU matmul dims stay hardware-aligned and the dim shards over the
        16-way model axis."""
        f = int(self.d_model * self.slstm_ffn_factor)
        return -(-f // 128) * 128

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def has_decode(self) -> bool:
        return not self.is_encoder_only

    def for_long_context(self) -> "ModelConfig":
        """Variant used for the 500k-decode shape: enable sliding-window
        attention on every attention layer (SSM layers are O(1) already)."""
        if self.long_context_window is None:
            return self
        return dataclasses.replace(self, sliding_window=self.long_context_window)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 superblocks, d_model<=256, <=4 experts."""
        # Shrink the superblock pattern to two layers that still cover both
        # mixer kinds of the family (e.g. jamba -> (mamba, attn)).
        if len(self.block_pattern) > 1:
            bp = (self.block_pattern[0], self.block_pattern[-1])
            fp = (self.ffn_pattern[0], self.ffn_pattern[-1])
        else:
            bp = self.block_pattern
            fp = self.ffn_pattern
        d_model = min(self.d_model, 256)
        n_heads = min(self.num_heads, 4)
        n_kv = min(self.num_kv_heads, n_heads)
        # keep GQA ratio valid
        while n_heads % n_kv:
            n_kv -= 1
        n_exp = min(self.num_experts, 4) if self.num_experts else 0
        # Lossless capacity (cf >= E/k => no token drops) so the dispatch path
        # is exactly equal to the dropless oracle in smoke/consistency tests.
        n_topk = min(self.top_k, 2) if self.top_k else 0
        cf = max(self.capacity_factor, n_exp / max(n_topk, 1)) if n_exp else self.capacity_factor
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            block_pattern=bp,
            ffn_pattern=fp,
            num_layers=2 * len(bp) if len(bp) == 1 else len(bp),
            d_model=d_model,
            num_heads=n_heads,
            num_kv_heads=max(1, n_kv),
            head_dim=d_model // n_heads,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=n_exp,
            top_k=n_topk,
            capacity_factor=cf,
            num_shared_experts=min(self.num_shared_experts, 1),
            moe_d_ff=min(self.moe_d_ff, 128) if self.moe_d_ff else 0,
            shared_d_ff=min(self.shared_d_ff, 128) if self.shared_d_ff else 0,
            num_prefix_tokens=min(self.num_prefix_tokens, 8),
            dtype="float32",
            param_dtype="float32",
            remat="none",
            q_chunk=64,
            kv_chunk=64,
        )
