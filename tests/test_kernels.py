"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sketch as sk
from repro.core.sensitivity import sensitivity_from_parts
from repro.kernels import ops, ref
from repro.kernels.buffer_agg import buffer_agg_pallas
from repro.kernels.sens_sketch import sens_sketch_pallas


@pytest.mark.parametrize("d", [1, 7, 512, 1024, 4097, 20000])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_sens_sketch_shapes_dtypes(d, dtype):
    key = jax.random.PRNGKey(d)
    dt = jnp.dtype(dtype)
    theta = jax.random.normal(key, (d,), dt)
    g = jax.random.normal(jax.random.fold_in(key, 1), (d,), dt)
    f = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (d,), dt))
    out = sens_sketch_pallas(theta, g, f, k=16, seed=3, block=1024, interpret=True)
    want = ref.sens_sketch_ref(theta.astype(jnp.float32), g.astype(jnp.float32),
                               f.astype(jnp.float32), k=16, seed=3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("k", [1, 4, 16, 32])
def test_sens_sketch_k_sweep(k):
    key = jax.random.PRNGKey(k)
    d = 3000
    theta, g = (jax.random.normal(jax.random.fold_in(key, i), (d,)) for i in range(2))
    f = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (d,)))
    out = sens_sketch_pallas(theta, g, f, k=k, seed=0, block=512, interpret=True)
    want = ref.sens_sketch_ref(theta, g, f, k=k, seed=0)
    assert out.shape == (k,)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_sens_sketch_block_invariance():
    key = jax.random.PRNGKey(9)
    d = 10240
    theta, g = (jax.random.normal(jax.random.fold_in(key, i), (d,)) for i in range(2))
    f = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (d,)))
    outs = [sens_sketch_pallas(theta, g, f, k=8, seed=1, block=b, interpret=True)
            for b in (256, 1024, 2048)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-4, atol=1e-4)


def test_sens_sketch_shards_compose_via_index_offset():
    """d-sharded contract: the sum of per-shard sketches computed with
    ``index_offset`` set to each shard's global start equals the full-vector
    sketch — the projection sign of element i depends only on its global
    index, so per-shard partials psum to the exact single-device result."""
    key = jax.random.PRNGKey(3)
    d = 4096 + 640   # not a multiple of typical shard counts' blocks
    theta, g = (jax.random.normal(jax.random.fold_in(key, i), (d,))
                for i in range(2))
    f = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (d,)))
    full = sens_sketch_pallas(theta, g, f, k=8, seed=11, interpret=True)
    for nshards in (2, 4):
        bounds = np.linspace(0, d, nshards + 1).astype(int)
        parts = [
            sens_sketch_pallas(theta[lo:hi], g[lo:hi], f[lo:hi], k=8,
                               seed=11, index_offset=int(lo), interpret=True)
            for lo, hi in zip(bounds[:-1], bounds[1:])
        ]
        np.testing.assert_allclose(np.asarray(sum(parts)), np.asarray(full),
                                   rtol=1e-4, atol=1e-4)


def test_fused_tree_sketch_matches_core_pipeline():
    key = jax.random.PRNGKey(0)
    tree = {"a": jax.random.normal(key, (40, 30)),
            "b": {"c": jax.random.normal(jax.random.fold_in(key, 1), (55,))}}
    g = jax.tree_util.tree_map(lambda x: 0.3 * x + 0.01, tree)
    f = jax.tree_util.tree_map(jnp.abs, tree)
    want = sk.sketch_tree(sensitivity_from_parts(tree, g, f), seed=5, k=16)
    got = ops.sketch_tree_fused(tree, g, f, seed=5, k=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("L,d", [(1, 64), (5, 3000), (8, 8193), (20, 100)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_buffer_agg_shapes_dtypes(L, d, dtype):
    key = jax.random.PRNGKey(L * d)
    dt = jnp.dtype(dtype)
    w = jax.nn.softmax(jax.random.normal(key, (L,)))
    gv = jax.random.normal(jax.random.fold_in(key, 1), (d,), dt)
    ups = jax.random.normal(jax.random.fold_in(key, 2), (L, d), dt)
    out = buffer_agg_pallas(w, gv, ups, block=1024, interpret=True)
    want = ref.buffer_agg_ref(w, gv, ups)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=5e-3, atol=5e-3)


def test_buffer_agg_matches_tree_weighted_sum_semantics():
    """The kernel is exactly Eq. 20 over a flattened pytree."""
    from repro.common import tree as tu
    key = jax.random.PRNGKey(7)
    trees = [{"w": jax.random.normal(jax.random.fold_in(key, i), (17, 3))}
             for i in range(4)]
    weights = jax.nn.softmax(jax.random.normal(key, (4,)))
    g = {"w": jax.random.normal(jax.random.fold_in(key, 99), (17, 3))}
    want = tu.tree_add(g, tu.tree_weighted_sum(trees, weights))
    gv, unflatten = tu.flatten_to_vector(g)
    ups = jnp.stack([tu.flatten_to_vector(t)[0] for t in trees])
    got = unflatten(ops.buffer_agg(weights, gv, ups))
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(want["w"]),
                               rtol=1e-5, atol=1e-5)
