"""Client availability scenarios (``federated/latency.py``).

Unit-level: the per-client availability distributions behave as documented
(bounds, means, the slow-fragile latency coupling). Sim-level:
``slow-fragile`` runs drop at the configured rate, a held slot re-dispatches
with the server version *current at the moment the slot frees* (checked
exactly against the event stream), and ``availability_kind="always"``
reproduces the dropout-free trajectory bit-for-bit regardless of
``dropout_rate``.
"""
import heapq

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import (ClientDataset, dirichlet_partition,
                        make_classification, train_test_split)
from repro.federated import SimConfig, run_algorithm
from repro.federated import simulator as sim_mod
from repro.federated.latency import (AVAILABILITY_KINDS,
                                     make_latency_sampler,
                                     per_client_availability,
                                     per_client_latency)

# ---------------------------------------------------------------------------
# Unit: latency distributions
# ---------------------------------------------------------------------------


def test_lognormal_latency_heavy_tail():
    """The lognormal kind: bounded support, deterministic by seed, and a
    genuinely heavy tail (mean > median, mass concentrated near lo)."""
    lo, hi = 10.0, 500.0
    sample = make_latency_sampler("lognormal", lo, hi, seed=0)
    draws = np.array([sample() for _ in range(4000)])
    assert np.all((lo <= draws) & (draws <= hi))
    assert np.mean(draws) > np.median(draws) * 1.1        # right-skew
    assert np.median(draws) < lo + 0.25 * (hi - lo)       # mass near lo
    assert np.max(draws) > 0.5 * hi                       # tail reaches out
    replay = make_latency_sampler("lognormal", lo, hi, seed=0)
    np.testing.assert_array_equal(draws[:50],
                                  [replay() for _ in range(50)])


def test_lognormal_per_client_latency_plumbs():
    sampler, means = per_client_latency("lognormal", 10.0, 500.0, 200, seed=1)
    assert means.shape == (200,)
    assert np.all((10.0 <= means) & (means <= 500.0))
    assert np.mean(means) > np.median(means)              # skew survives
    draws = np.array([sampler(i) for i in range(200)])
    assert np.all((10.0 <= draws) & (draws <= 500.0))
    with pytest.raises(ValueError, match="unknown latency kind"):
        make_latency_sampler("nope", 10.0, 500.0)


def test_lognormal_latency_runs_in_sim(world):
    """SimConfig.latency_kind='lognormal' drives a full async run, on both
    engines, with identical event streams."""
    cfg, clients, test, params = world
    kw = dict(latency_kind="lognormal", **QUICK)
    seq = run_algorithm("fedasync", cfg, params, clients, test,
                        SimConfig(engine="sequential", **kw))
    coh = run_algorithm("fedasync", cfg, params, clients, test,
                        SimConfig(engine="cohort", **kw))
    assert seq.dispatches == coh.dispatches > 0
    assert seq.receive_log == coh.receive_log
    np.testing.assert_allclose(coh.final_accuracy, seq.final_accuracy,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# Unit: availability distributions
# ---------------------------------------------------------------------------


def test_always_and_zero_rate_disable_dropout():
    assert np.all(per_client_availability("always", 0.5, 20) == 1.0)
    for kind in AVAILABILITY_KINDS:
        assert np.all(per_client_availability(kind, 0.0, 20) == 1.0)


def test_uniform_and_hetero_match_configured_rate():
    p_u = per_client_availability("uniform", 0.3, 1000, seed=1)
    np.testing.assert_allclose(p_u, 0.7)
    p_h = per_client_availability("hetero", 0.3, 4000, seed=1)
    assert np.all((0.0 <= p_h) & (p_h <= 1.0))
    assert abs(p_h.mean() - 0.7) < 0.05        # Beta mean = 1 - rate
    assert p_h.std() > 0.02                    # but chronically flaky tails


def test_slow_fragile_couples_availability_to_latency():
    _, means = per_client_latency("uniform", 10.0, 500.0, 50, seed=3)
    p = per_client_availability("slow-fragile", 0.25, 50, seed=3,
                                latency_means=means)
    order = np.argsort(means)
    # success prob decays monotonically with mean latency (affine in rank)
    assert np.all(np.diff(p[order]) <= 1e-12)
    assert p[order[0]] > 0.95 and p[order[-1]] < 0.6
    assert np.all(p >= 0.05)
    with pytest.raises(ValueError, match="latency_means"):
        per_client_availability("slow-fragile", 0.25, 50)


def test_availability_validation():
    with pytest.raises(ValueError, match="dropout_rate"):
        per_client_availability("uniform", 1.5, 10)
    with pytest.raises(ValueError, match="unknown availability"):
        per_client_availability("nope", 0.2, 10)


# ---------------------------------------------------------------------------
# Sim-level scenarios
# ---------------------------------------------------------------------------

QUICK = dict(num_clients=12, horizon=9_000.0, eval_every=4_500.0, seed=0)


@pytest.fixture(scope="module")
def world():
    cfg = get_config("paper-synthetic-mlp")
    full = make_classification(1_200, 10, 32, seed=0, class_sep=0.7)
    train, test = train_test_split(full, 0.1)
    parts = dirichlet_partition(train, QUICK["num_clients"], alpha=0.3,
                                seed=0)
    clients = [ClientDataset(train.subset(ix)) for ix in parts]
    params = M_init(cfg)
    return cfg, clients, test, params


def M_init(cfg):
    from repro.models import model as M
    return M.init_params(jax.random.PRNGKey(0), cfg)


def test_slow_fragile_drops_at_configured_rate(world):
    """Empirical drop fraction tracks dropout_rate (slow clients also hold
    their slots longer, so the dispatch-weighted rate sits near the mean)."""
    cfg, clients, test, params = world
    rate = 0.3
    r = run_algorithm("fedasync", cfg, params, clients, test,
                      SimConfig(availability_kind="slow-fragile",
                                dropout_rate=rate, **QUICK))
    frac = r.dropped / max(1, r.dropped + r.dispatches)
    assert r.dropped > 0
    assert 0.08 <= frac <= 0.55, frac
    assert r.launched == max(1, round(0.2 * QUICK["num_clients"])) + \
        r.dispatches + r.dropped


def test_held_slots_redispatch_with_current_version(world):
    """A failed dispatch holds its slot, then re-dispatches with the server
    version current at the time the slot frees. Verified exactly: record
    every heap push; replacement j (after the initial concurrency block)
    happens when processing the j-th completed event, so its
    version-at-dispatch must equal the number of global updates applied by
    the events processed up to then (fedasync: one update per ok receive)."""
    cfg, clients, test, params = world
    pushed = []
    orig_push = heapq.heappush

    def spy_push(h, ev):
        if isinstance(ev, sim_mod._Event):
            pushed.append(ev)
        return orig_push(h, ev)

    sim_mod.heapq.heappush = spy_push
    try:
        r = run_algorithm("fedasync", cfg, params, clients, test,
                          SimConfig(availability_kind="hetero",
                                    dropout_rate=0.35,
                                    engine="sequential", **QUICK))
    finally:
        sim_mod.heapq.heappush = orig_push
    assert r.dropped > 0
    conc = max(1, round(0.2 * QUICK["num_clients"]))
    assert len(pushed) == r.launched
    # replay: events are processed in (t_done, seq) heap order; replacement
    # conc + j is pushed while processing the j-th processed event
    processed = sorted(pushed, key=lambda e: (e.t_done, e.seq))
    version = 0
    n_replacements = len(pushed) - conc
    for j in range(n_replacements):
        ev = processed[j]
        if ev.ok:
            version += 1        # fedasync: every receive bumps the version
        replacement = pushed[conc + j]
        assert replacement.version == version, (j, ev.ok)
    # in particular every dropped event's replacement carried the version
    # that was current when its slot freed — asserted above for ok=False


def test_always_reproduces_dropout_free_trajectory(world):
    """``availability_kind='always'`` must ignore dropout_rate entirely and
    reproduce the default (pre-availability-modelling) trajectory: same RNG
    stream, same receive log, same curve."""
    cfg, clients, test, params = world
    base = run_algorithm("fedbuff", cfg, params, clients, test,
                         SimConfig(**QUICK))
    always = run_algorithm("fedbuff", cfg, params, clients, test,
                           SimConfig(availability_kind="always",
                                     dropout_rate=0.7, **QUICK))
    assert base.receive_log == always.receive_log
    assert base.times == always.times
    assert base.accuracies == always.accuracies
    assert base.final_accuracy == always.final_accuracy
    assert always.dropped == 0


def test_dropout_identical_across_engines(world):
    cfg, clients, test, params = world
    kw = dict(availability_kind="slow-fragile", dropout_rate=0.3, **QUICK)
    seq = run_algorithm("fedbuff", cfg, params, clients, test,
                        SimConfig(engine="sequential", **kw))
    coh = run_algorithm("fedbuff", cfg, params, clients, test,
                        SimConfig(engine="cohort", **kw))
    assert seq.dropped == coh.dropped > 0
    assert seq.receive_log == coh.receive_log
    np.testing.assert_allclose(coh.final_accuracy, seq.final_accuracy,
                               atol=1e-4)
