"""llama3-405b [dense] — GQA, 128k vocab [arXiv:2407.21783].

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256. long_500k uses
the sliding-window variant (window 8192) since full attention is quadratic.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=500000.0,
    block_pattern=("attn",),
    ffn_pattern=("dense",),
    long_context_window=8192,
    # §Perf opt: two-level sqrt-remat scan (9 groups x 14 layers) — cuts the
    # saved-carry stack 14x; binding roofline term -13% vs baseline
    scan_groups=9,
)
