"""Sensitivity sketching via a *never-materialized* random projection.

Paper Eq. 11-15: the server broadcasts a fixed R in R^{k x d} and clients
send sketches R @ s. At assigned-architecture scale (llama3-405b: d ~ 4e11)
a dense R would be ~100 TB, so R is never formed. Instead every entry is a
Rademacher sign generated on the fly from a counter-based integer hash:

    R[r, j] = sign(pcg(seed_leaf ^ pcg(j * K + r))) / sqrt(k)

Rademacher projections satisfy the JL lemma (Achlioptas 2003), so sketch-
space cosine approximates full-space cosine exactly as in the paper. The
hash is pure uint32 arithmetic — identical in jnp (this module), in the
Pallas kernel (repro/kernels/sens_sketch.py), and in its ref oracle, so all
paths produce bit-identical sketches.

Sharding: the hash/sign/multiply are elementwise over the (sharded) leaf and
the contraction is a full reduce-sum — under GSPMD each device sketches its
local shard and one all-reduce of k floats combines partials. The server
never sees a full-d vector (DESIGN.md §3).

uint32 wraparound note: for leaves with >2^32/K elements the linear index
wraps; the resulting rare sign-collisions are harmless for JL (they touch a
2^-28 fraction of entries) and are deterministic across all implementations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_K = 16  # paper: compressed dimension k = 16


def pcg_hash(x: jnp.ndarray) -> jnp.ndarray:
    """PCG-XSH-RR style 32-bit mix (uint32 in, uint32 out)."""
    x = x.astype(jnp.uint32)
    state = x * jnp.uint32(747796405) + jnp.uint32(2891336453)
    word = ((state >> ((state >> jnp.uint32(28)) + jnp.uint32(4))) ^ state)
    word = word * jnp.uint32(277803737)
    return (word >> jnp.uint32(22)) ^ word


def leaf_seed(seed: int, leaf_index: int) -> jnp.ndarray:
    return pcg_hash(jnp.uint32(seed) ^ (jnp.uint32(leaf_index) * jnp.uint32(0x9E3779B9)))


def leaf_seed_host(seed: int, leaf_index: int) -> int:
    """``leaf_seed`` as pure-python uint32 arithmetic (bit-identical) — a
    static per-leaf constant usable while tracing an outer jit."""
    M = 0xFFFFFFFF

    def pcg(x: int) -> int:
        state = (x * 747796405 + 2891336453) & M
        word = ((state >> (((state >> 28) + 4) & 31)) ^ state) & M
        word = (word * 277803737) & M
        return ((word >> 22) ^ word) & M

    return pcg((seed ^ ((leaf_index * 0x9E3779B9) & M)) & M)


def rademacher_row(seed_u32, lin_idx: jnp.ndarray, r: int, k: int) -> jnp.ndarray:
    """±1 f32 signs for projection row r at flat positions ``lin_idx``."""
    h = pcg_hash(seed_u32 ^ pcg_hash(lin_idx * jnp.uint32(k) + jnp.uint32(r)))
    return jnp.where((h >> jnp.uint32(31)) == 0, 1.0, -1.0).astype(jnp.float32)


def _leaf_linear_index(shape) -> jnp.ndarray:
    """Flat linear index as a tensor of ``shape`` built from per-dim iotas
    (elementwise, so it partitions under GSPMD without relayout)."""
    idx = jnp.zeros(shape, jnp.uint32)
    stride = 1
    for d in range(len(shape) - 1, -1, -1):
        io = jax.lax.broadcasted_iota(jnp.uint32, shape, d)
        idx = idx + io * jnp.uint32(stride % (1 << 32))
        stride *= shape[d]
    return idx


def sketch_leaf(leaf: jnp.ndarray, seed_u32, k: int = DEFAULT_K,
                unroll: bool = False) -> jnp.ndarray:
    """(k,) partial sketch of one leaf.

    Default: all k rows at once on a trailing sign axis — one fused
    hash+multiply+reduce whose XLA program size is independent of k (the
    unrolled form was the ~2-min fedpsa token-sketch compile: k rows x
    n_leaves distinct hash/reduce chains). Bit-identical to the unrolled
    path: the uint32 hash math is unchanged, ``lin[..., None] * k + r`` is
    the same index each row r hashed, and each row still reduces over
    exactly the leaf axes (the k axis stays unreduced).

    ``unroll=True`` keeps the legacy row-at-a-time form — the committed
    compile-time baseline (benchmarks/kernel_micro.py measures both).
    """
    x = leaf.astype(jnp.float32)
    lin = _leaf_linear_index(leaf.shape)
    if unroll:
        rows = []
        for r in range(k):
            sign = rademacher_row(seed_u32, lin, r, k)
            rows.append(jnp.sum(x * sign))
        return jnp.stack(rows) / np.sqrt(k)
    r = jnp.arange(k, dtype=jnp.uint32)
    h = pcg_hash(seed_u32 ^ pcg_hash(lin[..., None] * jnp.uint32(k) + r))
    sign = jnp.where((h >> jnp.uint32(31)) == 0, 1.0, -1.0).astype(jnp.float32)
    return jnp.sum(x[..., None] * sign,
                   axis=tuple(range(x.ndim))) / np.sqrt(k)


def sketch_tree(tree, seed: int = 0, k: int = DEFAULT_K,
                unroll: bool = False) -> jnp.ndarray:
    """Full-model sensitivity sketch: sum of per-leaf partial sketches.

    Equivalent to R @ concat(leaves) for the blockwise-defined R.
    """
    leaves = jax.tree_util.tree_leaves(tree)
    total = jnp.zeros((k,), jnp.float32)
    for i, leaf in enumerate(leaves):
        total = total + sketch_leaf(leaf, leaf_seed(seed, i), k, unroll)
    return total


def cosine(a: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-12) -> jnp.ndarray:
    """Sketch-space cosine similarity (paper Eq. 12), in [-1, 1]."""
    num = jnp.sum(a * b)
    den = jnp.sqrt(jnp.sum(jnp.square(a))) * jnp.sqrt(jnp.sum(jnp.square(b)))
    return num / jnp.maximum(den, eps)


def dense_projection(seed: int, leaf_shapes, k: int = DEFAULT_K) -> np.ndarray:
    """Materialize R (k x d) for SMALL models — test oracle / paper-faithful
    reference. Column order matches ``sketch_tree`` leaf order."""
    cols = []
    for i, shape in enumerate(leaf_shapes):
        n = int(np.prod(shape)) if shape else 1
        seed_u = leaf_seed(seed, i)
        lin = jnp.arange(n, dtype=jnp.uint32)
        block = jnp.stack([rademacher_row(seed_u, lin, r, k) for r in range(k)])
        cols.append(np.asarray(block))
    return np.concatenate(cols, axis=1) / np.sqrt(k)
