"""Lowerable entry points: train_step / prefill_step / encode_step / serve_step.

Each builder binds (cfg, rules) and returns a function whose positional args
match the ShapeDtypeStructs from ``configs.shapes.input_specs`` plus the
parameter pytree. The training step is the paper's client local step (SGD);
serving steps are the inference paths for the decode shapes.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.common.sharding import LogicalRules
from repro.models import model as model_lib
from repro.models.config import ModelConfig


def make_train_step(cfg: ModelConfig, rules: LogicalRules) -> Callable:
    def train_step(params, batch, lr):
        m = max(cfg.grad_accum, 1)

        def loss(p, b):
            return model_lib.loss_fn(p, b, cfg, rules)

        if m > 1:
            # gradient accumulation: scan over microbatches; the activation
            # footprint (and the saved-carry stacks) shrink by m
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), batch)

            def body(acc, mb):
                acc_g, acc_l = acc
                l, g = jax.value_and_grad(loss)(params, mb)
                acc_g = jax.tree_util.tree_map(
                    lambda a, gi: a + gi.astype(jnp.float32), acc_g, g)
                return (acc_g, acc_l + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum), _ = jax.lax.scan(body, (zeros, jnp.float32(0.0)), micro)
            grads = jax.tree_util.tree_map(lambda g: g / m, grads)
            l = lsum / m
        else:
            l, grads = jax.value_and_grad(lambda p: loss(p, batch))(params)
        new_params = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_params, l
    return train_step


def make_prefill_step(cfg: ModelConfig, rules: LogicalRules) -> Callable:
    def prefill_step(params, batch):
        return model_lib.prefill(params, batch, cfg, rules)
    return prefill_step


def make_encode_step(cfg: ModelConfig, rules: LogicalRules) -> Callable:
    def encode_step(params, batch):
        return model_lib.encode(params, batch, cfg, rules)
    return encode_step


def make_serve_step(cfg: ModelConfig, rules: LogicalRules) -> Callable:
    def serve_step(params, cache, tokens, pos):
        return model_lib.decode_step(params, cache, tokens, pos, cfg, rules)
    return serve_step


def make_sketch_step(cfg: ModelConfig, rules: LogicalRules, *,
                     k: int = 16, seed: int = 42) -> Callable:
    """FedPSA client-upload path at production scale: grads + Fisher diag on
    a calibration batch, Eq. 8 sensitivity, streaming sketch. The sketch
    shards with the parameters; kappa needs one k-float all-reduce."""
    from repro.core.sensitivity import fisher_diagonal, sensitivity_from_parts
    from repro.core import sketch as sketch_lib

    def sketch_step(params, calib_batch):
        def loss(p, b):
            return model_lib.loss_fn(p, b, cfg, rules)
        grads = jax.grad(loss)(params, calib_batch)
        fisher = fisher_diagonal(loss, params, calib_batch, num_micro=1)
        sens = sensitivity_from_parts(params, grads, fisher)
        return sketch_lib.sketch_tree(sens, seed=seed, k=k)
    return sketch_step


def make_step(mode: str, cfg: ModelConfig, rules: LogicalRules) -> Callable:
    if mode == "train":
        return make_train_step(cfg, rules)
    if mode == "prefill":
        return make_prefill_step(cfg, rules)
    if mode == "encode":
        return make_encode_step(cfg, rules)
    if mode == "decode":
        return make_serve_step(cfg, rules)
    raise ValueError(mode)
