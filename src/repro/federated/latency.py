"""Client response-time and availability models (paper §6.2 heterogeneity).

Latency: Uniform(lo, hi) plus two heavy-tailed distributions over the same
support — ``longtail`` (Pareto-shaped) and ``lognormal`` (log-space normal)
— with most clients near ``lo`` and a straggler tail toward ``hi`` (the
paper notes long-tail response times cluster around the minimum).

Every sampler exposes a batched ``sample(n)`` drawing n values in ONE
vectorized call from the SAME RandomState stream a loop of scalar calls
would consume — the population-scale simulator draws a whole wave's (or the
whole initial concurrency block's) latencies at once, and the batch API is
what keeps those draws bit-identical to the historical per-dispatch scalars
(the golden digest streams depend on this).

Availability: FLGo-style intermittent clients — each dispatch succeeds with
a per-client probability; a failed dispatch still occupies its concurrency
slot for the full response time (the server only learns about the dropout
when the reply fails to arrive) and is then re-dispatched. ``SimConfig``
plumbs this through as ``availability_kind`` / ``dropout_rate``. The
``trace`` kind replaces the Bernoulli draw with a deterministic replay of a
per-client on/off ping schedule (FLGo phone-simulator style) — see
``AvailabilityTrace``.

RNG streams: one base seed fans out into decorrelated sub-streams via
``_subseed`` — stream 0 the per-client latency means, stream 1 the
per-dispatch jitter, stream 2 the availability probabilities, stream 3 the
per-dispatch availability Bernoulli draws (owned by the simulator), stream
4 the synthetic availability traces, stream 5 the synchronous fedavg round
sampling (``run_fedavg`` used to draw its per-round client choice from the
bare dispatch stream, which made the sync and async paths perturb each
other's draws at equal base seeds). Distinct streams must never share an
MT19937 state: the probabilities used to seed ad hoc as ``seed + 0x5EED``,
which collides with the latency sub-streams for adversarially chosen seeds.
"""
from __future__ import annotations

import numpy as np


def _subseed(seed: int, stream: int) -> int:
    """Derive decorrelated 32-bit sub-seeds from one base seed (multiplicative
    hashing): distinct streams must never share a MT19937 state."""
    return (int(seed) * 0x9E3779B1 + 0x85EBCA77 * (stream + 1)) % (2 ** 32)


# _subseed stream ids (see module docstring)
STREAM_MEANS = 0
STREAM_JITTER = 1
STREAM_AVAILABILITY = 2
STREAM_AVAIL_DRAWS = 3
STREAM_TRACE = 4
STREAM_SYNC_CHOICE = 5


class LatencySampler:
    """One latency distribution over [lo, hi] with a batched ``sample(n)``.

    ``sample(n)`` consumes the underlying ``RandomState`` stream exactly as
    n scalar ``sampler()`` calls would (numpy's legacy array fills loop the
    same per-value routine), so batched and per-dispatch callers interleave
    freely without perturbing each other's draws.
    """

    def __init__(self, kind: str, lo: float, hi: float, seed: int = 0):
        if kind not in ("uniform", "longtail", "lognormal"):
            raise ValueError(f"unknown latency kind {kind!r}")
        self.kind = kind
        self.lo, self.hi = float(lo), float(hi)
        self.rng = np.random.RandomState(seed)

    def sample(self, n: int) -> np.ndarray:
        """Draw ``n`` latencies as one vectorized call; (n,) float64."""
        lo, hi = self.lo, self.hi
        if self.kind == "uniform":
            return self.rng.uniform(lo, hi, size=n)
        if self.kind == "longtail":
            # Pareto-shaped: mass near lo, tail to hi
            x = np.power(1.0 - self.rng.rand(n), -1.0 / 1.5) - 1.0
            return np.clip(lo * (1.0 + x), lo, hi)
        # lognormal — heavy-tail in log space: median at the lower quartile
        # of the log-range, sigma a quarter of the log-range; most clients
        # sit near ``lo`` with a long straggler tail toward ``hi`` (clipped
        # to the support, like the other kinds).
        span = np.log(hi / lo)
        mu = np.log(lo) + 0.25 * span
        sigma = 0.25 * span
        return np.clip(np.exp(self.rng.normal(mu, sigma, size=n)), lo, hi)

    def __call__(self) -> float:
        return float(self.sample(1)[0])


def make_latency_sampler(kind: str, lo: float, hi: float,
                         seed: int = 0) -> LatencySampler:
    return LatencySampler(kind, lo, hi, seed)


class PerClientLatency:
    """Fixed mean latency per client + per-dispatch jitter, as in FLGO:
    heterogeneity lives across clients, not only across dispatches.

    The per-client means and the per-dispatch jitter draw from DISTINCT
    sub-seeded RNG streams (they used to share ``RandomState(seed)``, which
    correlated the means with the first jitter draws). The means are one
    batched ``sample(num_clients)`` draw — bit-identical to the historical
    python loop of scalar calls, and O(1) python cost at C=10^6. The jitter
    stream is exposed as ``self.rng`` so the simulator can snapshot/restore
    it across checkpoints; ``sample_for(cids)`` draws a whole wave's
    jittered latencies from it in one call.
    """

    def __init__(self, kind: str, lo: float, hi: float, num_clients: int,
                 seed: int = 0):
        sampler = make_latency_sampler(kind, lo, hi,
                                       _subseed(seed, STREAM_MEANS))
        self.means = sampler.sample(num_clients)
        self.lo, self.hi = lo, hi
        self.rng = np.random.RandomState(_subseed(seed, STREAM_JITTER))

    def sample_for(self, client_ids) -> np.ndarray:
        """Jittered response times for a batch of dispatches, one vectorized
        draw; consumes the jitter stream exactly as len(client_ids) scalar
        calls would."""
        cids = np.asarray(client_ids, np.int64)
        jitter = self.rng.uniform(0.9, 1.1, size=cids.shape[0])
        return np.clip(self.means[cids] * jitter, self.lo, self.hi)

    def __call__(self, client_id: int) -> float:
        return float(self.sample_for([client_id])[0])


def per_client_latency(kind: str, lo: float, hi: float, num_clients: int,
                       seed: int = 0):
    """Build the per-client latency process; returns (sampler, means) where
    ``sampler(client_id)`` draws one jittered response time,
    ``sampler.sample_for(cids)`` a batch (and carries its RNG as
    ``sampler.rng`` — see ``PerClientLatency``)."""
    lat = PerClientLatency(kind, lo, hi, num_clients, seed)
    return lat, lat.means


AVAILABILITY_KINDS = ("always", "uniform", "hetero", "slow-fragile", "trace")


def per_client_availability(kind: str, dropout_rate: float, num_clients: int,
                            seed: int = 0,
                            latency_means=None) -> np.ndarray:
    """Per-client probability that a dispatch completes successfully.

    ``always``        every dispatch succeeds (dropout disabled)
    ``uniform``       every client succeeds w.p. 1 - dropout_rate
    ``hetero``        per-client Beta-distributed success probs with mean
                      1 - dropout_rate — some clients are chronically flaky
                      (FLGo's intermittently-available population)
    ``slow-fragile``  dropout concentrated on the slowest clients (success
                      prob decays with the client's mean latency) — couples
                      system heterogeneity to availability, the adversarial
                      case for staleness policies
    ``trace``         handled by ``AvailabilityTrace`` (deterministic on/off
                      schedule replay); this helper returns all-ones since
                      no Bernoulli probabilities are drawn for it
    """
    if kind in ("always", "trace") or dropout_rate <= 0.0:
        return np.ones(num_clients)
    if not 0.0 < dropout_rate < 1.0:
        raise ValueError(f"dropout_rate must be in (0, 1), got {dropout_rate}")
    rng = np.random.RandomState(_subseed(seed, STREAM_AVAILABILITY))
    if kind == "uniform":
        return np.full(num_clients, 1.0 - dropout_rate)
    if kind == "hetero":
        # Beta(a, b) with mean 1-rate and fixed concentration a+b=8
        conc = 8.0
        a = conc * (1.0 - dropout_rate)
        return rng.beta(a, conc - a, size=num_clients)
    if kind == "slow-fragile":
        if latency_means is None:
            raise ValueError("slow-fragile availability needs latency_means")
        m = np.asarray(latency_means, np.float64)
        rank = (m - m.min()) / max(m.max() - m.min(), 1e-12)
        # fastest client ~always available; slowest drops at 2x the mean rate
        p = 1.0 - dropout_rate * 2.0 * rank
        return np.clip(p, 0.05, 1.0)
    raise ValueError(f"unknown availability kind {kind!r}; "
                     f"known: {AVAILABILITY_KINDS}")


class AvailabilityTrace:
    """Per-client on/off ping schedules, replayed deterministically.

    FLGo's phone simulator replays real mobile-usage pings: a client is
    reachable only inside its recorded on-intervals. This is the synthetic
    equivalent: each client holds a sorted array of toggle times — the
    client starts in ``start_on[c]`` state at t=0 and flips state at every
    toggle — and a dispatch at virtual time ``t`` succeeds iff the client is
    on at ``t``. Replay is pure lookup (``searchsorted`` into the client's
    toggle run), so availability consumes NO RNG stream at dispatch time:
    trace runs share the exact client-sampling and latency streams of a
    dropout-free run.

    Storage is one concatenated toggle array with per-client offsets, so a
    trace over C clients costs O(total toggles), not O(C x horizon).
    """

    def __init__(self, toggles: np.ndarray, offsets: np.ndarray,
                 start_on: np.ndarray):
        self.toggles = np.asarray(toggles, np.float64)
        self.offsets = np.asarray(offsets, np.int64)      # (C + 1,)
        self.start_on = np.asarray(start_on, bool)        # (C,)
        assert self.offsets.shape[0] == self.start_on.shape[0] + 1
        assert self.offsets[-1] == self.toggles.shape[0]

    @property
    def num_clients(self) -> int:
        return self.start_on.shape[0]

    def on_at(self, client_ids, ts) -> np.ndarray:
        """(B,) bool: is each client on at its dispatch time? Vectorized
        over the batch; each lookup counts the client's toggles before t
        (an odd count flips the start state)."""
        cids = np.asarray(client_ids, np.int64)
        ts = np.asarray(ts, np.float64)
        lo = self.offsets[cids]
        hi = self.offsets[cids + 1]
        # one searchsorted over the concatenated runs: biasing each query
        # by its client's window keeps the lookup inside that client's run
        flips = np.empty(cids.shape[0], np.int64)
        for i in range(cids.shape[0]):
            flips[i] = np.searchsorted(self.toggles[lo[i]:hi[i]], ts[i],
                                       side="right")
        return self.start_on[cids] ^ (flips % 2 == 1)

    def on_fraction(self, horizon: float) -> np.ndarray:
        """(C,) per-client fraction of [0, horizon] spent on (for tests)."""
        out = np.empty(self.num_clients)
        for c in range(self.num_clients):
            tg = self.toggles[self.offsets[c]:self.offsets[c + 1]]
            edges = np.concatenate([[0.0], np.clip(tg, 0.0, horizon),
                                    [horizon]])
            spans = np.diff(edges)
            state = self.start_on[c]
            on = 0.0
            for s in spans:
                if state:
                    on += s
                state = not state
            out[c] = on / horizon
        return out


def make_availability_trace(num_clients: int, horizon: float,
                            off_fraction: float, seed: int = 0, *,
                            mean_session: float = 0.0) -> AvailabilityTrace:
    """Synthetic trace generator: alternating exponential on/off sessions.

    Each client alternates on-sessions (mean ``mean_session``) and
    off-sessions (scaled so the long-run off fraction is ``off_fraction``),
    with its own phase — the FLGo-phone-style intermittent population
    without needing real usage logs. ``mean_session`` defaults to
    ``horizon / 20`` so a default trace toggles ~tens of times per run.
    Deterministic in (num_clients, horizon, off_fraction, seed).
    """
    if not 0.0 <= off_fraction < 1.0:
        raise ValueError(f"off_fraction must be in [0, 1), got {off_fraction}")
    rng = np.random.RandomState(_subseed(seed, STREAM_TRACE))
    mean_on = mean_session or horizon / 20.0
    mean_off = (mean_on * off_fraction / (1.0 - off_fraction)
                if off_fraction > 0.0 else 0.0)
    runs, offsets, start_on = [], [0], np.empty(num_clients, bool)
    total = 0
    for c in range(num_clients):
        start_on[c] = bool(rng.rand() >= off_fraction)
        if off_fraction <= 0.0:
            offsets.append(total)
            continue
        t, toggles, on = 0.0, [], bool(start_on[c])
        while t < horizon:
            t += rng.exponential(mean_on if on else mean_off)
            if t >= horizon:
                break
            toggles.append(t)
            on = not on
        runs.append(np.asarray(toggles))
        total += len(toggles)
        offsets.append(total)
    toggles = (np.concatenate(runs) if runs else np.zeros(0))
    return AvailabilityTrace(toggles, np.asarray(offsets, np.int64), start_on)
