"""Run-merged event timeline: the simulator's population-scale event queue.

The legacy timeline was a ``heapq`` of ``_Event`` tuples — one python push
per dispatch, one pop per completion. At C=10^5-10^6 with thousands of
in-flight dispatches the per-event python cost dominates the run. This
module replaces the heap with a *k-way run merge*: a batched dispatch (one
wave's replacements, or the whole initial concurrency block) inserts ONE
presorted run of numpy arrays, and ``pop()`` merges run heads through a
small heap whose size is the number of live runs (~ in-flight / wave size),
not the number of in-flight events.

Ordering is identical to the legacy heap: events sort by ``(t_done, seq)``
and ``seq`` is unique, so the merge is a total order and the simulator's
wave boundaries, RNG consumption and receive order are unchanged.
``extend_arrays`` is the single insertion choke point — scalar ``push``
delegates to it — which is also what the event-spy tests hook.
"""
from __future__ import annotations

import heapq
from typing import List, NamedTuple, Optional

import numpy as np


class _Event(NamedTuple):
    """One in-flight dispatch. ``snapshot`` is the global model captured at
    dispatch time — a flat (d,) vector or a ``(source, row)`` reference into
    a batched-ingest snapshot sequence (cohort engine), or the params pytree
    (sequential engine); ``ok`` is the availability draw — False means the
    client never reports back and the slot re-dispatches at ``t_done``."""
    t_done: float
    seq: int
    cid: int
    snapshot: object
    version: int
    ok: bool


class _Run:
    """One presorted batch of events (column arrays + snapshot refs)."""
    __slots__ = ("t", "seq", "cid", "version", "ok", "snaps")

    def __init__(self, t, seq, cid, version, ok, snaps):
        self.t, self.seq, self.cid = t, seq, cid
        self.version, self.ok, self.snaps = version, ok, snaps


class Timeline:
    """Min-ordered event queue over ``(t_done, seq)`` with batch insertion.

    ``_heap`` holds ``(t_head, seq_head, run, i)`` cursors, one per
    non-exhausted run; ``(t, seq)`` pairs are unique so tuple comparison
    never reaches the run object. Scalar pushes create single-event runs —
    the sequential engine's timeline degenerates to the legacy heap with
    identical complexity.
    """

    def __init__(self):
        self._heap: List[tuple] = []
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def __bool__(self) -> bool:
        return self._n > 0

    def head_t(self) -> Optional[float]:
        """The next event's ``t_done`` (None when empty) — the wave-boundary
        probe, replacing ``heap[0].t_done``."""
        return float(self._heap[0][0]) if self._heap else None

    def extend_arrays(self, t_done, seqs, cids, versions, oks,
                      snapshots) -> None:
        """Insert one batch of events. Array-likes of equal length n plus a
        length-n list of snapshot refs; sorted here by ``(t_done, seq)`` so
        callers pass dispatch order. THE insertion choke point: every event
        — batched or scalar — enters the timeline through this call."""
        t = np.asarray(t_done, np.float64)
        seqs = np.asarray(seqs, np.int64)
        n = t.shape[0]
        if n == 0:
            return
        cids = np.asarray(cids, np.int64)
        versions = np.asarray(versions, np.int64)
        oks = np.asarray(oks, bool)
        assert len(snapshots) == n
        order = np.lexsort((seqs, t))
        if not np.array_equal(order, np.arange(n)):
            t, seqs, cids = t[order], seqs[order], cids[order]
            versions, oks = versions[order], oks[order]
            snapshots = [snapshots[i] for i in order]
        run = _Run(t, seqs, cids, versions, oks, list(snapshots))
        heapq.heappush(self._heap, (t[0], seqs[0], run, 0))
        self._n += n

    def push(self, ev: _Event) -> None:
        self.extend_arrays([ev.t_done], [ev.seq], [ev.cid], [ev.version],
                           [ev.ok], [ev.snapshot])

    def pop(self) -> _Event:
        t, s, run, i = heapq.heappop(self._heap)
        ev = _Event(float(t), int(s), int(run.cid[i]), run.snaps[i],
                    int(run.version[i]), bool(run.ok[i]))
        run.snaps[i] = None            # release the snapshot ref promptly
        j = i + 1
        if j < run.seq.shape[0]:
            heapq.heappush(self._heap, (run.t[j], run.seq[j], run, j))
        self._n -= 1
        return ev

    def peek_wave_cids(self, latency_lo: float, max_cohort: int,
                       horizon: float) -> np.ndarray:
        """Client ids of the OK events the NEXT wave would train, without
        consuming anything — a non-destructive replica of the cohort
        drain's wave rule (maximal prefix with ``t_done < t_first +
        latency_lo``, capped at ``max_cohort``, truncated at the horizon).
        This is what makes shard prefetch possible: the moment a wave's
        replacement dispatches are inserted, the next wave's member set is
        already determined. Walks a shallow copy of the run-cursor heap —
        O(wave * log runs), no event is popped and no run is mutated."""
        heap = list(self._heap)      # cursor tuples are immutable; runs
        if not heap:                 # are shared read-only
            return np.empty(0, np.int64)
        t, _s, run, i = heapq.heappop(heap)
        if t > horizon:
            return np.empty(0, np.int64)
        bound = t + latency_lo
        out, count = [], 0
        while True:
            if run.ok[i]:
                out.append(int(run.cid[i]))
            count += 1
            j = i + 1
            if j < run.seq.shape[0]:
                heapq.heappush(heap, (run.t[j], run.seq[j], run, j))
            if not heap or count >= max_cohort:
                break
            t, _s, run, i = heapq.heappop(heap)
            if t >= bound or t > horizon:
                break
        return np.asarray(out, np.int64)

    def events(self) -> List[_Event]:
        """All in-flight events in ``(t_done, seq)`` order (checkpointing)."""
        out = []
        for _, _, run, i in self._heap:
            for j in range(i, run.seq.shape[0]):
                out.append(_Event(float(run.t[j]), int(run.seq[j]),
                                  int(run.cid[j]), run.snaps[j],
                                  int(run.version[j]), bool(run.ok[j])))
        out.sort(key=lambda e: (e.t_done, e.seq))
        return out

    def clear(self) -> None:
        self._heap.clear()
        self._n = 0
