"""Minimal client-side data loading: shuffled epoch batch iterators."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np

from repro.data.synthetic import SyntheticClassification


@dataclass
class ClientDataset:
    data: SyntheticClassification

    def __len__(self):
        return len(self.data)

    def epochs(self, num_epochs: int, batch_size: int, seed: int) -> Iterator[dict]:
        rng = np.random.RandomState(seed)
        n = len(self.data)
        bs = min(batch_size, n)
        for _ in range(num_epochs):
            order = rng.permutation(n)
            for start in range(0, n - bs + 1, bs):
                idx = order[start:start + bs]
                yield {"x": self.data.x[idx].astype(np.float32),
                       "y": self.data.y[idx].astype(np.int32)}


def batch_iterator(ds: SyntheticClassification, batch_size: int,
                   seed: int = 0) -> Iterator[dict]:
    """Endless shuffled batches (evaluation/training streams)."""
    rng = np.random.RandomState(seed)
    n = len(ds)
    while True:
        order = rng.permutation(n)
        for start in range(0, n - batch_size + 1, batch_size):
            idx = order[start:start + batch_size]
            yield {"x": ds.x[idx].astype(np.float32),
                   "y": ds.y[idx].astype(np.int32)}
