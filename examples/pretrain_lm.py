"""End-to-end driver: federated pretraining of a transformer LM with FedPSA.

    PYTHONPATH=src python examples/pretrain_lm.py                 # ~20M model
    PYTHONPATH=src python examples/pretrain_lm.py --preset 100m   # ~100M model

Exercises the SAME sharded train_step the production dry-run lowers (here on
1 CPU device with empty rules), driven by the asynchronous FedPSA server:
clients hold disjoint shards of a synthetic bigram corpus, train locally
with AdamW, and upload deltas + sensitivity sketches; the server runs
Algorithm 1. A few hundred aggregate steps of the default preset fit in CPU
minutes; `--preset 100m` is the full-scale variant of the same driver.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.sharding import SINGLE_DEVICE_RULES as R
from repro.common import tree as tu
from repro.core import PSAConfig, client_sketch, init_state, server_step
from repro.data import make_lm_corpus
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw, apply_updates, warmup_cosine
from repro.checkpoint import save_pytree

PRESETS = {
    # ~2M params: CI smoke
    "tiny": dict(num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
                 d_ff=512, vocab_size=1024),
    # ~20M params: quick CPU demo
    "20m": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=4,
                d_ff=1024, vocab_size=2048),
    # ~100M params: the assignment's "train a ~100M model" scale
    "100m": dict(num_layers=8, d_model=768, num_heads=12, num_kv_heads=12,
                 d_ff=3072, vocab_size=8192),
}


def make_cfg(preset: str) -> ModelConfig:
    p = PRESETS[preset]
    return ModelConfig(
        name=f"pretrain-{preset}", family="dense",
        block_pattern=("attn",), ffn_pattern=("dense",),
        dtype="float32", param_dtype="float32", remat="none",
        q_chunk=128, kv_chunk=128, **p)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="20m", choices=list(PRESETS))
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=40,
                    help="global aggregations (x buffer = client updates)")
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = make_cfg(args.preset)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    total, _ = M.count_params(cfg)
    print(f"[pretrain] {cfg.name}: {total/1e6:.1f}M params, "
          f"{args.clients} clients, buffer 2")

    corpus = make_lm_corpus(400_000, vocab=cfg.vocab_size, seed=0)
    shards = np.array_split(corpus, args.clients)

    opt = adamw(weight_decay=0.01)
    schedule = warmup_cosine(args.lr, 20, args.rounds * 2)

    def loss_fn(p, batch):
        return M.loss_fn(p, batch, cfg, R)

    @jax.jit
    def local_step(p, opt_state, batch, lr):
        l, g = jax.value_and_grad(loss_fn)(p, batch)
        upd, opt_state = opt.update(g, opt_state, p, lr)
        return apply_updates(p, upd), opt_state, l

    def sample_batch(shard, rng):
        starts = rng.randint(0, len(shard) - args.seq - 1, size=args.batch)
        toks = np.stack([shard[s:s + args.seq + 1] for s in starts])
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}

    psa_cfg = PSAConfig(buffer_size=2, queue_len=10, sketch_k=16)
    rng = np.random.RandomState(0)
    calib = sample_batch(corpus, rng)

    @jax.jit
    def sketch_of(p):
        return client_sketch(loss_fn, p, calib, psa_cfg)

    # Functional server core: flat parameter vector + fused Algorithm-1 step
    # (receive + conditional aggregate + global-sketch refresh, one jit call).
    spec = tu.FlatSpec(params)
    psa = init_state(psa_cfg, spec.size, sketch_of(params))
    g_vec = spec.flatten(params)

    @jax.jit
    def fused_step(psa, g_vec, delta_vec, sketch_vec):
        return server_step(psa, g_vec, delta_vec, sketch_vec, psa_cfg,
                           lambda vec: sketch_of(spec.unflatten(vec)))

    t0 = time.time()
    losses = []
    step = 0
    version = 0
    while version < args.rounds:
        cid = rng.randint(args.clients)
        p_local = params
        opt_state = opt.init(p_local)
        for _ in range(args.local_steps):
            lr = schedule(step)
            p_local, opt_state, l = local_step(
                p_local, opt_state, sample_batch(shards[cid], rng), lr)
            step += 1
        delta = tu.tree_sub(p_local, params)
        psa, g_vec, info = fused_step(psa, g_vec, spec.flatten(delta),
                                      sketch_of(p_local))
        losses.append(float(l))
        if bool(info.updated):
            version += 1
            params = spec.unflatten(g_vec)
            if version % 5 == 0 or version == args.rounds:
                temp = float(info.temp) if bool(info.temp_valid) else None
                print(f"[pretrain] agg {version:4d} "
                      f"loss {np.mean(losses[-8:]):.3f} temp={temp} "
                      f"({time.time()-t0:.0f}s)")

    if args.ckpt:
        save_pytree(params, args.ckpt, step=args.rounds)
        print(f"[pretrain] checkpoint -> {args.ckpt}")
    ppl0 = np.exp(losses[0])
    ppl1 = np.exp(np.mean(losses[-8:]))
    print(f"[pretrain] perplexity {ppl0:.1f} -> {ppl1:.1f} "
          f"(bigram floor ~ branching=8)")


if __name__ == "__main__":
    main()
