"""Unified model assembly for every assigned architecture family.

A model is a stack of *superblocks* executed with ``lax.scan`` over stacked
parameters (keeps the HLO compact at 126-layer / 16k-dim scale). Each
superblock is a fixed sequence of positions; position ``p`` has a sequence
mixer (``attn | mamba | mlstm | slstm``) and a feed-forward kind
(``dense | moe | moe+dense | none``), both taken from the config patterns.

Three execution paths share the same parameters:

* ``loss_fn`` / ``forward``      — training & evaluation (full sequence)
* ``prefill``                    — full sequence, additionally returns the
                                   decode cache (KV ring buffers / SSM states)
* ``decode_step``                — one token against the cache (``serve_step``)

The paper's CNN / linear models (MNIST, FMNIST, CIFAR) live here too — the
federated runtime trains them for the accuracy experiments, while the
transformer families exercise the production dry-run meshes.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.sharding import LogicalRules, with_logical_constraint
from repro.models import layers, moe, ssm
from repro.models.config import ModelConfig
from repro.models.member_math import member_dot


# ---------------------------------------------------------------------------
# Superblock init / axes
# ---------------------------------------------------------------------------

def _norm_init(cfg: ModelConfig):
    pd = layers.param_dtype_of(cfg)
    if cfg.family == "audio":
        return lambda d: layers.init_layernorm(d, pd)
    return lambda d: layers.init_rmsnorm(d, pd)


def _norm_apply(cfg: ModelConfig):
    if cfg.family == "audio":
        return lambda p, x: layers.layernorm(p, x, cfg.norm_eps)
    return lambda p, x: layers.rmsnorm(p, x, cfg.norm_eps)


_MIXER_INIT = {
    "attn": layers.init_attention,
    "mamba": ssm.init_mamba,
    "mlstm": ssm.init_mlstm,
    "slstm": ssm.init_slstm,
}
_MIXER_AXES = {
    "attn": layers.ATTN_AXES,
    "mamba": ssm.MAMBA_AXES,
    "mlstm": ssm.MLSTM_AXES,
    "slstm": ssm.SLSTM_AXES,
}


def init_superblock(key, cfg: ModelConfig) -> dict:
    """One superblock: a dict keyed ``p{i}`` per position."""
    out = {}
    keys = jax.random.split(key, len(cfg.block_pattern))
    ninit = _norm_init(cfg)
    for i, (mix, ffn) in enumerate(zip(cfg.block_pattern, cfg.ffn_pattern)):
        km, kf = jax.random.split(keys[i])
        pos: Dict[str, Any] = {
            "norm1": ninit(cfg.d_model),
            "mixer": _MIXER_INIT[mix](km, cfg),
        }
        if ffn != "none":
            pos["norm2"] = ninit(cfg.d_model)
            if ffn == "dense":
                pos["ffn"] = layers.init_ffn(kf, cfg)
            elif ffn == "moe":
                pos["ffn"] = init_moe_guarded(kf, cfg)
            elif ffn == "moe+dense":
                k1, k2 = jax.random.split(kf)
                pos["ffn"] = {"moe": init_moe_guarded(k1, cfg),
                              "dense": layers.init_ffn(k2, cfg)}
            else:
                raise ValueError(ffn)
        out[f"p{i}"] = pos
    return out


def init_moe_guarded(key, cfg: ModelConfig):
    assert cfg.num_experts > 0 and cfg.top_k > 0, cfg.name
    return moe.init_moe(key, cfg)


_NORM_AXES = {"scale": ("embed_act",)}
_NORM_AXES_LN = {"scale": ("embed_act",), "bias": ("embed_act",)}


def superblock_axes(cfg: ModelConfig) -> dict:
    naxes = _NORM_AXES_LN if cfg.family == "audio" else _NORM_AXES
    out = {}
    for i, (mix, ffn) in enumerate(zip(cfg.block_pattern, cfg.ffn_pattern)):
        pos = {"norm1": naxes, "mixer": dict(_MIXER_AXES[mix])}
        if mix == "attn":
            pass
        if ffn != "none":
            pos["norm2"] = naxes
            if ffn == "dense":
                pos["ffn"] = dict(layers.FFN_AXES)
            elif ffn == "moe":
                pos["ffn"] = _moe_axes(cfg)
            elif ffn == "moe+dense":
                pos["ffn"] = {"moe": _moe_axes(cfg), "dense": dict(layers.FFN_AXES)}
        out[f"p{i}"] = pos
    return out


def _moe_axes(cfg: ModelConfig) -> dict:
    ax = dict(moe.MOE_AXES)
    if cfg.num_shared_experts == 0:
        ax.pop("shared", None)
    return ax


def _prune_axes(axes, params):
    """Drop axis entries whose key is absent from params (e.g. swiglu gate)."""
    if isinstance(params, dict):
        return {k: _prune_axes(axes[k], v) for k, v in params.items()}
    return axes


# ---------------------------------------------------------------------------
# Whole-model init / axes
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> dict:
    if cfg.family == "cnn":
        return init_cnn(key, cfg)
    if cfg.family == "mlp":
        return init_mlp(key, cfg)
    k_embed, k_blocks, k_final = jax.random.split(key, 3)
    nsb = cfg.num_superblocks
    blocks = jax.vmap(lambda k: init_superblock(k, cfg))(jax.random.split(k_blocks, nsb))
    params = {
        "blocks": blocks,
        "final_norm": _norm_init(cfg)(cfg.d_model),
    }
    if cfg.frontend == "audio":
        # Frontend stub: inputs are precomputed frame embeddings (B, S, D).
        # A learned input projection + cls head stand in for the conv codec.
        params["in_proj"] = layers.dense_init(k_embed, (cfg.d_model, cfg.d_model),
                                              layers.param_dtype_of(cfg))
        cls = layers.dense_init(k_final, (cfg.d_model, cfg.vocab_size),
                                layers.param_dtype_of(cfg))
        params["cls"] = layers._pad_to(cls, cfg.vocab_padded, 1)
    else:
        params["embed"] = layers.init_embed(k_embed, cfg)
        if cfg.frontend == "vision":
            # projector from (stubbed) vision embeddings into the LM space
            params["proj"] = layers.dense_init(k_final, (cfg.d_model, cfg.d_model),
                                               layers.param_dtype_of(cfg))
    return params


def param_axes(cfg: ModelConfig, params: Optional[dict] = None) -> dict:
    """Pytree of logical-axis tuples matching ``init_params`` structure.

    Stacked superblock leaves get a leading ``layers`` axis.
    """
    if cfg.family in ("cnn", "mlp"):
        if params is None:
            params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
        return jax.tree_util.tree_map(lambda x: tuple([None] * x.ndim), params)
    sb = superblock_axes(cfg)
    sb = jax.tree_util.tree_map(
        lambda ax: ("layers",) + ax,
        sb,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )
    naxes = _NORM_AXES_LN if cfg.family == "audio" else _NORM_AXES
    axes = {"blocks": sb, "final_norm": naxes}
    if cfg.frontend == "audio":
        axes["in_proj"] = ("embed", "embed_act")
        axes["cls"] = ("embed", "vocab")
    else:
        axes["embed"] = dict(layers.EMBED_AXES)
        if cfg.tie_embeddings:
            axes["embed"].pop("unembed")
        if cfg.frontend == "vision":
            axes["proj"] = ("embed", "embed_act")
    if params is None:
        params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    return _prune_axes(axes, params)


# ---------------------------------------------------------------------------
# Superblock forward (train / prefill) and decode
# ---------------------------------------------------------------------------

def _ffn_apply(pos_params, ffn_kind, x, cfg, rules):
    if ffn_kind == "dense":
        return layers.ffn_forward(pos_params["ffn"], x, cfg, rules), 0.0
    if ffn_kind == "moe":
        return moe.moe_forward(pos_params["ffn"], x, cfg, rules)
    if ffn_kind == "moe+dense":
        y_moe, aux = moe.moe_forward(pos_params["ffn"]["moe"], x, cfg, rules)
        y_dense = layers.ffn_forward(pos_params["ffn"]["dense"], x, cfg, rules)
        return y_moe + y_dense, aux
    raise ValueError(ffn_kind)


def _residual_constraint(x, cfg: ModelConfig, rules: LogicalRules):
    """Between-block residual-stream sharding (Megatron-SP when seq_shard)."""
    if cfg.seq_shard:
        return with_logical_constraint(x, rules, ("batch", "seq_act", "embed_act"))
    return with_logical_constraint(x, rules, ("batch", None, "embed_act"))


def superblock_forward(params, x, cfg: ModelConfig, rules: LogicalRules, positions):
    napply = _norm_apply(cfg)
    aux_total = jnp.float32(0.0)
    for i, (mix, ffn) in enumerate(zip(cfg.block_pattern, cfg.ffn_pattern)):
        pp = params[f"p{i}"]
        h = napply(pp["norm1"], x)
        if mix == "attn":
            y = layers.attention_forward(pp["mixer"], h, cfg, rules, positions)
        elif mix == "mamba":
            y = ssm.mamba_forward(pp["mixer"], h, cfg, rules)
        elif mix == "mlstm":
            y = ssm.mlstm_forward(pp["mixer"], h, cfg, rules)
        else:
            y = ssm.slstm_forward(pp["mixer"], h, cfg, rules)
        x = _residual_constraint(x + y, cfg, rules)
        if ffn != "none":
            h = napply(pp["norm2"], x)
            y, aux = _ffn_apply(pp, ffn, h, cfg, rules)
            x = _residual_constraint(x + y, cfg, rules)
            aux_total = aux_total + aux
    return x, aux_total


def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def backbone_forward(params, x, cfg: ModelConfig, rules: LogicalRules,
                     positions=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run all superblocks. x: (B, S, D) -> (hidden, aux_loss).

    With ``cfg.scan_groups = G > 1`` the layer stack runs as a two-level
    scan: the outer scan saves only G carries for backward, and the inner
    scan over superblocks-per-group is inside the jax.checkpoint and is
    recomputed — the saved-activation stack shrinks num_superblocks/G x.
    """
    if positions is None:
        positions = jnp.arange(x.shape[1])[None, :]
    x = _residual_constraint(x, cfg, rules)

    def body(carry, sb_params):
        h, aux = carry
        h, a = superblock_forward(sb_params, h, cfg, rules, positions)
        return (h, aux + a), None

    G = cfg.scan_groups
    nsb = cfg.num_superblocks
    if G and G > 1 and nsb % G == 0:
        blocks = jax.tree_util.tree_map(
            lambda a: a.reshape((G, nsb // G) + a.shape[1:]), params["blocks"])
        # sqrt-remat: checkpoint BOTH levels. The outer checkpoint keeps the
        # saved stack at G carries; the inner checkpoint makes the group
        # backward re-derive one superblock's intermediates at a time instead
        # of holding all nsb/G layers' attention blocks simultaneously.
        inner_body = _remat_wrap(body, cfg)

        def group_body(carry, group_params):
            out, _ = jax.lax.scan(inner_body, carry, group_params)
            return out, None

        group_body = _remat_wrap(group_body, cfg)
        (x, aux), _ = jax.lax.scan(group_body, (x, jnp.float32(0.0)), blocks)
    else:
        body = _remat_wrap(body, cfg)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["blocks"])
    x = _norm_apply(cfg)(params["final_norm"], x)
    return x, aux


# ---------------------------------------------------------------------------
# Embedding of heterogeneous inputs
# ---------------------------------------------------------------------------

def embed_inputs(params, batch: dict, cfg: ModelConfig, rules: LogicalRules):
    """Returns (x, label_mask_extra) where x: (B, S, D)."""
    if cfg.frontend == "audio":
        x = batch["features"].astype(layers.dtype_of(cfg))
        x = member_dot(x, params["in_proj"].astype(x.dtype))
        return with_logical_constraint(x, rules, ("batch", "seq", "embed_act"))
    tok = layers.embed_tokens(params["embed"], batch["tokens"], cfg, rules)
    if cfg.frontend == "vision" and "patches" in batch:
        p = batch["patches"].astype(tok.dtype)
        p = member_dot(p, params["proj"].astype(tok.dtype))
        tok = jnp.concatenate([p, tok], axis=1)
    return with_logical_constraint(tok, rules, ("batch", "seq", "embed_act"))


# ---------------------------------------------------------------------------
# Loss (chunked over sequence so full-vocab f32 logits never materialize)
# ---------------------------------------------------------------------------

def _xent_from_logits(logits, labels):
    """logits (N, V) any dtype (pad vocab columns already masked);
    labels (N,) int32, <0 = masked. f32 math."""
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[:, None], axis=-1)[:, 0]
    nll = (lse - gold) * mask
    return jnp.sum(nll), jnp.sum(mask)


def chunked_cross_entropy(hidden, unembed_w, labels, cfg: ModelConfig,
                          rules: LogicalRules, chunk: int = 1024):
    """hidden (B, S, D); unembed_w (D, V); labels (B, S)."""
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    n = -(-S // chunk)
    Sp = n * chunk
    if Sp != S:
        hidden = jnp.pad(hidden, ((0, 0), (0, Sp - S), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, Sp - S)), constant_values=-1)
    hid = hidden.reshape(B, n, chunk, D)
    lab = labels.reshape(B, n, chunk)

    def body(carry, idx):
        tot, cnt = carry
        h = hid[:, idx].reshape(B * chunk, D)
        logits = member_dot(h, unembed_w.astype(h.dtype))
        logits = layers.mask_vocab_pad(logits, cfg)
        logits = with_logical_constraint(logits, rules, ("tokens", "vocab"))
        t, c = _xent_from_logits(logits, lab[:, idx].reshape(-1))
        return (tot + t, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                 jnp.arange(n))
    return tot / jnp.maximum(cnt, 1.0)


def _unembed_weight(params, cfg: ModelConfig):
    if cfg.frontend == "audio":
        return params["cls"]
    if cfg.tie_embeddings:
        return params["embed"]["tok"].T
    return params["embed"]["unembed"]


def loss_fn(params, batch: dict, cfg: ModelConfig, rules: LogicalRules):
    """Mean next-token (LM) / per-frame (audio) cross entropy + MoE aux."""
    if cfg.family == "cnn":
        return cnn_loss(params, batch, cfg)
    if cfg.family == "mlp":
        return mlp_loss(params, batch, cfg)
    x = embed_inputs(params, batch, cfg, rules)
    hidden, aux = backbone_forward(params, x, cfg, rules)
    labels = batch["labels"]
    if cfg.frontend == "vision" and "patches" in batch:
        # patch positions carry no labels
        P = batch["patches"].shape[1]
        pad = jnp.full(labels.shape[:1] + (P,), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    if cfg.causal:
        # predict token t+1 from position t
        hidden = hidden[:, :-1]
        labels = labels[:, 1:]
    w = _unembed_weight(params, cfg)
    ce = chunked_cross_entropy(hidden, w, labels, cfg, rules)
    return ce + aux


def forward_logits(params, batch: dict, cfg: ModelConfig, rules: LogicalRules):
    """Full logits (small models / eval only)."""
    x = embed_inputs(params, batch, cfg, rules)
    hidden, _ = backbone_forward(params, x, cfg, rules)
    w = _unembed_weight(params, cfg)
    logits = member_dot(hidden, w.astype(hidden.dtype))
    logits = layers.mask_vocab_pad(logits, cfg)
    return with_logical_constraint(logits, rules, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# Decode path (serve_step): cache init / prefill / one-token step
# ---------------------------------------------------------------------------

def _pos_cache_init(mix: str, cfg: ModelConfig, batch: int, max_len: int):
    if mix == "attn":
        return layers.init_attention_cache(cfg, batch, max_len)
    if mix == "mamba":
        return ssm.init_mamba_state(cfg, batch)
    if mix == "mlstm":
        return ssm.init_mlstm_state(cfg, batch)
    return ssm.init_slstm_state(cfg, batch)


_POS_CACHE_AXES = {
    "attn": layers.ATTN_CACHE_AXES,
    "mamba": ssm.MAMBA_STATE_AXES,
    "mlstm": ssm.MLSTM_STATE_AXES,
    "slstm": ssm.SLSTM_STATE_AXES,
}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    assert cfg.has_decode, f"{cfg.name} is encoder-only: no decode path"
    one = {f"p{i}": _pos_cache_init(mix, cfg, batch, max_len)
           for i, mix in enumerate(cfg.block_pattern)}
    nsb = cfg.num_superblocks
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (nsb,) + x.shape), one)


def cache_axes(cfg: ModelConfig) -> dict:
    one = {f"p{i}": dict(_POS_CACHE_AXES[mix])
           for i, mix in enumerate(cfg.block_pattern)}
    return jax.tree_util.tree_map(
        lambda ax: ("layers",) + ax, one,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


def superblock_decode(params, cache, x, pos, cfg: ModelConfig, rules: LogicalRules):
    napply = _norm_apply(cfg)
    new_cache = {}
    for i, (mix, ffn) in enumerate(zip(cfg.block_pattern, cfg.ffn_pattern)):
        pp = params[f"p{i}"]
        h = napply(pp["norm1"], x)
        if mix == "attn":
            c, y = layers.attention_decode(pp["mixer"], cache[f"p{i}"], h, pos, cfg, rules)
        elif mix == "mamba":
            c, y = ssm.mamba_decode(pp["mixer"], cache[f"p{i}"], h, cfg)
        elif mix == "mlstm":
            c, y = ssm.mlstm_decode(pp["mixer"], cache[f"p{i}"], h, cfg)
        else:
            c, y = ssm.slstm_decode(pp["mixer"], cache[f"p{i}"], h, cfg)
        new_cache[f"p{i}"] = c
        x = x + y
        if ffn != "none":
            h = napply(pp["norm2"], x)
            y, _ = _ffn_apply(pp, ffn, h, cfg, rules)
            x = x + y
    return new_cache, x


def decode_step(params, cache, tokens, pos, cfg: ModelConfig, rules: LogicalRules):
    """One-token decode. tokens: (B, 1) int32; pos: scalar int32.

    Returns (new_cache, logits (B, 1, V)).
    """
    x = layers.embed_tokens(params["embed"], tokens, cfg, rules)

    def body(h, xs):
        sb_params, sb_cache = xs
        c, h = superblock_decode(sb_params, sb_cache, h, pos, cfg, rules)
        return h, c

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = _norm_apply(cfg)(params["final_norm"], x)
    w = _unembed_weight(params, cfg)
    logits = member_dot(x, w.astype(x.dtype))
    logits = layers.mask_vocab_pad(logits, cfg)
    return new_cache, with_logical_constraint(logits, rules, ("batch", "seq", "vocab"))


def superblock_prefill(params, x, cfg: ModelConfig, rules: LogicalRules, positions,
                       max_len: Optional[int] = None):
    napply = _norm_apply(cfg)
    cache = {}
    for i, (mix, ffn) in enumerate(zip(cfg.block_pattern, cfg.ffn_pattern)):
        pp = params[f"p{i}"]
        h = napply(pp["norm1"], x)
        if mix == "attn":
            c, y = layers.attention_fill_cache(pp["mixer"], h, cfg, rules, max_len)
        elif mix == "mamba":
            c, y = ssm.mamba_fill_state(pp["mixer"], h, cfg, rules)
        elif mix == "mlstm":
            c, y = ssm.mlstm_fill_state(pp["mixer"], h, cfg, rules)
        else:
            c, y = ssm.slstm_fill_state(pp["mixer"], h, cfg, rules)
        cache[f"p{i}"] = c
        x = x + y
        if ffn != "none":
            h = napply(pp["norm2"], x)
            y, _ = _ffn_apply(pp, ffn, h, cfg, rules)
            x = x + y
    return cache, x


def prefill(params, batch: dict, cfg: ModelConfig, rules: LogicalRules,
            max_len: Optional[int] = None):
    """Full-sequence prefill. Returns (cache, last-position logits (B, V)).

    ``max_len`` sizes KV caches for the decode horizon (defaults to S).
    """
    x = embed_inputs(params, batch, cfg, rules)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(h, sb_params):
        c, h = superblock_prefill(sb_params, h, cfg, rules, positions, max_len)
        return h, c

    x, cache = jax.lax.scan(body, x, params["blocks"])
    x = _norm_apply(cfg)(params["final_norm"], x)
    w = _unembed_weight(params, cfg)
    last = x[:, -1]
    logits = member_dot(last, w.astype(x.dtype))
    logits = layers.mask_vocab_pad(logits, cfg)
    return cache, with_logical_constraint(logits, rules, ("batch", "vocab"))


def encode(params, batch: dict, cfg: ModelConfig, rules: LogicalRules):
    """Encoder-only forward (hubert): per-frame logits."""
    x = embed_inputs(params, batch, cfg, rules)
    hidden, _ = backbone_forward(params, x, cfg, rules)
    w = _unembed_weight(params, cfg)
    logits = member_dot(hidden, w.astype(hidden.dtype))
    logits = layers.mask_vocab_pad(logits, cfg)
    return with_logical_constraint(logits, rules, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# Paper models: CNN (MNIST / CIFAR) and linear / MLP (FMNIST)
# ---------------------------------------------------------------------------

def init_cnn(key, cfg: ModelConfig) -> dict:
    H, W, C = cfg.input_hw
    ks = jax.random.split(key, len(cfg.cnn_channels) + 3)
    params = {}
    in_c = C
    h, w = H, W
    for i, ch in enumerate(cfg.cnn_channels):
        params[f"conv{i}"] = {
            "w": layers.dense_init(ks[i], (cfg.cnn_kernel, cfg.cnn_kernel, in_c, ch),
                                   jnp.float32, scale=1.0 / math.sqrt(cfg.cnn_kernel ** 2 * in_c)),
            "b": jnp.zeros((ch,), jnp.float32),
        }
        in_c = ch
        h, w = h // 2, w // 2  # 2x2 maxpool each conv
    flat = h * w * in_c
    dims = (flat,) + tuple(cfg.mlp_hidden) + (cfg.num_classes,)
    for i in range(len(dims) - 1):
        params[f"fc{i}"] = {
            "w": layers.dense_init(ks[len(cfg.cnn_channels) + i], (dims[i], dims[i + 1]), jnp.float32),
            "b": jnp.zeros((dims[i + 1],), jnp.float32),
        }
    return params


def cnn_forward(params, x, cfg: ModelConfig):
    """x: (B, H, W, C) f32 -> logits (B, num_classes)."""
    for i in range(len(cfg.cnn_channels)):
        p = params[f"conv{i}"]
        x = jax.lax.conv_general_dilated(
            x, p["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x + p["b"])
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = x.reshape(x.shape[0], -1)
    n_fc = len(cfg.mlp_hidden) + 1
    for i in range(n_fc):
        p = params[f"fc{i}"]
        x = member_dot(x, p["w"]) + p["b"]
        if i < n_fc - 1:
            x = jax.nn.relu(x)
    return x


def cnn_loss(params, batch, cfg: ModelConfig):
    logits = cnn_forward(params, batch["x"], cfg)
    return _mean_xent(logits, batch["y"])


def init_mlp(key, cfg: ModelConfig) -> dict:
    H, _, _ = cfg.input_hw
    dims = (H,) + tuple(cfg.mlp_hidden) + (cfg.num_classes,)
    ks = jax.random.split(key, len(dims))
    return {
        f"fc{i}": {
            "w": layers.dense_init(ks[i], (dims[i], dims[i + 1]), jnp.float32),
            "b": jnp.zeros((dims[i + 1],), jnp.float32),  # paper: bias init 0
        }
        for i in range(len(dims) - 1)
    }


def mlp_forward(params, x, cfg: ModelConfig):
    n = len(cfg.mlp_hidden) + 1
    for i in range(n):
        p = params[f"fc{i}"]
        x = member_dot(x, p["w"]) + p["b"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


def mlp_loss(params, batch, cfg: ModelConfig):
    return _mean_xent(mlp_forward(params, batch["x"], cfg), batch["y"])


def _mean_xent(logits, y):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def predict(params, x, cfg: ModelConfig):
    if cfg.family == "cnn":
        return jnp.argmax(cnn_forward(params, x, cfg), axis=-1)
    if cfg.family == "mlp":
        return jnp.argmax(mlp_forward(params, x, cfg), axis=-1)
    raise ValueError(cfg.family)


def accuracy(params, batch, cfg: ModelConfig) -> jnp.ndarray:
    return jnp.mean((predict(params, batch["x"], cfg) == batch["y"]).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Parameter counting (eval_shape — no allocation, works at 405B scale)
# ---------------------------------------------------------------------------

def count_params(cfg: ModelConfig) -> Tuple[int, int]:
    """Returns (total, active) parameter counts. ``active`` discounts routed
    experts to top_k/E (MoE); equals total for dense models."""
    shapes = jax.eval_shape(functools.partial(init_params, jax.random.PRNGKey(0), cfg))
    total = 0
    expert_total = 0
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        total += n
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if any(k in ("w_in", "w_gate", "w_out") for k in keys) and cfg.num_experts > 0:
            # routed-expert weights carry an E dim right after the stacked
            # superblock (layers) dim: (layers, E, D, F)
            if len(leaf.shape) >= 3 and cfg.num_experts in leaf.shape[:2]:
                expert_total += n
    if cfg.num_experts > 0 and cfg.top_k > 0:
        active = total - expert_total + expert_total * cfg.top_k // cfg.num_experts
    else:
        active = total
    return total, active
