"""The `make roofline` chain: dry-run artifact production -> roofline table.

The dry-run MUST run as its own process (it forces 512 placeholder host
devices via XLA_FLAGS before any jax import), and benchmarks.roofline reads
its artifact dir from DRYRUN_DIR at import — so both halves run as
subprocesses against a tmpdir, exactly like the Makefile target.
"""
import json
import os
import subprocess
import sys

import pytest


def _run(argv, tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env["DRYRUN_DIR"] = str(tmp_path / "dryrun")
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable] + argv, env=env,
                          capture_output=True, text=True, timeout=300,
                          cwd=os.path.dirname(env["PYTHONPATH"]))


@pytest.mark.slow
def test_roofline_chain_renders_nonempty_table(tmp_path):
    out_dir = str(tmp_path / "dryrun")
    r = _run(["-m", "repro.launch.dryrun", "--arch", "internvl2-1b",
              "--shape", "train_4k", "--mesh", "pod", "--out", out_dir],
             tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "1 ok, 0 skipped, 0 errors" in r.stdout

    rec = json.load(open(os.path.join(out_dir,
                                      "internvl2-1b__train_4k__pod.json")))
    assert rec["status"] == "ok"
    assert rec["flops_per_device"] > 0
    # xla_cost_analysis must be a flat dict (jax>=0.4.30 returns a list of
    # per-device dicts from compiled.cost_analysis — the regression that
    # left roofline with no ok artifacts to read)
    assert isinstance(rec["xla_cost_analysis"], dict)

    r2 = _run(["-m", "benchmarks.roofline"], tmp_path)
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "internvl2-1b" in r2.stdout  # the table rendered a row
    rows = json.load(open(tmp_path / "roofline_pod.json"))
    assert len(rows) == 1
    assert rows[0]["dominant"] in ("compute", "memory", "collective")
    assert rows[0]["note"]


def test_roofline_empty_artifacts_is_a_clean_failure(tmp_path):
    """No artifacts -> exit 1 with a pointer at the producer, not a crash."""
    r = _run(["-m", "benchmarks.roofline"], tmp_path)
    assert r.returncode == 1
    assert "repro.launch.dryrun" in r.stderr
