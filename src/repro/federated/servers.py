"""Server-side aggregation strategies — thin shims over the policy core.

Every async algorithm (fedasync, fedbuff, fedpsa, ca2fl, fedfa, fedpac,
asyncfeded; the synchronous fedavg runs round-based in the simulator) is a
pure jit-compiled ``policy.step`` in ``repro.federated.policies``.
``PolicyServer`` adapts that functional core to the legacy object interface
the simulator and benchmarks speak:

    receive(delta, client_params, meta) -> bool   # True if global updated
    params                                        # current global pytree
    version                                       # number of global updates

``meta`` carries tau (version gap), client_id, data_size and, for FedPSA,
the uploaded sensitivity sketch. One ``receive`` costs exactly one jitted
device call; ``params`` unflattens the flat state vector lazily (cached per
version). The original unjitted classes live in ``repro.federated.legacy``
as the numerical reference.
"""
from __future__ import annotations

from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.common import tree as tu
from repro.core import psa as psa_lib
from repro.federated import policies as pol


class PolicyServer:
    """Host-side adapter around one ``Policy``: owns the ``ServerState``,
    converts metas to ``Arrival``s, and renders ``StepInfo`` into the
    per-update log the benchmarks consume."""

    def __init__(self, policy: pol.Policy, params):
        self.policy = policy
        self.name = policy.name
        self.needs_sketch = policy.needs_sketch
        self.client_align = policy.client_align
        self.state = policy.init(params)
        self.log: List[dict] = []
        self._version = 0
        self._tree_cache = None
        self._tree_cache_version = -1
        self._unflatten = jax.jit(policy.spec.unflatten)

    @property
    def params(self):
        if self._tree_cache_version != self._version:
            self._tree_cache = self._unflatten(self.state.params)
            self._tree_cache_version = self._version
        return self._tree_cache

    @property
    def version(self) -> int:
        return self._version

    @property
    def psa(self) -> Optional[psa_lib.PSAState]:
        """Snapshot of the FedPSA sub-state (e.g. ``server.psa.global_sketch``).

        Copied: the live state's buffers are donated to the next jitted step,
        so a reference held across ``receive`` would be a deleted array."""
        if self.state.psa is None:
            return None
        return jax.tree_util.tree_map(jnp.copy, self.state.psa)

    def receive(self, delta, client_params, meta) -> bool:
        if self.needs_sketch and "sketch" not in meta:
            raise KeyError(
                f"{self.name} requires meta['sketch'] (behavioral sketch)")
        if self.state.cache is not None:
            cid = int(meta["client_id"])  # cache policies require a real id
            if not 0 <= cid < self.state.cache.data.shape[0]:
                raise ValueError(
                    f"client_id {cid} outside the server's num_clients="
                    f"{self.state.cache.data.shape[0]} cache")
        else:
            cid = int(meta.get("client_id", 0))
        arrival = pol.Arrival(
            update=delta,
            client_params=client_params,
            tau=jnp.float32(meta.get("tau", 0)),
            client_id=jnp.int32(cid),
            data_size=jnp.float32(meta.get("data_size", 1.0)),
            sketch=jnp.asarray(
                meta["sketch"], jnp.float32) if "sketch" in meta
            else jnp.zeros((self.policy.sketch_k,), jnp.float32),
        )
        self.state, info = self.policy.step(self.state, arrival)
        updated = bool(info.updated)
        if updated:
            self._version += 1
            if self.policy.log_fn is not None:
                entry = self.policy.log_fn(info, meta)
                if entry is not None:
                    self.log.append(entry)
        return updated


def make_server(name: str, params, *, num_clients: int = 50,
                psa_cfg: Optional[psa_lib.PSAConfig] = None,
                sketch_fn: Optional[Callable] = None, **kw) -> PolicyServer:
    """Build the policy-backed server for one algorithm.

    ``sketch_fn`` (fedpsa) maps a params *pytree* to its (k,) sketch; the
    policy core re-expresses it over the flat layout so the global-sketch
    refresh fuses into the jitted step."""
    spec = tu.FlatSpec(params)
    sketch_refresh = None
    if name == "fedpsa":
        assert psa_cfg is not None and sketch_fn is not None
        sketch_refresh = lambda vec: sketch_fn(spec.unflatten(vec))
    policy = pol.make_policy(name, spec, num_clients=num_clients,
                             psa_cfg=psa_cfg, sketch_refresh=sketch_refresh,
                             **kw)
    return PolicyServer(policy, params)
