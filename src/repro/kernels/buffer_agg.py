"""Buffered weighted-sum Pallas kernel (FedPSA Eq. 20 apply step).

Aggregates the L_s buffered client updates into the global model in one
streaming pass: for each parameter block, read the (L, block) update slab
and the global block, emit global + sum_l w_l * update_l. One HBM read per
update element, one read+write of the global — no (L x d) temporary.

Block layout: updates are stored stacked (L, d); the grid walks d in
(8*128*8)-lane blocks, weights stay resident in VMEM ((L,) is tiny).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 8 * 128 * 8


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """None -> auto: compiled on TPU, interpreter everywhere else (the
    interpreter traces the kernel body to plain XLA ops, the CPU fallback)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _buffer_agg_kernel(w_ref, g_ref, u_ref, out_ref):
    w = w_ref[...].astype(jnp.float32)           # (L,)
    u = u_ref[...].astype(jnp.float32)           # (L, block)
    g = g_ref[...].astype(jnp.float32)           # (block,)
    out_ref[...] = g + jnp.einsum("l,lb->b", w, u)


def buffer_agg_pallas(weights: jnp.ndarray, global_vec: jnp.ndarray,
                      updates: jnp.ndarray, *, block: int = DEFAULT_BLOCK,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """weights (L,), global_vec (d,), updates (L, d) -> (d,) f32.

    Layout-agnostic: under the d-sharded server this runs per-shard on the
    local ``d_local`` slice (the weighted sum is elementwise over d, so no
    cross-shard traffic). The block clamps to the vector width so a small
    shard is not padded out to the full 8k-lane default."""
    interpret = resolve_interpret(interpret)
    L, d = updates.shape
    block = min(block, -(-d // 1024) * 1024)
    n = -(-d // block)
    dp = n * block
    gv = jnp.pad(global_vec.astype(jnp.float32), [(0, dp - d)])
    up = jnp.pad(updates.astype(jnp.float32), [(0, 0), (0, dp - d)])

    out = pl.pallas_call(
        _buffer_agg_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((L,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((L, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=interpret,
    )(weights.astype(jnp.float32), gv, up)
    return out[:d]
