"""Sweep engine throughput: S-lane ``run_sweep`` vs a python loop of runs.

The point of the lane axis: a multi-seed / multi-hyperparameter grid (the
paper's Tables 1/2, Fig. 4, Table 6) should pay the simulator's
per-dispatch python/jit overhead ONCE, not once per grid point. This
benchmark runs the same S experiment variants (per-lane model/data seeds
plus a small fedasync-alpha grid) twice on the paper MLP world:

* ``loop``  — S standalone ``run_async`` calls sharing the timeline seed
  (exactly what the benchmarks did before the sweep engine), and
* ``sweep`` — ONE ``run_sweep`` call with S lanes,

and reports aggregate run-throughput (completed runs / wall-second). Both
sides get a full-length warmup so compile time is billed to neither.

Regime (the CPU notes): XLA CPU does NOT vectorize the vmapped member/lane
math — per-lane device cost is ~linear in S — so the lane win is overhead
amortization, dominant only when per-dispatch math is small. The gated
cells therefore run the overhead-bound FedSGD-style protocol (48-sample
shards, ONE local step per dispatch: batch == shard, 1 epoch) where the
python/jit per-wave overhead the lane axis shares dominates. The
paper-protocol cell (E=5 epochs, batch 16: 60 local steps/dispatch) is
recorded UNGATED for honesty: there device math dominates and the sweep
approaches parity (~1.1-1.3x, never a loss).

Writes artifacts/bench/BENCH_sweep_throughput.json. Acceptance gate
(ISSUE 5): sweep >= 3x aggregate run-throughput at S=8 on the paper MLP
(fedasync FedSGD cell; the fedpsa cell — which adds per-lane sketch
refreshes — and the paper-protocol cell are recorded alongside). Override
lanes with SWEEP_BENCH_LANES=4.
"""
from __future__ import annotations

import os
import sys
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import PSAConfig
from repro.data import ClientDataset, make_calibration_batch, make_classification
from repro.federated import SimConfig, SweepConfig, run_async, run_sweep
from repro.models import model as model_lib
from benchmarks import common

NUM_CLIENTS = 50
LATENCY_LO, LATENCY_HI = 100.0, 500.0
TARGET_DISPATCHES = 150
LANES = int(os.environ.get("SWEEP_BENCH_LANES", "8"))
GATE = 3.0

# (samples/client, batch, epochs): the gated FedSGD-style regime (one local
# step per dispatch) and the recorded paper protocol (60 steps/dispatch)
FEDSGD = dict(samples_per_client=48, batch_size=48, local_epochs=1)
PAPER = dict(samples_per_client=192, batch_size=16, local_epochs=5)

_WORLD_CACHE = {}


def build_world(samples_per_client: int = 192, seed: int = 0):
    key = (samples_per_client, seed)
    if key in _WORLD_CACHE:
        return _WORLD_CACHE[key]
    cfg = get_config("paper-synthetic-mlp")
    n = NUM_CLIENTS * samples_per_client
    full = make_classification(n + 1000, cfg.num_classes, dim=cfg.input_hw[0],
                               seed=seed, class_sep=0.7)
    test = full.subset(np.arange(n, n + 1000))
    clients = [
        ClientDataset(full.subset(np.arange(c * samples_per_client,
                                            (c + 1) * samples_per_client)))
        for c in range(NUM_CLIENTS)
    ]
    calib = make_calibration_batch(full.subset(np.arange(n)), 64, "gaussian")
    params = model_lib.init_params(jax.random.PRNGKey(seed), cfg)
    _WORLD_CACHE[key] = (cfg, clients, test, calib, params)
    return _WORLD_CACHE[key]


def horizon_for(target: int) -> float:
    mean_lat = 0.5 * (LATENCY_LO + LATENCY_HI)
    rate = 0.2 * NUM_CLIENTS / mean_lat
    return max(target / rate, 2.0 * LATENCY_HI)


def sim_kw(horizon: float, protocol: dict) -> dict:
    return dict(num_clients=NUM_CLIENTS, concurrency=0.2,
                local_epochs=protocol["local_epochs"],
                batch_size=protocol["batch_size"],
                horizon=horizon, eval_every=horizon, latency_kind="uniform",
                latency_lo=LATENCY_LO, latency_hi=LATENCY_HI,
                eval_batches=2, engine="cohort")


def lane_grid(alg: str):
    """S variants: per-lane model/data seeds, plus an alpha grid for the
    fedasync cell (hyperparameter lanes must be timeline-preserving)."""
    seeds = list(range(LANES))
    if alg == "fedasync":
        hypers = [{"alpha": round(0.3 + 0.05 * s, 2)} for s in range(LANES)]
    else:
        hypers = [None] * LANES
    return seeds, hypers


def bench_cell(alg: str, protocol: dict, label: str) -> dict:
    cfg, clients, test, calib, params = build_world(
        protocol["samples_per_client"])
    horizon = horizon_for(TARGET_DISPATCHES)
    kw = {}
    if alg == "fedpsa":
        kw = dict(psa_cfg=PSAConfig(), calib_batch=calib)
    seeds, hypers = lane_grid(alg)
    lane_params = [model_lib.init_params(jax.random.PRNGKey(s), cfg)
                   for s in seeds]

    def run_loop():
        out = []
        for s in seeds:
            # the exact standalone equivalent of sweep lane s: shared
            # timeline + data seed, per-lane init params and hyper
            sim = SimConfig(seed=0, timeline_seed=0,
                            **sim_kw(horizon, protocol))
            skw = dict(kw)
            if hypers[s]:
                skw["server_kwargs"] = dict(hypers[s])
            out.append(run_async(alg, cfg, lane_params[s], clients, test,
                                 sim, **skw))
        return out

    def run_lanes():
        sim = SimConfig(seed=0, timeline_seed=0, **sim_kw(horizon, protocol))
        sweep = SweepConfig(model_seeds=seeds,
                            policy_params=hypers)
        return run_sweep(alg, cfg, params, clients, test, sim, sweep, **kw)

    # full-length warmups: every wave/chunk bucket both paths hit is
    # compiled before the timed runs
    run_loop()
    run_lanes()

    t0 = time.perf_counter()
    loop_res = run_loop()
    t_loop = time.perf_counter() - t0
    t0 = time.perf_counter()
    sweep_res = run_lanes()
    t_sweep = time.perf_counter() - t0

    dispatches = loop_res[0].dispatches
    assert sweep_res.dispatches == dispatches, "timelines diverged"
    # the sweep's lanes are the loop's runs: spot-check final accuracies
    drift = float(np.max(np.abs(
        np.asarray(sweep_res.final_accuracy)
        - np.asarray([r.final_accuracy for r in loop_res]))))
    cell = {
        "alg": alg, "cell": label, "lanes": LANES, "horizon": horizon,
        "protocol": dict(protocol),
        "dispatches_per_run": dispatches,
        "loop": {"wall_s": t_loop, "runs_per_s": LANES / t_loop},
        "sweep": {"wall_s": t_sweep, "runs_per_s": LANES / t_sweep,
                  "cohorts": sweep_res.cohorts},
        "speedup": t_loop / t_sweep,
        "max_final_accuracy_drift": drift,
    }
    print(f"sweep_throughput,cell={label},alg={alg},S={LANES},"
          f"loop_s={t_loop:.2f},sweep_s={t_sweep:.2f},"
          f"speedup={cell['speedup']:.2f}x,drift={drift:.2e}", flush=True)
    return cell


def main(argv=None):
    cells = [bench_cell("fedasync", FEDSGD, "fedasync-fedsgd"),
             bench_cell("fedpsa", FEDSGD, "fedpsa-fedsgd"),
             bench_cell("fedasync", PAPER, "fedasync-paper-protocol")]
    payload = {
        "model": "paper-synthetic-mlp",
        "backend": jax.default_backend(),
        "num_clients": NUM_CLIENTS,
        "gate": {"cell": "fedasync-fedsgd", "min_speedup": GATE,
                 "at_lanes": 8},
        "cells": cells,
    }
    path = common.save("BENCH_sweep_throughput", payload)
    print(f"wrote {path}")
    gate = [c for c in cells if c["cell"] == "fedasync-fedsgd"]
    if gate and LANES >= 8 and gate[0]["speedup"] < GATE:
        print(f"WARNING: sweep speedup at S={LANES} is "
              f"{gate[0]['speedup']:.2f}x < {GATE}x", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
